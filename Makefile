# Single entry point for tests, benchmarks and doc checks (see README.md).
#
#   make verify      pre-merge umbrella: test-fast + docs-check
#   make test-fast   tier-1 suite (excludes @slow; the CI / pre-merge gate)
#   make test-all    everything, including multi-device + heavy-arch tests
#   make bench       benchmark driver (paper tables) + batched-engine bench
#   make bench-serve serving throughput sweep (wave size x mesh shape)
#   make bench-diff  re-run the batched bench and flag >20% throughput
#                    regressions vs the committed BENCH_batched.json snapshot
#   make docs-check  execute the code blocks in README.md and docs/*.md,
#                    and assert the README coverage matrix matches the
#                    registries (tools/gen_matrix.py --check)
#   make shims-check assert no internal caller uses the deprecated entry
#                    points (maximize/batched_maximize/legacy submit) —
#                    everything internal routes through SelectionSpec/solve

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test-fast test-all bench bench-batched bench-serve bench-diff docs-check shims-check

verify: test-fast docs-check shims-check

test-fast:
	$(PYTHON) -m pytest -x -q

test-all:
	$(PYTHON) -m pytest -q -m ""

# benchmarks.run already includes batched_bench; bench-batched runs it alone
bench:
	$(PYTHON) -m benchmarks.run

bench-batched:
	$(PYTHON) -m benchmarks.batched_bench

# own process: it must set --xla_force_host_platform_device_count pre-import
bench-serve:
	$(PYTHON) -m benchmarks.serve_bench

# fresh snapshot to /tmp, then diff against the committed baseline
bench-diff:
	$(PYTHON) -m benchmarks.batched_bench --json /tmp/BENCH_batched_new.json >/dev/null
	$(PYTHON) tools/bench_diff.py benchmarks/BENCH_batched.json /tmp/BENCH_batched_new.json

docs-check:
	$(PYTHON) tools/check_docs.py

shims-check:
	$(PYTHON) tools/check_shims.py
