# Single entry point for tests, benchmarks and doc checks (see README.md).
#
#   make verify      pre-merge umbrella: test-fast + docs-check
#   make test-fast   tier-1 suite (excludes @slow; the CI / pre-merge gate)
#   make test-all    everything, including multi-device + heavy-arch tests
#   make bench       benchmark driver (paper tables) + batched-engine bench
#   make bench-serve serving throughput sweep (wave size x mesh shape)
#   make bench-diff  re-run the batched bench and flag >20% throughput
#                    regressions vs the committed BENCH_batched.json snapshot
#   make serve-smoke serve CLI one round on a unit mesh, then diff a quick
#                    serve_bench run against the committed
#                    BENCH_serving.json (deterministic rejection/deadline
#                    counters compare exactly; timings at a loose 50%)
#   make scale-smoke quick dense-vs-matrix-free scale_bench run diffed
#                    against the committed BENCH_scale.json (analytic
#                    peak_bytes compare exactly; timings at a loose 50%)
#   make stream-smoke quick offline-vs-streaming stream_bench run diffed
#                    against the committed BENCH_streaming.json (oracle
#                    eval counts compare exactly; timings at a loose 50%)
#   make chaos-smoke quick chaos_bench run (fault injection: retry,
#                    quarantine, breaker fallback, crash-restore) diffed
#                    against the committed BENCH_resilience.json (the
#                    *_total resilience counters compare exactly; timings
#                    at a loose 50%)
#   make docs-check  execute the code blocks in README.md and docs/*.md,
#                    and assert the README coverage matrix matches the
#                    registries (tools/gen_matrix.py --check)
#   make shims-check assert no internal caller uses the deprecated entry
#                    points (maximize/batched_maximize/legacy submit) —
#                    everything internal routes through SelectionSpec/solve
#   make lint        repro-lint: the rule-registry static-analysis pass
#                    (AST rules + jaxpr audit + registry drift; see
#                    docs/linting.md) — suppress with
#                    `# lint: ok(RULE-ID): reason`

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test-fast test-all bench bench-batched bench-serve bench-diff serve-smoke scale-smoke stream-smoke chaos-smoke docs-check shims-check lint

verify: test-fast docs-check shims-check lint serve-smoke scale-smoke stream-smoke chaos-smoke

test-fast:
	$(PYTHON) -m pytest -x -q

test-all:
	$(PYTHON) -m pytest -q -m ""

# benchmarks.run already includes batched_bench; bench-batched runs it alone
bench:
	$(PYTHON) -m benchmarks.run

bench-batched:
	$(PYTHON) -m benchmarks.batched_bench

# own process: it must set --xla_force_host_platform_device_count pre-import
bench-serve:
	$(PYTHON) -m benchmarks.serve_bench

# fresh snapshot to /tmp, then diff against the committed baseline
bench-diff:
	$(PYTHON) -m benchmarks.batched_bench --json /tmp/BENCH_batched_new.json >/dev/null
	$(PYTHON) tools/bench_diff.py benchmarks/BENCH_batched.json /tmp/BENCH_batched_new.json

# serving smoke: one CLI round on a unit mesh (the sharded engine with live
# collectives reduced to one device), then a quick serve_bench diffed
# against the committed snapshot.  The quick cells are a subset of the full
# sweep; rejection/deadline counters are deterministic and compare exactly,
# timings use a loose 50% threshold (shared boxes are noisy).
serve-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=1 JAX_PLATFORMS=cpu \
	  $(PYTHON) -m repro.launch.serve --requests 8 --rounds 1 --mesh 1x1 --metrics
	$(PYTHON) -m benchmarks.serve_bench --quick --json /tmp/BENCH_serving_new.json >/dev/null
	$(PYTHON) tools/bench_diff.py benchmarks/BENCH_serving.json /tmp/BENCH_serving_new.json --threshold 0.5

# scale smoke: the quick dense-vs-matrix-free cells (a subset of the full
# sweep) diffed against the committed snapshot.  The analytic peak_bytes
# columns are machine-independent and compare exactly; wall-clock uses the
# same loose 50% threshold as serve-smoke.
scale-smoke:
	$(PYTHON) -m benchmarks.scale_bench --quick --json /tmp/BENCH_scale_new.json >/dev/null
	$(PYTHON) tools/bench_diff.py benchmarks/BENCH_scale.json /tmp/BENCH_scale_new.json --threshold 0.5

# streaming smoke: the quick offline-vs-streaming cells (a subset of the
# full sweep) diffed against the committed snapshot.  The n_evals oracle
# counters are deterministic and compare exactly; wall-clock uses the same
# loose 50% threshold as the other smokes.
stream-smoke:
	$(PYTHON) -m benchmarks.stream_bench --quick --json /tmp/BENCH_streaming_new.json >/dev/null
	$(PYTHON) tools/bench_diff.py benchmarks/BENCH_streaming.json /tmp/BENCH_streaming_new.json --threshold 0.5

# chaos smoke: the quick fault-injection cells (a subset of the full sweep)
# diffed against the committed snapshot.  The *_total resilience counters
# come from seeded fault plans against a sync server, so they are
# deterministic and compare exactly; recovery_ms / degraded_qps wall clock
# uses the same loose 50% threshold as the other smokes.
chaos-smoke:
	$(PYTHON) -m benchmarks.chaos_bench --quick --json /tmp/BENCH_resilience_new.json >/dev/null
	$(PYTHON) tools/bench_diff.py benchmarks/BENCH_resilience.json /tmp/BENCH_resilience_new.json --threshold 0.5

docs-check:
	$(PYTHON) tools/check_docs.py

shims-check:
	$(PYTHON) tools/check_shims.py

lint:
	JAX_PLATFORMS=cpu $(PYTHON) -m tools.lint
