# Single entry point for tests and benchmarks (referenced from ROADMAP.md).
#
#   make test-fast   tier-1 suite (excludes @slow; the CI / pre-merge gate)
#   make test-all    everything, including multi-device + heavy-arch tests
#   make bench       benchmark driver (paper tables) + batched-engine bench

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test-fast test-all bench bench-batched

test-fast:
	$(PYTHON) -m pytest -x -q

test-all:
	$(PYTHON) -m pytest -q -m ""

# benchmarks.run already includes batched_bench; bench-batched runs it alone
bench:
	$(PYTHON) -m benchmarks.run

bench-batched:
	$(PYTHON) -m benchmarks.batched_bench
