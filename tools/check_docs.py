"""Run the documentation's code blocks so the docs can't rot silently.

Extracts fenced code blocks from README.md and docs/*.md and executes every
block tagged ```python as a standalone script (PYTHONPATH=src, 8 forced host
devices so mesh examples work).  Blocks tagged ```python no-run are checked
for syntax only; other languages are ignored.  Blocks run concurrently
(they are independent subprocesses), so wall time is roughly the slowest
block, not the sum.

Also asserts that the README's function x backend coverage matrix matches
the live registries (``tools/gen_matrix.py --check``), so a new kernel /
padder / ShardRule registration cannot land without the front door
advertising it.

    python tools/check_docs.py            # all docs + the matrix check
    python tools/check_docs.py README.md  # one file (skips the matrix check)

Exit status is non-zero if any block fails — `make docs-check` gates on it,
and tests/test_docs_examples.py runs it in the fast tier.
"""
from __future__ import annotations

import concurrent.futures
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```(\S+)([^\n]*)\n(.*?)^```\s*$", re.M | re.S)
TIMEOUT_S = 240
MAX_WORKERS = 8

_ENV = {
    "PYTHONPATH": "src",
    "PATH": "/usr/bin:/bin:/usr/local/bin",
    "JAX_PLATFORMS": "cpu",
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
}


def doc_files(args: list[str]) -> list[pathlib.Path]:
    if args:
        return [ROOT / a for a in args]
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def blocks(path: pathlib.Path):
    text = path.read_text()
    for m in FENCE.finditer(text):
        lang, info, body = m.group(1), m.group(2), m.group(3)
        line = text[: m.start()].count("\n") + 1
        yield lang, info.strip(), body, line


def run_block(path: pathlib.Path, body: str, line: int) -> str | None:
    """Run one python block; returns an error string or None."""
    try:
        r = subprocess.run(
            [sys.executable, "-c", body],
            capture_output=True,
            text=True,
            timeout=TIMEOUT_S,
            env=_ENV,
            cwd=ROOT,
        )
    except subprocess.TimeoutExpired:
        return f"{path.name}:{line}: block timed out after {TIMEOUT_S}s"
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-12:]
        return f"{path.name}:{line}: block failed\n  " + "\n  ".join(tail)
    return None


def check_matrix() -> str | None:
    """README coverage matrix must match the registries (gen_matrix --check)."""
    r = subprocess.run(
        [sys.executable, "tools/gen_matrix.py", "--check"],
        capture_output=True,
        text=True,
        timeout=TIMEOUT_S,
        env=_ENV,
        cwd=ROOT,
    )
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-6:]
        return "README.md: coverage matrix stale\n  " + "\n  ".join(tail)
    return None


def main(argv: list[str]) -> int:
    failures, skipped = [], 0
    jobs = []  # (label, callable)
    for path in doc_files(argv):
        if not path.exists():
            failures.append(f"{path} does not exist")
            continue
        for lang, info, body, line in blocks(path):
            if lang != "python":
                continue
            if "no-run" in info:
                try:
                    compile(body, f"{path.name}:{line}", "exec")
                except SyntaxError as e:
                    failures.append(f"{path.name}:{line}: syntax error: {e}")
                skipped += 1
                continue
            jobs.append(
                (f"{path.name}:{line}", lambda p=path, b=body, l=line: run_block(p, b, l))
            )
    if not argv:
        jobs.append(("README.md:matrix", check_matrix))

    with concurrent.futures.ThreadPoolExecutor(
        max_workers=min(MAX_WORKERS, max(1, len(jobs)))
    ) as pool:
        futures = {pool.submit(fn): label for label, fn in jobs}
        for fut in concurrent.futures.as_completed(futures):
            err = fut.result()
            if err:
                failures.append(err)
            else:
                print(f"ok: {futures[fut]}")

    print(f"\n{len(jobs)} checks run, {skipped} syntax-checked, {len(failures)} failed")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
