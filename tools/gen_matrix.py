"""Generate the README function x backend coverage matrix from the registries.

The tables are derived from the LIVE plug-in points — ``gain_backend()`` /
``backend_name`` (core/optimizers/backends.py), the coalescer padder registry
(launch/coalesce.py), the ShardRule registry
(core/optimizers/distributed.py), and the optimizer registry
(core/optimizers/spec.py) — by building a tiny instance of every family /
probing every registered optimizer and asking each layer whether it serves
it.  A hand-maintained table goes stale the moment a registration lands;
these cannot.

    PYTHONPATH=src python tools/gen_matrix.py            # print the table
    PYTHONPATH=src python tools/gen_matrix.py --write    # rewrite README.md
    PYTHONPATH=src python tools/gen_matrix.py --check    # exit 1 on drift

The README block between the BEGIN/END markers below is the generated
region; ``tools/check_docs.py`` runs ``--check`` so `make docs-check` (and
the fast test tier) fail when the README drifts from the registries.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(ROOT), str(ROOT / "src")):  # script runs with sys.path[0] = tools/
    if _p not in sys.path:
        sys.path.insert(0, _p)
README = ROOT / "README.md"
BEGIN = "<!-- BEGIN GENERATED: function-backend-matrix (tools/gen_matrix.py) -->"
END = "<!-- END GENERATED: function-backend-matrix -->"
OPT_BEGIN = "<!-- BEGIN GENERATED: optimizer-registry (tools/gen_matrix.py) -->"
OPT_END = "<!-- END GENERATED: optimizer-registry -->"
LINT_BEGIN = "<!-- BEGIN GENERATED: lint-rules (tools/gen_matrix.py) -->"
LINT_END = "<!-- END GENERATED: lint-rules -->"

_N = 8  # tiny probe instances


def _families():
    """Ordered (display name, plain instance, use_kernel instance | None,
    matrix-free instance | None)."""
    from repro.core import (
        GCMI,
        FLCG,
        FLCMI,
        FLQMI,
        FLVMI,
        ConcaveOverModular,
        DisparityMin,
        DisparityMinSum,
        DisparitySum,
        FacilityLocation,
        FacilityLocationMF,
        FeatureBased,
        GraphCut,
        GraphCutMF,
        LogDet,
        ProbabilisticSetCover,
        SetCover,
        generic_mi,
        sc_mi,
    )

    rng = np.random.default_rng(0)
    S = rng.uniform(0.1, 1.0, size=(_N, _N)).astype(np.float32)
    S = (S + S.T) / 2
    Sq = rng.uniform(0.1, 1.0, size=(3, _N)).astype(np.float32)
    D = 1.0 - S
    cover = rng.integers(0, 2, size=(_N, 5)).astype(np.float32)
    probs = rng.uniform(0, 0.9, size=(_N, 5)).astype(np.float32)
    feats = rng.uniform(0, 1, size=(_N, 5)).astype(np.float32)

    sc_measure = sc_mi(cover, np.ones(5, np.float32), cover[:2])
    generic = generic_mi(SetCover.from_cover(cover), [0, 1], _N)

    return [
        ("FacilityLocation", FacilityLocation.from_kernel(S),
         FacilityLocation.from_kernel(S, use_kernel=True),
         FacilityLocationMF.from_features(feats, use_kernel=True)),
        ("GraphCut", GraphCut.from_kernel(S, lam=0.3),
         GraphCut.from_kernel(S, lam=0.3, use_kernel=True),
         GraphCutMF.from_features(feats, lam=0.3, use_kernel=True)),
        ("FeatureBased", FeatureBased.from_features(feats),
         FeatureBased.from_features(feats, use_kernel=True), None),
        ("SetCover", SetCover.from_cover(cover),
         SetCover.from_cover(cover, use_kernel=True), None),
        ("ProbabilisticSetCover", ProbabilisticSetCover.from_probs(probs),
         ProbabilisticSetCover.from_probs(probs, use_kernel=True), None),
        ("DisparitySum", DisparitySum.from_distance(D),
         DisparitySum.from_distance(D, use_kernel=True), None),
        ("DisparityMin", DisparityMin.from_distance(D),
         DisparityMin.from_distance(D, use_kernel=True), None),
        ("DisparityMinSum", DisparityMinSum.from_distance(D), None, None),
        ("LogDet", LogDet.from_kernel(S + 0.5 * np.eye(_N, dtype=np.float32)),
         None, None),
        ("FLVMI", FLVMI.build(S, Sq.T), None, None),
        ("FLQMI", FLQMI.build(Sq), None, None),
        ("FLCG", FLCG.build(S, Sq.T), None, None),
        ("FLCMI", FLCMI.build(S, Sq.T, Sq.T), None, None),
        ("GCMI", GCMI.build(Sq.T, lam=0.4), None, None),
        ("ConcaveOverModular", ConcaveOverModular.build(Sq.T), None, None),
        ("SC/PSC/GC/LogDet MI-CG measures (base-class instances)",
         sc_measure, None, None),
        ("generic MI/CG/CMI combinators", generic, None, None),
    ]


def _probe(fn, fn_kernel, fn_mf):
    """(pallas, subset-sweep, matrix-free, padder, shard-rule) cells."""
    from repro.core.optimizers.backends import backend_name, resolve_backend
    from repro.core.optimizers.distributed import shard_rule
    from repro.launch.coalesce import bucket_size, pad_function

    pallas = "—"
    subset = "`gains_at`"  # the jnp reference partial sweep (every family)
    if fn_kernel is not None:
        name = backend_name(fn_kernel)
        if name != "xla":
            pallas = f"`{name}`"
            if hasattr(resolve_backend(fn_kernel), "partial_sweep"):
                subset = "fused + `gains_at`"

    mf = "—"
    if fn_mf is not None:
        # live checks: the MF instance has its own fused sweep AND rides the
        # same serving padders as the dense form
        mf = f"features + k-NN (`{backend_name(fn_mf)}`)"
        pad_function(fn_mf, bucket_size(fn_mf.n + 1))

    try:
        pad_function(fn, bucket_size(fn.n + 1))
        padder = "yes"
    except NotImplementedError:
        padder = "—"

    try:
        shard_rule(fn)
        rule = "yes"
    except NotImplementedError:
        rule = "—"
    if rule == "yes" and fn_kernel is not None:
        try:
            shard_rule(fn_kernel)
        except ValueError:
            rule = "yes \\*"  # memoized form only: use_kernel=True rejected
    return pallas, subset, mf, padder, rule


def build_table() -> str:
    rows = [
        "| Function family | Fused Pallas sweep (`use_kernel=True`) | "
        "Subset sweep (`partial_sweep`) | Matrix-free (features/k-NN) | "
        "Served waves (padder) | Sharded serving (`ShardRule`) |",
        "|---|---|---|---|---|---|",
    ]
    for name, fn, fn_kernel, fn_mf in _families():
        pallas, subset, mf, padder, rule = _probe(fn, fn_kernel, fn_mf)
        rows.append(
            f"| {name} | {pallas} | {subset} | {mf} | {padder} | {rule} |"
        )
    rows.append("")
    rows.append(
        "Every family keeps the generic XLA full sweep (`gains()`); the "
        "subset column is the gathered partial sweep behind the bucketed "
        "lazy engines (\"fused\" = a masked-subset Pallas entry point when "
        "built with `use_kernel=True`).  Both optimizers — NaiveGreedy and "
        "LazyGreedy — run single-device, batched, and sharded for every "
        "family with a ShardRule.  The matrix-free column is the "
        "`SimilaritySource` route (`FacilityLocationMF` / `GraphCutMF` over "
        "features or a sparse k-NN graph): the n x n kernel is never "
        "materialized, and the fused feature-tile Pallas sweeps plus the "
        "serving padders are probed live — see docs/functions.md."
    )
    rows.append("")
    rows.append(
        "\\* the mesh ShardRule keeps the bit-identical contract with the "
        "*memoized* sweep only, so it rejects `use_kernel=True` instances "
        "(the stateless Pallas recompute is a different float reduction); "
        "serve those single-device, or build with `use_kernel=False`."
    )
    return "\n".join(rows)


def build_optimizer_table() -> str:
    """The optimizer-registry table, probed from the LIVE registry: which
    optimizers exist, their validated hyperparameters (with the defaults the
    specs resolve), and which execution routes each one serves."""
    from repro.core.optimizers.spec import optimizer_names, resolve_optimizer

    rows = [
        "| Optimizer | Hyperparameters (defaults) | `solve()` sequential | "
        "batched / sharded / served waves |",
        "|---|---|---|---|",
    ]
    for name in optimizer_names():
        defn = resolve_optimizer(name)
        params = (
            ", ".join(
                f"`{p}={spec.default!r}`" for p, spec in sorted(defn.params.items())
            )
            or "—"
        )
        waves = "yes" if defn.batched_capable else "—"
        rows.append(f"| {name} | {params} | yes | {waves} |")
    rows.append("")
    rows.append(
        "Probed from the `register_optimizer` registry "
        "(`repro.core.optimizers.spec`): hyperparameters are validated and "
        "defaulted at `OptimizerSpec` construction; optimizers without "
        "batched execution hooks are rejected at submit/spec-routing time, "
        "never mid-flush."
    )
    return "\n".join(rows)


def build_lint_table() -> str:
    """The lint-rules table, probed from the LIVE ``tools.lint`` registry."""
    from tools.lint import all_rules

    rows = [
        "| Rule | Engine | Scope | Invariant |",
        "|---|---|---|---|",
    ]
    for rule in all_rules():
        rows.append(
            f"| `{rule.id}` | {rule.engine} | {rule.scope} | {rule.summary} |"
        )
    rows.append("")
    rows.append(
        "Probed from the `tools.lint` rule registry (`make lint`, part of "
        "`make verify`).  Suppress a finding with "
        "`# lint: ok(RULE-ID): reason` — trailing on a line for that line, "
        "on a comment-only line for the whole file; each rule's invariant, "
        "provenance, and suppression guidance is in docs/linting.md."
    )
    return "\n".join(rows)


def _splice(text: str, begin: str, end: str, table: str) -> str:
    try:
        head, rest = text.split(begin, 1)
        _, tail = rest.split(end, 1)
    except ValueError:
        raise SystemExit(f"README.md is missing the {begin!r} / {end!r} markers")
    return f"{head}{begin}\n{table}\n{end}{tail}"


def render(readme_text: str, table: str, opt_table: str) -> str:
    out = _splice(readme_text, BEGIN, END, table)
    return _splice(out, OPT_BEGIN, OPT_END, opt_table)


def render_all(readme_text: str) -> str:
    """README text with every generated region rebuilt from the live
    registries (what ``--write`` writes and ``--check`` / the MATRIX lint
    rule compare against)."""
    out = render(readme_text, build_table(), build_optimizer_table())
    return _splice(out, LINT_BEGIN, LINT_END, build_lint_table())


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--write", action="store_true", help="rewrite README.md")
    mode.add_argument(
        "--check", action="store_true", help="exit 1 if README.md is stale"
    )
    a = ap.parse_args(argv)

    current = README.read_text()
    updated = render_all(current)
    if a.write:
        README.write_text(updated)
        print("README.md matrix regenerated")
        return 0
    if a.check:
        if current != updated:
            print(
                "README.md function x backend matrix is stale; run\n"
                "  PYTHONPATH=src python tools/gen_matrix.py --write",
                file=sys.stderr,
            )
            return 1
        print("README.md matrix matches the registries")
        return 0
    print(build_table())
    print()
    print(build_optimizer_table())
    print()
    print(build_lint_table())
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
