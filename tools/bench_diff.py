"""Compare two BENCH_*.json snapshots and flag throughput regressions.

Snapshots are what ``benchmarks/serve_bench.py --json`` and
``benchmarks/batched_bench.py --json`` write: ``{"bench": ..., "rows":
[...]}`` with each row a flat dict of identifying fields (family, B, n,
budget, mesh, gains, section, ...) plus metric fields.  Rows are matched
across snapshots by their identifying fields; for every matched row the
throughput-style metrics are compared and a drop of more than
``--threshold`` (default 20%) is a REGRESSION:

- higher-is-better metrics: ``qps`` / ``*_qps``, ``*_speedup``
- lower-is-better metrics:  ``*_ms`` / ``wave_ms``

Resilience counters (``*_total``, from ``benchmarks/chaos_bench.py``) are
deterministic by construction — seeded fault plans against a sync server —
so they compare exactly, like eval counts.

Eval *counts* and ``*_bytes`` memory footprints are compared exactly (they
are hardware-independent: a change means the algorithm or its memory shape
changed, not the machine) but reported as NOTEs, not regressions —
bit-level behaviour is the test suite's job.  ``eval_ratio`` is derived
from those counts, so it is skipped entirely rather than flagged twice
under a throughput label.

Exit status: 1 if any regression was flagged, else 0.  Benchmark timings on
shared CPU boxes are noisy (±2x run-to-run is common here — see the verify
notes), so treat a flag as "re-run and look", not proof.

    PYTHONPATH=src python tools/bench_diff.py benchmarks/BENCH_batched.json new.json
    make bench-diff   # re-runs batched_bench and diffs against the snapshot
"""
from __future__ import annotations

import argparse
import json
import sys

def _metric_kind(name: str) -> str | None:
    if name == "eval_ratio":
        return "skip"  # derived from the exact-compared eval counts
    if name.startswith("queue_"):
        return "skip"  # queue dwell is scheduler-timing noise, not throughput
    if name in ("rejections", "deadline_misses"):
        return "exact"  # deterministic by construction in serve_bench
    if name == "qps" or name.endswith("_qps") or name.endswith("speedup"):
        return "higher"
    if name.endswith("_ms") or name == "wave_ms":
        return "lower"
    if name.endswith("_evals"):
        return "exact"
    if name.endswith("_bytes"):
        return "exact"  # analytic memory footprints, hardware-independent
    if name.endswith("_total"):
        return "exact"  # resilience counters: deterministic by construction
    return None


def _row_key(row: dict) -> tuple:
    ident = {
        k: v for k, v in row.items() if _metric_kind(k) is None
    }
    return tuple(sorted(ident.items()))


def load_rows(path: str) -> dict[tuple, dict]:
    with open(path) as f:
        snap = json.load(f)
    rows = snap["rows"] if isinstance(snap, dict) else snap
    out = {}
    for row in rows:
        out[_row_key(row)] = row
    return out


def _fmt_key(key: tuple) -> str:
    return " ".join(f"{k}={v}" for k, v in key)


def diff(old_path: str, new_path: str, threshold: float = 0.2) -> int:
    old_rows = load_rows(old_path)
    new_rows = load_rows(new_path)
    regressions, improvements, notes = [], [], []

    for key, old in old_rows.items():
        new = new_rows.get(key)
        if new is None:
            notes.append(f"row dropped: {_fmt_key(key)}")
            continue
        for name, old_v in old.items():
            kind = _metric_kind(name)
            if kind is None or kind == "skip" or name not in new:
                continue
            new_v = new[name]
            if kind == "exact":
                if new_v != old_v:
                    notes.append(
                        f"{_fmt_key(key)} :: {name} {old_v} -> {new_v} "
                        "(algorithmic change?)"
                    )
                continue
            if not old_v:
                continue
            rel = (new_v - old_v) / old_v
            worse = rel < -threshold if kind == "higher" else rel > threshold
            better = rel > threshold if kind == "higher" else rel < -threshold
            line = (
                f"{_fmt_key(key)} :: {name} {old_v:.2f} -> {new_v:.2f} "
                f"({rel:+.0%})"
            )
            if worse:
                regressions.append(line)
            elif better:
                improvements.append(line)
    for key in new_rows:
        if key not in old_rows:
            notes.append(f"new row: {_fmt_key(key)}")

    if improvements:
        print(f"# {len(improvements)} improvement(s) > {threshold:.0%}")
        for line in improvements:
            print(f"  + {line}")
    if notes:
        print(f"# {len(notes)} note(s)")
        for line in notes:
            print(f"  * {line}")
    if regressions:
        print(f"# {len(regressions)} REGRESSION(S) > {threshold:.0%}")
        for line in regressions:
            print(f"  - {line}")
        return 1
    print(f"# no throughput regressions > {threshold:.0%} "
          f"({len(old_rows)} rows compared)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("old", help="baseline snapshot (the committed BENCH_*.json)")
    ap.add_argument("new", help="candidate snapshot")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.2,
        help="relative change that counts as a regression (default 0.2 = 20%%)",
    )
    a = ap.parse_args()
    return diff(a.old, a.new, a.threshold)


if __name__ == "__main__":
    sys.exit(main())
