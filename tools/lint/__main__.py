"""CLI driver: ``python -m tools.lint`` (= ``make lint``).

    python -m tools.lint                      # all rules, committed baseline
    python -m tools.lint --rules BITSTAB,LOCKDISC
    python -m tools.lint --list               # rule table
    python -m tools.lint --root /tmp/tree --rules WALLCLOCK --baseline none
    python -m tools.lint --write-baseline     # record current violations

Exit status: 0 when every non-baselined violation count is zero, 1
otherwise.  Rules marked *rooted* (jaxpr audit, registry drift) only run
against the real repo tree and are skipped under a custom ``--root``.
"""
from __future__ import annotations

import argparse
import sys
import time

from tools.lint.framework import (
    DEFAULT_BASELINE,
    ROOT,
    run_lint,
    write_baseline,
)


def _list_rules() -> int:
    from tools.lint import all_rules

    for rule in all_rules():
        print(f"{rule.id:<10} [{rule.engine}] {rule.scope}")
        print(f"{'':<10} {rule.summary}")
        print(f"{'':<10} provenance: {rule.provenance}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m tools.lint", description=__doc__)
    ap.add_argument("--rules", default=None, help="comma-separated rule ids")
    ap.add_argument("--list", action="store_true", help="print the rule table")
    ap.add_argument("--root", default=None, help="tree to scan (default: repo)")
    ap.add_argument(
        "--baseline",
        default=str(DEFAULT_BASELINE),
        help="baseline json path, or 'none' to disable",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="record every current violation into the baseline and exit 0",
    )
    a = ap.parse_args(argv)
    if a.list:
        return _list_rules()

    rule_ids = [r.strip() for r in a.rules.split(",")] if a.rules else None
    baseline = None if a.baseline.lower() == "none" else a.baseline
    t0 = time.monotonic()
    report = run_lint(root=a.root, rule_ids=rule_ids, baseline_path=baseline)

    if a.write_baseline:
        if baseline is None:
            raise SystemExit("--write-baseline needs a baseline path")
        write_baseline(
            __import__("pathlib").Path(baseline),
            report.fresh + report.baselined,
        )
        print(
            f"lint: baseline rewritten with "
            f"{len(report.fresh) + len(report.baselined)} entries"
        )
        return 0

    for v in report.fresh:
        print(f"FAIL {v.render()}", file=sys.stderr)
    for v in report.baselined:
        print(f"baselined {v.render()}")
    if report.skipped_rules:
        print(f"skipped (custom --root): {', '.join(report.skipped_rules)}")
    root = a.root or ROOT
    print(
        f"lint: {len(report.ran_rules)} rules over {report.n_files} files "
        f"({root}) in {time.monotonic() - t0:.1f}s — "
        f"{len(report.fresh)} violations, {len(report.baselined)} baselined"
    )
    return 1 if report.failed else 0


if __name__ == "__main__":
    sys.exit(main())
