"""Jaxpr auditor: structural invariants of traced selection programs.

Generalizes the one-off jaxpr-walk test from the matrix-free PR into a
library (``walk_jaxprs`` / ``square_intermediates`` / ``host_callbacks`` /
``dot_generals``) plus a manifest of representative specs.  The registered
JAXPR rule traces every manifest case at n = 50_000 and asserts:

- **no (n, n) intermediate** — the streaming ceiling that lets selection
  reach n >= 10^6 on one host (peak bytes O(n * d + n * TILE));
- **no host callbacks** — a ``pure_callback`` / ``io_callback`` inside a
  sweep would silently serialize every tile through the host;
- **no ``dot_general``** — the bit-pinned gains paths are reduce-form by
  contract (see BITSTAB); a contraction primitive appearing in a traced
  sweep means some path regressed to matvec form.

The library half is import-safe without jax installed being configured for
any particular backend; tracing happens only when a manifest runs.  Tests
(``tests/test_matrix_free.py``) import the walk/check helpers from here so
the test suite and the lint gate share one implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from tools.lint.framework import LintContext, Violation, register_rule

# ---------------------------------------------------------------------------
# jaxpr walking + structural checks (pure library, no manifest state)


def walk_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every jaxpr nested in its eqn params (scan /
    while / cond bodies, custom_vmap rules, pjit calls, ...)."""
    import jax.extend.core  # explicit: `import jax` alone does not expose it

    yield jaxpr
    for eqn in jaxpr.eqns:
        stack = list(eqn.params.values())
        while stack:
            p = stack.pop()
            if isinstance(p, (tuple, list)):
                stack.extend(p)
            elif isinstance(p, jax.extend.core.ClosedJaxpr):
                yield from walk_jaxprs(p.jaxpr)
            elif hasattr(p, "eqns"):
                yield from walk_jaxprs(p)


def iter_eqns(jaxpr):
    """Every equation in ``jaxpr`` and its nested jaxprs."""
    for jx in walk_jaxprs(jaxpr):
        yield from jx.eqns


def square_intermediates(jaxpr, n: int, tile: int) -> list[str]:
    """Descriptions of intermediates violating the streaming ceiling: any
    value with two dims >= n, or more than ``n * 4 * tile`` elements
    (O(n * d + n * TILE) streaming blocks pass; an (n, n) kernel does
    not)."""
    cap = n * 4 * tile
    out = []
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            shape = getattr(getattr(v, "aval", None), "shape", None)
            if not shape:
                continue
            dims = [s for s in shape if isinstance(s, int)]
            big = [s for s in dims if s >= n]
            size = 1
            for s in dims:
                size *= s
            if len(big) >= 2:
                out.append(
                    f"(n, n)-sized intermediate {tuple(shape)} in "
                    f"{eqn.primitive}"
                )
            elif size > cap:
                out.append(
                    f"intermediate {tuple(shape)} ({size} elems) exceeds "
                    f"the n*4*TILE streaming ceiling in {eqn.primitive}"
                )
    return out


def host_callbacks(jaxpr) -> list[str]:
    """Host-callback primitives (pure_callback / io_callback / debug
    callbacks) anywhere in the program."""
    return sorted(
        {
            f"host callback primitive `{eqn.primitive.name}`"
            for eqn in iter_eqns(jaxpr)
            if "callback" in eqn.primitive.name
        }
    )


def dot_generals(jaxpr) -> list[str]:
    """``dot_general`` (or einsum-lowered) contraction primitives — banned
    in bit-pinned sweeps, where every contraction must be reduce-form."""
    return sorted(
        {
            f"contraction primitive `{eqn.primitive.name}`"
            for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in ("dot_general", "einsum")
        }
    )


# ---------------------------------------------------------------------------
# the manifest


@dataclasses.dataclass(frozen=True)
class AuditCase:
    """One representative traced program.  ``trace()`` returns a
    ``ClosedJaxpr`` (via ``jax.make_jaxpr``); the flags pick which
    structural invariants apply."""

    name: str
    n: int
    trace: Callable[[], object]
    forbid_square: bool = True
    forbid_callbacks: bool = True
    forbid_dot_general: bool = True


N_AUDIT = 50_000  # the ISSUE-mandated ceiling re-proof size
_D, _U, _K = 8, 64, 8


def _features(seed: int, rows: int, d: int = _D):
    import numpy as np

    return np.asarray(
        np.random.default_rng(seed).standard_normal((rows, d)), np.float32
    )


def _knn(seed: int, n: int, k: int = _K):
    import numpy as np

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n, size=(n, k)).astype(np.int32)
    w = np.abs(rng.standard_normal((n, k))).astype(np.float32)
    return idx, w


def default_manifest(n: int = N_AUDIT) -> list[AuditCase]:
    """Every matrix-free source x metric x optimizer cell the repo's
    streaming guarantee covers, traced at ``n`` candidates."""
    import jax
    import jax.numpy as jnp

    from repro.core import FacilityLocationMF, GraphCutMF
    from repro.core.optimizers.backends import full_sweep, partial_sweep
    from repro.core.optimizers.greedy import naive_greedy

    x, y = _features(0, _U), _features(1, n)

    def flmf(metric):
        return FacilityLocationMF.from_features(x, y=y, metric=metric)

    def gcmf(metric):
        return GraphCutMF.from_features(y, metric=metric)

    def t_full(fn):
        return jax.make_jaxpr(lambda f: full_sweep(f, f.init_state()))(fn)

    def t_partial(fn):
        idx = jnp.arange(_K, dtype=jnp.int32)
        return jax.make_jaxpr(
            lambda f: partial_sweep(f, f.init_state(), idx)
        )(fn)

    def t_greedy(fn):
        return jax.make_jaxpr(lambda f: naive_greedy(f, 3))(fn)

    cases = [
        AuditCase(f"flmf-{m}-full_sweep", n, lambda m=m: t_full(flmf(m)))
        for m in ("dot", "cosine", "rbf", "euclidean")
    ]
    cases += [
        AuditCase("flmf-dot-naive_greedy", n, lambda: t_greedy(flmf("dot"))),
        AuditCase("flmf-dot-partial_sweep", n, lambda: t_partial(flmf("dot"))),
        AuditCase("gcmf-dot-full_sweep", n, lambda: t_full(gcmf("dot"))),
        AuditCase("gcmf-rbf-full_sweep", n, lambda: t_full(gcmf("rbf"))),
        AuditCase("gcmf-dot-naive_greedy", n, lambda: t_greedy(gcmf("dot"))),
    ]

    ki, kw = _knn(2, n)
    cases += [
        AuditCase(
            "flmf-knn-full_sweep",
            n,
            lambda: t_full(FacilityLocationMF.from_knn(ki, kw)),
        ),
        AuditCase(
            "gcmf-knn-full_sweep",
            n,
            lambda: t_full(GraphCutMF.from_knn(ki, kw)),
        ),
    ]
    return cases


def audit_case(case: AuditCase, tile: int | None = None) -> list[str]:
    """Trace one case and return every invariant breach (empty = clean)."""
    if tile is None:
        from repro.core.sources import TILE as tile

    closed = case.trace()
    jaxpr = getattr(closed, "jaxpr", closed)
    problems = []
    if case.forbid_square:
        problems += square_intermediates(jaxpr, case.n, tile)
    if case.forbid_callbacks:
        problems += host_callbacks(jaxpr)
    if case.forbid_dot_general:
        problems += dot_generals(jaxpr)
    return problems


@register_rule(
    "JAXPR",
    engine="jaxpr",
    scope="traced manifest (matrix-free source x metric x optimizer cells)",
    summary=(
        "traced matrix-free sweeps contain no (n, n) intermediate at "
        f"n = {N_AUDIT:,}, no host callbacks, and no dot_general in "
        "bit-pinned sweeps"
    ),
    provenance=(
        "PR 7: the streaming-source PR proved the O(n * d + n * TILE) "
        "ceiling with a one-off jaxpr walk at n = 5e4; this generalizes "
        "that walk over every source x metric x optimizer cell so a new "
        "code path cannot quietly re-materialize the kernel"
    ),
    rooted=True,
)
def check_jaxpr(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    for case in default_manifest():
        for problem in audit_case(case):
            out.append(
                Violation("JAXPR", f"<jaxpr:{case.name}>", 1, problem)
            )
    return out
