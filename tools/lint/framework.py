"""repro-lint: the rule registry, pragma suppression, and baseline machinery.

The repo's headline guarantee — one ``SelectionSpec`` is bit-identical
(ids / gains / n_evals) across sequential, batched, sharded, served and
async execution — rests on invariants that used to live only in prose
(CHANGES.md NOTEs, docstrings).  This package turns each of them into a
registered lint rule so a regression fails ``make lint`` (part of
``make verify``) instead of silently corrupting selections:

- AST rules scan the source trees named in their scope (see
  ``tools/lint/ast_rules.py``);
- the jaxpr auditor traces representative matrix-free programs and checks
  structural invariants of the emitted jaxprs
  (``tools/lint/jaxpr_audit.py``);
- registry rules re-check generated artifacts against the live plug-in
  registries (the README coverage matrix).

Suppression — ``# lint: ok(RULE-ID): reason`` — comes in two scopes:

- **trailing** (the pragma shares a line with code): suppresses that rule
  on that line only;
- **file-scoped** (the pragma is a comment-only line): suppresses that rule
  for the whole file.

A reason is mandatory; a pragma without one does not parse and suppresses
nothing.

The baseline (``tools/lint/baseline.json``) is a burn-down list: violations
recorded there are reported but do not fail the run, so a new rule can land
before every historical violation is fixed.  New violations always fail.
The committed baseline is empty — keep it that way; it exists for
transitions, not as a parking lot.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Callable, Iterable

ROOT = pathlib.Path(__file__).resolve().parents[2]
DEFAULT_BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"

_PRAGMA = re.compile(r"#\s*lint:\s*ok\(([A-Za-z0-9_\-]+)\)\s*:\s*(\S.*?)\s*$")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule finding.  ``path`` is root-relative (posix); jaxpr-audit
    findings use ``<jaxpr:case-name>`` pseudo-paths (no file to point at)."""

    rule: str
    path: str
    line: int
    message: str

    def key(self) -> str:
        """Baseline identity: line numbers are deliberately excluded so an
        unrelated edit above a baselined violation does not churn the file."""
        return f"{self.rule}::{self.path}::{self.message}"

    def render(self) -> str:
        return f"{self.rule} {self.path}:{self.line}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered check.  ``check(ctx)`` returns raw violations; the
    framework applies pragma suppression and the baseline afterwards."""

    id: str
    engine: str  # "ast" | "jaxpr" | "registry"
    scope: str  # human-readable tree description (docs + --list)
    summary: str  # one-line invariant (the README rules table)
    provenance: str  # which PR's hard-won fix this rule fossilizes
    check: Callable[["LintContext"], list[Violation]]
    rooted: bool = False  # True: only meaningful against the real repo tree


RULES: dict[str, Rule] = {}


def register_rule(
    rule_id: str,
    *,
    engine: str,
    scope: str,
    summary: str,
    provenance: str,
    rooted: bool = False,
):
    """Decorator registering ``fn(ctx) -> list[Violation]`` as a rule."""

    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate lint rule id {rule_id!r}")
        RULES[rule_id] = Rule(
            id=rule_id,
            engine=engine,
            scope=scope,
            summary=summary,
            provenance=provenance,
            check=fn,
            rooted=rooted,
        )
        return fn

    return deco


def all_rules() -> list[Rule]:
    """Registration-ordered rule list (imports the rule modules)."""
    from tools.lint import _ensure_registered

    _ensure_registered()
    return list(RULES.values())


class SourceFile:
    """One parsed python file plus its pragma index."""

    def __init__(self, path: pathlib.Path, root: pathlib.Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.text = path.read_text()
        self.tree = ast.parse(self.text, filename=str(path))
        self.file_pragmas: set[str] = set()
        self.line_pragmas: dict[int, set[str]] = {}
        for lineno, raw in enumerate(self.text.splitlines(), 1):
            m = _PRAGMA.search(raw)
            if not m:
                continue
            rule = m.group(1)
            if raw.strip().startswith("#"):
                self.file_pragmas.add(rule)  # comment-only line: whole file
            else:
                self.line_pragmas.setdefault(lineno, set()).add(rule)

    def suppressed(self, rule: str, line: int) -> bool:
        return rule in self.file_pragmas or rule in self.line_pragmas.get(
            line, set()
        )


class LintContext:
    """Parsed-file cache shared by every rule in a run."""

    def __init__(self, root: pathlib.Path):
        self.root = pathlib.Path(root).resolve()
        self._cache: dict[pathlib.Path, SourceFile] = {}
        self._by_rel: dict[str, SourceFile] = {}

    def files(self, *trees: str) -> list[SourceFile]:
        """Every ``*.py`` under the given root-relative trees (a tree may
        also name a single file).  Missing trees yield nothing, so the same
        rules run unchanged against fixture trees in tests."""
        out: list[SourceFile] = []
        for tree in trees:
            base = self.root / tree
            if base.is_file():
                paths: Iterable[pathlib.Path] = [base]
            elif base.is_dir():
                paths = sorted(base.rglob("*.py"))
            else:
                continue
            for path in paths:
                sf = self._cache.get(path)
                if sf is None:
                    sf = self._cache[path] = SourceFile(path, self.root)
                    self._by_rel[sf.rel] = sf
                out.append(sf)
        return out

    def lookup(self, rel: str) -> SourceFile | None:
        return self._by_rel.get(rel)

    @property
    def n_files(self) -> int:
        return len(self._cache)


@dataclasses.dataclass
class LintReport:
    fresh: list[Violation]
    baselined: list[Violation]
    skipped_rules: list[str]  # rooted rules skipped under a custom --root
    ran_rules: list[str]
    n_files: int

    @property
    def failed(self) -> bool:
        return bool(self.fresh)


def load_baseline(path: pathlib.Path | None) -> set[str]:
    if path is None or not path.exists():
        return set()
    data = json.loads(path.read_text())
    if not isinstance(data, list) or not all(isinstance(k, str) for k in data):
        raise SystemExit(f"{path}: baseline must be a JSON list of keys")
    return set(data)


def write_baseline(path: pathlib.Path, violations: list[Violation]) -> None:
    keys = sorted({v.key() for v in violations})
    path.write_text(json.dumps(keys, indent=2) + "\n")


def run_lint(
    root: pathlib.Path | str | None = None,
    rule_ids: list[str] | None = None,
    baseline_path: pathlib.Path | str | None = DEFAULT_BASELINE,
) -> LintReport:
    """Run the selected rules (default: all) against ``root`` (default: the
    repo).  Returns the report; the CLI in ``__main__`` owns printing and
    exit codes, so tests can call this in-process."""
    from tools.lint import _ensure_registered

    _ensure_registered()
    root = pathlib.Path(root).resolve() if root is not None else ROOT
    ctx = LintContext(root)
    at_root = root == ROOT
    if rule_ids is None:
        selected = list(RULES.values())
    else:
        unknown = [r for r in rule_ids if r not in RULES]
        if unknown:
            raise SystemExit(
                f"unknown lint rule(s) {unknown}; known: {sorted(RULES)}"
            )
        selected = [RULES[r] for r in rule_ids]

    baseline = load_baseline(
        pathlib.Path(baseline_path) if baseline_path is not None else None
    )
    fresh: list[Violation] = []
    baselined: list[Violation] = []
    skipped: list[str] = []
    ran: list[str] = []
    for rule in selected:
        if rule.rooted and not at_root:
            skipped.append(rule.id)
            continue
        ran.append(rule.id)
        for v in rule.check(ctx):
            sf = ctx.lookup(v.path)
            if sf is not None and sf.suppressed(v.rule, v.line):
                continue
            (baselined if v.key() in baseline else fresh).append(v)
    return LintReport(
        fresh=fresh,
        baselined=baselined,
        skipped_rules=skipped,
        ran_rules=ran,
        n_files=ctx.n_files,
    )
