"""repro-lint: static analysis enforcing the repo's bit-stability,
lock-discipline, and trace-purity invariants.

Run it with ``make lint`` or ``python -m tools.lint``; see
``tools/lint/framework.py`` for the registry / pragma / baseline contract,
``docs/linting.md`` for the rule reference.
"""
from __future__ import annotations

import pathlib
import sys

# The package is imported both as ``tools.lint`` (from the repo root) and by
# scripts whose sys.path[0] is tools/; rules additionally import the library
# under src/.  Pin both roots defensively so every entry point agrees.
_ROOT = pathlib.Path(__file__).resolve().parents[2]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from tools.lint.framework import (  # noqa: E402
    RULES,
    LintReport,
    Rule,
    Violation,
    all_rules,
    register_rule,
    run_lint,
)

_REGISTERED = False


def _ensure_registered() -> None:
    """Import every rule module exactly once (registration side effect)."""
    global _REGISTERED
    if _REGISTERED:
        return
    _REGISTERED = True
    from tools.lint import ast_rules  # noqa: F401
    from tools.lint import jaxpr_audit  # noqa: F401


__all__ = [
    "RULES",
    "LintReport",
    "Rule",
    "Violation",
    "all_rules",
    "register_rule",
    "run_lint",
]
