"""AST rules over the library source trees.

Each rule fossilizes a hard-won fix from an earlier PR (provenance in the
registration); see docs/linting.md for the full reference and suppression
syntax.  All rules operate purely on parsed source — no imports, no
execution — so they run against fixture trees in tests via ``--root``.
"""
from __future__ import annotations

import ast

from tools.lint.framework import LintContext, Violation, register_rule

# ---------------------------------------------------------------------------
# shared helpers


def _call_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _attr_root(node: ast.expr) -> str | None:
    """The leftmost Name of an attribute chain (``np`` in ``np.random.x``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _module_aliases(tree: ast.Module, modules: set[str]) -> dict[str, str]:
    """Names this file binds to any of ``modules`` via ``import`` — e.g.
    ``{"time": "time", "t": "time"}`` for ``import time as t``."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                top = alias.name.split(".")[0]
                if top in modules:
                    out[alias.asname or top] = top
    return out


def _from_imports(tree: ast.Module, modules: set[str]) -> dict[str, str]:
    """Names bound via ``from <module> import x [as y]`` — ``{y: module.x}``."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            top = node.module.split(".")[0]
            if top in modules:
                for alias in node.names:
                    out[alias.asname or alias.name] = f"{top}.{alias.name}"
    return out


# ---------------------------------------------------------------------------
# BITSTAB — reduce-form contractions only, on every gains path


_BITSTAB_TREES = (
    "src/repro/core/functions",
    "src/repro/core/info",
    "src/repro/core/sources.py",
    "src/repro/kernels/ref.py",
)
# beyond *gains* itself: the memoized-statistic update and the
# SimilaritySource streaming contract are marginal-path too — a matmul there
# re-introduces the same shape-dependent reduction order
_BITSTAB_EXTRA = {"update", "col", "col_sums", "diag", "masked_rowmax"}
_CONTRACTIONS = {"dot", "matmul", "einsum", "tensordot", "vdot"}


def _gains_path(name: str) -> bool:
    return "gains" in name or name in _BITSTAB_EXTRA


@register_rule(
    "BITSTAB",
    engine="ast",
    scope="core/functions, core/info, core/sources.py, kernels/ref.py",
    summary=(
        "no `@` / `jnp.dot` / `jnp.matmul` / `jnp.einsum` inside gains / "
        "gains_at / marginal-path methods — reduce-form contractions only"
    ),
    provenance=(
        "PR 2/3: XLA matvec reduction trees are shape- and batch-dependent, "
        "so `@` in a gains path broke served-vs-sequential bit-identity; "
        "every family was rewritten to `(A * m).sum(axis)` reduce form"
    ),
)
def check_bitstab(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    for sf in ctx.files(*_BITSTAB_TREES):
        seen: set[tuple[int, int]] = set()
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _gains_path(fn.name):
                continue
            for node in ast.walk(fn):
                bad = None
                if isinstance(node, ast.BinOp) and isinstance(
                    node.op, ast.MatMult
                ):
                    bad = "`@` (matmul)"
                elif (
                    isinstance(node, ast.Call)
                    and _call_name(node) in _CONTRACTIONS
                ):
                    bad = f"`{_call_name(node)}()`"
                if bad is None:
                    continue
                key = (node.lineno, node.col_offset)
                if key in seen:
                    continue
                seen.add(key)
                out.append(
                    Violation(
                        "BITSTAB",
                        sf.rel,
                        node.lineno,
                        f"{bad} in gains-path function {fn.name!r}: use the "
                        "reduce form `(A * m).sum(axis)` — XLA contraction "
                        "order is shape/batch dependent and breaks the "
                        "bit-identity contract",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# NEGMASK — dense gains_at overrides must route the masking hook


_NEGMASK_TREES = ("src/repro/core", "src/repro/kernels")
_HOOK_BASE = "SetFunction"
_HOOK_FN = "_mask_negative_idxs"


def _base_names(cls: ast.ClassDef) -> list[str]:
    names = []
    for b in cls.bases:
        if isinstance(b, ast.Name):
            names.append(b.id)
        elif isinstance(b, ast.Attribute):
            names.append(b.attr)
    return names


@register_rule(
    "NEGMASK",
    engine="ast",
    scope="core/, kernels/",
    summary=(
        "every `gains_at` override must route the SetFunction "
        "`__init_subclass__` NEG-INF masking hook (no hook-bypassing "
        "classes or post-hoc assignments)"
    ),
    provenance=(
        "PR 8: dense gains_at is a plain gather, so idx = -1 silently read "
        "the LAST row and a padded order buffer could select a ghost of the "
        "last candidate; the `__init_subclass__` hook NEG-INF-masks every "
        "override exactly once"
    ),
)
def check_negmask(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    files = ctx.files(*_NEGMASK_TREES)

    # pass 1: the class graph across the scanned tree
    bases: dict[str, list[str]] = {}
    for sf in files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                bases.setdefault(node.name, _base_names(node))

    def descends(name: str, seen: frozenset[str] = frozenset()) -> bool:
        if name == _HOOK_BASE:
            return True
        if name in seen:
            return False
        return any(
            descends(b, seen | {name}) for b in bases.get(name, ())
        )

    # pass 2: overrides and post-hoc assignments
    for sf in files:
        class_stack: list[ast.ClassDef] = []

        def visit(node, in_class: ast.ClassDef | None):
            if isinstance(node, ast.ClassDef):
                for child in node.body:
                    visit(child, node)
                return
            if (
                isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name == "gains_at"
                and in_class is not None
                and not descends(in_class.name)
            ):
                out.append(
                    Violation(
                        "NEGMASK",
                        sf.rel,
                        node.lineno,
                        f"class {in_class.name!r} overrides gains_at but "
                        "does not descend from SetFunction — the "
                        "__init_subclass__ NEG-INF masking hook will not "
                        "wrap it and idx < 0 wraps pythonically",
                    )
                )
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (
                        isinstance(tgt, ast.Attribute)
                        and tgt.attr == "gains_at"
                        and not (
                            isinstance(node.value, ast.Call)
                            and _call_name(node.value) == _HOOK_FN
                        )
                    ):
                        out.append(
                            Violation(
                                "NEGMASK",
                                sf.rel,
                                node.lineno,
                                "post-hoc `<cls>.gains_at = ...` assignment "
                                "bypasses the __init_subclass__ masking "
                                "hook; define gains_at in a SetFunction "
                                "subclass body (or wrap the value in "
                                "_mask_negative_idxs)",
                            )
                        )
            for child in ast.iter_child_nodes(node):
                visit(child, in_class if not isinstance(node, ast.ClassDef) else None)

        for top in sf.tree.body:
            visit(top, None)
    return out


# ---------------------------------------------------------------------------
# LOCKDISC — declared lock ownership, enforced


_LOCKDISC_TREES = ("src/repro/launch",)
_LOCK_FACTORIES = {"Lock", "RLock", "Condition"}
_LOCKDISC_EXEMPT = {"__init__", "__post_init__", "__del__"}


def _guarded_map(cls: ast.ClassDef):
    """(map, lineno) from a literal ``_GUARDED_BY = {...}`` in the class
    body, or (None, None).  Raises ValueError on a non-literal map."""
    for node in cls.body:
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "_GUARDED_BY"
            for t in node.targets
        ):
            value = ast.literal_eval(node.value)  # may raise ValueError
            if not (
                isinstance(value, dict)
                and all(
                    isinstance(k, str) and isinstance(v, str)
                    for k, v in value.items()
                )
            ):
                raise ValueError("_GUARDED_BY must be a {attr: lock} dict")
            return value, node.lineno
    return None, None


def _self_lock_assignments(cls: ast.ClassDef) -> list[tuple[str, int]]:
    """``self.<x> = threading.Lock()/RLock()/Condition()`` sites."""
    out = []
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not (
            isinstance(node.value, ast.Call)
            and _call_name(node.value) in _LOCK_FACTORIES
        ):
            continue
        for tgt in node.targets:
            if (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                out.append((tgt.attr, node.lineno))
    return out


@register_rule(
    "LOCKDISC",
    engine="ast",
    scope="launch/",
    summary=(
        "lock-bearing classes declare `_GUARDED_BY = {attr: lock}` and "
        "guarded attributes are only touched inside `with self.<lock>` "
        "(methods named `*_locked` assert the caller holds it)"
    ),
    provenance=(
        "PR 6/9: async_serve's two-lock protocol (`_cv` guards queues + "
        "futures ONLY; dispatch runs outside it) fixed head-of-line "
        "blocking and a close() race that stranded futures — the protocol "
        "is now machine-checked, not a docstring"
    ),
)
def check_lockdisc(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    for sf in ctx.files(*_LOCKDISC_TREES):
        for cls in ast.walk(sf.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            try:
                guarded, _ = _guarded_map(cls)
            except ValueError as e:
                out.append(
                    Violation(
                        "LOCKDISC",
                        sf.rel,
                        cls.lineno,
                        f"class {cls.name!r}: _GUARDED_BY is not a literal "
                        f"{{attr: lock}} dict ({e})",
                    )
                )
                continue
            locks_made = _self_lock_assignments(cls)
            if guarded is None:
                if locks_made:
                    attr, lineno = locks_made[0]
                    out.append(
                        Violation(
                            "LOCKDISC",
                            sf.rel,
                            lineno,
                            f"class {cls.name!r} creates a lock "
                            f"(self.{attr}) but declares no _GUARDED_BY "
                            "map — declare which attributes the lock "
                            "guards",
                        )
                    )
                continue
            lock_names = set(guarded.values())
            for meth in cls.body:
                if not isinstance(
                    meth, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    continue
                if meth.name in _LOCKDISC_EXEMPT or meth.name.endswith(
                    "_locked"
                ):
                    continue
                _check_method(out, sf, cls, meth, guarded, lock_names)
    return out


def _check_method(out, sf, cls, meth, guarded, lock_names):
    def visit(node, held: frozenset[str]):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = set()
            for item in node.items:
                ce = item.context_expr
                visit(ce, held)  # the lock expr itself runs unguarded
                if item.optional_vars is not None:
                    visit(item.optional_vars, held)
                if (
                    isinstance(ce, ast.Attribute)
                    and isinstance(ce.value, ast.Name)
                    and ce.value.id == "self"
                    and ce.attr in lock_names
                ):
                    acquired.add(ce.attr)
            inner = held | acquired
            for stmt in node.body:
                visit(stmt, inner)
            return
        if (
            isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in guarded
            and guarded[node.attr] not in held
        ):
            out.append(
                Violation(
                    "LOCKDISC",
                    sf.rel,
                    node.lineno,
                    f"{cls.name}.{meth.name} touches self.{node.attr} "
                    f"outside `with self.{guarded[node.attr]}` (declared "
                    f"in _GUARDED_BY); hold the lock, or suffix the "
                    "method `_locked` if the caller holds it",
                )
            )
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for stmt in meth.body:
        visit(stmt, frozenset())


# ---------------------------------------------------------------------------
# TRACEPURE — no host-side impurity in traced-code trees


_TRACEPURE_TREES = ("src/repro/core", "src/repro/kernels")
_IMPURE_MODULES = {"time", "random", "threading"}
_NUMPY_MODULES = {"numpy"}


@register_rule(
    "TRACEPURE",
    engine="ast",
    scope="core/, kernels/",
    summary=(
        "no `time.*` / `random.*` / `np.random.*` / `threading.*` calls in "
        "code reachable from jit traces (jax.random is fine — it is "
        "functional)"
    ),
    provenance=(
        "PR 9: faults.check no-ops inside jax traces (trace_state_clean) "
        "because host-side effects fired during tracing would be baked "
        "into the jit cache — firing order, timing and randomness must "
        "never depend on cache state"
    ),
)
def check_tracepure(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    for sf in ctx.files(*_TRACEPURE_TREES):
        aliases = _module_aliases(sf.tree, _IMPURE_MODULES)
        np_aliases = _module_aliases(sf.tree, _NUMPY_MODULES)
        from_names = _from_imports(sf.tree, _IMPURE_MODULES)
        # `from numpy import random [as r]` binds the same hazard
        for name, origin in _from_imports(sf.tree, _NUMPY_MODULES).items():
            if origin == "numpy.random":
                aliases[name] = "numpy.random"
        if not aliases and not np_aliases and not from_names:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id in from_names:
                    out.append(
                        Violation(
                            "TRACEPURE",
                            sf.rel,
                            node.lineno,
                            f"call to {from_names[f.id]} in a traced-code "
                            "tree: host-side impurity would be baked into "
                            "jit caches (use jax.random / hoist to launch/)",
                        )
                    )
                    continue
                root = _attr_root(f) if isinstance(f, ast.Attribute) else None
                if root in aliases:
                    out.append(
                        Violation(
                            "TRACEPURE",
                            sf.rel,
                            node.lineno,
                            f"call into {aliases[root]!r} in a traced-code "
                            "tree: host-side impurity would be baked into "
                            "jit caches (use jax.random / hoist to launch/)",
                        )
                    )
                    continue
            if (
                isinstance(node, ast.Attribute)
                and node.attr == "random"
                and isinstance(node.value, ast.Name)
                and node.value.id in np_aliases
            ):
                out.append(
                    Violation(
                        "TRACEPURE",
                        sf.rel,
                        node.lineno,
                        "np.random in a traced-code tree: stateful host "
                        "RNG would make traced values depend on call "
                        "order (use jax.random keys)",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# WALLCLOCK — monotonic clocks for durations


_WALLCLOCK_TREES = ("src/repro", "benchmarks", "tools", "examples")


@register_rule(
    "WALLCLOCK",
    engine="ast",
    scope="src/repro, benchmarks, tools, examples",
    summary=(
        "`time.time()` is banned — durations must use `time.monotonic()` / "
        "`time.perf_counter()` (pragma the rare epoch-timestamp need)"
    ),
    provenance=(
        "PR 10: dryrun.py timed compile/lower phases with time.time(), "
        "which jumps under NTP slew — every latency figure in the serving "
        "stack (queue_s / wave_s / backoff / breaker cooldowns) is "
        "monotonic; this keeps it that way"
    ),
)
def check_wallclock(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    for sf in ctx.files(*_WALLCLOCK_TREES):
        aliases = _module_aliases(sf.tree, {"time"})
        from_names = {
            name
            for name, origin in _from_imports(sf.tree, {"time"}).items()
            if origin == "time.time"
        }
        if not aliases and not from_names:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            hit = (
                isinstance(f, ast.Name) and f.id in from_names
            ) or (
                isinstance(f, ast.Attribute)
                and f.attr == "time"
                and isinstance(f.value, ast.Name)
                and f.value.id in aliases
            )
            if hit:
                out.append(
                    Violation(
                        "WALLCLOCK",
                        sf.rel,
                        node.lineno,
                        "time.time() jumps under clock slew — use "
                        "time.monotonic() for durations (pragma with a "
                        "reason if you truly need an epoch timestamp)",
                    )
                )
    return out


# ---------------------------------------------------------------------------
# SHIMS — no internal caller uses the deprecated entry points


_SHIMS_TREES = ("src/repro", "benchmarks", "examples", "tools")
_LEGACY_NAMES = {"maximize", "batched_maximize"}
_LEGACY_SUBMIT_KWARGS = {
    "budget",
    "optimizer",
    "stopIfZeroGain",
    "stopIfNegativeGain",
    "screen_k",
}


@register_rule(
    "SHIMS",
    engine="ast",
    scope="src/repro, benchmarks, examples, tools",
    summary=(
        "no internal caller uses the deprecated entry points "
        "(`maximize` / `batched_maximize` / legacy `submit(fn, budget, "
        "...)`) — everything routes through SelectionSpec / solve()"
    ),
    provenance=(
        "PR 5: the legacy entry points became DeprecationWarning shims "
        "over the typed front door; internal use would make them "
        "permanent (formerly tools/check_shims.py, now a registered rule)"
    ),
)
def check_shims(ctx: LintContext) -> list[Violation]:
    out: list[Violation] = []
    for sf in ctx.files(*_SHIMS_TREES):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name in _LEGACY_NAMES:
                out.append(
                    Violation(
                        "SHIMS",
                        sf.rel,
                        node.lineno,
                        f"call to deprecated shim {name!r} — route through "
                        "solve(SelectionSpec(...)) / BatchedEngine.run",
                    )
                )
            elif name == "submit" and isinstance(node.func, ast.Attribute):
                kwargs = {k.arg for k in node.keywords if k.arg}
                if len(node.args) >= 2 or kwargs & _LEGACY_SUBMIT_KWARGS:
                    out.append(
                        Violation(
                            "SHIMS",
                            sf.rel,
                            node.lineno,
                            "legacy submit(fn, budget, ...) form — submit "
                            "a SelectionSpec instead",
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# MATRIX — the README's generated tables match the live registries


@register_rule(
    "MATRIX",
    engine="registry",
    scope="README.md vs the live registries",
    summary=(
        "the README's generated tables (function x backend matrix, "
        "optimizer registry, lint rules) match the live registries "
        "(`tools/gen_matrix.py --check` as a registered rule)"
    ),
    provenance=(
        "PR 3/5: a hand-maintained coverage matrix goes stale the moment a "
        "registration lands; the tables are generated from the live "
        "plug-in points and drift fails the gate"
    ),
    rooted=True,
)
def check_matrix(ctx: LintContext) -> list[Violation]:
    from tools import gen_matrix

    current = gen_matrix.README.read_text()
    if current != gen_matrix.render_all(current):
        return [
            Violation(
                "MATRIX",
                "README.md",
                1,
                "generated tables are stale — run "
                "`PYTHONPATH=src python tools/gen_matrix.py --write`",
            )
        ]
    return []
