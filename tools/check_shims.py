"""Gate: no internal caller uses the deprecated entry-point shims.

The legacy entry points — ``maximize``, ``batched_maximize``,
``BatchedEngine.maximize``, and the ``SelectionServer.submit(fn, budget,
...)`` form — are DeprecationWarning shims over the typed front door
(``SelectionSpec`` / ``solve()``, see docs/api.md).  They exist for users,
not for us: library code, benchmarks, examples and tools must run on the
spec API, otherwise the shims never become deletable and the deprecation
drifts into permanence.

This script AST-scans those trees and fails on:

- any call named ``maximize`` or ``batched_maximize`` (bare, attribute, or
  method — catches ``engine.maximize(...)`` too);
- any ``*.submit(...)`` call in the legacy shape: two or more positional
  arguments, or serving keywords (``budget`` / ``optimizer`` /
  ``stopIfZeroGain`` / ``stopIfNegativeGain`` / ``screen_k``) — a
  single-argument ``submit(spec)`` / executor ``submit(fn)`` is fine.

Tests are deliberately NOT scanned: the shim regression tests call the
legacy forms on purpose.  Run via ``make shims-check`` (part of
``make verify``).
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_TREES = ("src/repro", "benchmarks", "examples", "tools")

LEGACY_NAMES = {"maximize", "batched_maximize"}
LEGACY_SUBMIT_KWARGS = {
    "budget",
    "optimizer",
    "stopIfZeroGain",
    "stopIfNegativeGain",
    "screen_k",
}


def _call_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _violations(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []
    rel = path.relative_to(ROOT)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in LEGACY_NAMES:
            out.append(
                f"{rel}:{node.lineno}: call to deprecated shim {name!r} — "
                "route through solve(SelectionSpec(...)) / BatchedEngine.run"
            )
        elif name == "submit" and isinstance(node.func, ast.Attribute):
            kwargs = {k.arg for k in node.keywords if k.arg}
            if len(node.args) >= 2 or kwargs & LEGACY_SUBMIT_KWARGS:
                out.append(
                    f"{rel}:{node.lineno}: legacy submit(fn, budget, ...) "
                    "form — submit a SelectionSpec instead"
                )
    return out


def main() -> int:
    failures: list[str] = []
    n_files = 0
    for tree in SCAN_TREES:
        for path in sorted((ROOT / tree).rglob("*.py")):
            n_files += 1
            failures.extend(_violations(path))
    print(f"shims-check: scanned {n_files} files under {', '.join(SCAN_TREES)}")
    if failures:
        for f in failures:
            print(f"FAIL {f}", file=sys.stderr)
        return 1
    print("no internal caller uses the deprecated entry points")
    return 0


if __name__ == "__main__":
    sys.exit(main())
