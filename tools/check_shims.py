"""Gate: no internal caller uses the deprecated entry-point shims.

Thin alias over the SHIMS lint rule (``tools/lint/ast_rules.py``) so the
historical ``make shims-check`` entry point keeps working — the scan
logic, output format, and exit-code contract now live in the lint driver
(``python -m tools.lint``, see docs/linting.md).  Tests are deliberately
NOT scanned: the shim regression tests call the legacy forms on purpose.
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:  # script runs with sys.path[0] = tools/
    sys.path.insert(0, str(ROOT))


def main() -> int:
    from tools.lint.__main__ import main as lint_main

    rc = lint_main(["--rules", "SHIMS"])
    if rc == 0:
        print("no internal caller uses the deprecated entry points")
    return rc


if __name__ == "__main__":
    sys.exit(main())
