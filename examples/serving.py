"""Serving a mixed selection workload — the three-family request wave.

Builds FacilityLocation, GraphCut and FeatureBased ``SelectionSpec``
requests with heterogeneous ground-set sizes and budgets and submits them
to a :class:`SelectionServer`, which coalesces them into padded
per-(family, n-bucket) waves, answers each wave with ONE batched-engine
dispatch, and demultiplexes the responses.  Every selection is verified
bit-identical to solving the same spec sequentially — the serving contract.

    PYTHONPATH=src python examples/serving.py

Add a 2-D device mesh to shard the waves (batch x data axes):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serving.py --mesh 2x2

Add ``--async-serve`` to route the same specs through the
:class:`AsyncSelectionServer` futures front end, where each (family,
n-bucket) group flushes on its own depth / timer / deadline trigger
instead of a manual flush.
"""
import argparse

import numpy as np

from repro.core import (
    FacilityLocation,
    FeatureBased,
    GraphCut,
    SelectionSpec,
    create_kernel,
    solve,
)
from repro.launch.serve import SelectionServer

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", default=None, help="BATCHxDATA grid, e.g. 2x2")
ap.add_argument(
    "--async-serve",
    action="store_true",
    help="submit through AsyncSelectionServer futures instead of flush()",
)
args = ap.parse_args()

rng = np.random.default_rng(0)


def embeddings(n):
    return rng.normal(size=(n, 16)).astype(np.float32)


# a mixed workload: 2 coverage queries, 2 representation+diversity queries,
# 2 feature-coverage queries — different ground-set sizes and budgets
specs = []
for n, budget in ((40, 6), (64, 8)):
    S = np.asarray(create_kernel(embeddings(n), metric="euclidean"))
    specs.append(SelectionSpec(FacilityLocation.from_kernel(S), budget))
for n, budget in ((40, 5), (48, 7)):
    S = np.asarray(create_kernel(embeddings(n), metric="euclidean"))
    specs.append(SelectionSpec(GraphCut.from_kernel(S, lam=0.3), budget))
for n, budget in ((40, 6), (56, 4)):
    feats = rng.uniform(0, 1, size=(n, 24)).astype(np.float32)
    specs.append(
        SelectionSpec(FeatureBased.from_features(feats, concave="sqrt"), budget)
    )

mesh = None
if args.mesh:
    import jax

    b, d = (int(v) for v in args.mesh.lower().split("x"))
    mesh = jax.make_mesh((b, d), ("batch", "data"))

server = SelectionServer(mesh=mesh)
if args.async_serve:
    from repro.launch.async_serve import AsyncSelectionServer

    # max_pending=2 depth-flushes a group as soon as two requests share a
    # (family, n-bucket) wave shape; singleton groups fall back to the timer
    with AsyncSelectionServer(server, max_pending=2) as front:
        futures = [front.submit(s) for s in specs]
        responses = [f.result(timeout=600) for f in futures]
else:
    responses = server.select(specs)

print(f"{len(specs)} requests -> {server.stats.waves} waves\n")
for spec, resp in zip(specs, responses):
    ids = [i for i, _ in resp.selection]
    print(
        f"{type(spec.fn).__name__:>16s} n={spec.fn.n:3d} k={spec.budget}  "
        f"wave(B={resp.wave_size}, n_bucket={resp.n_bucket}, "
        f"backend={resp.backend})  -> {ids}"
    )
    # the serving contract: identical to solving the spec sequentially
    assert resp.selection == solve(spec).as_list(), "serving must be exact"

print(f"\nall selections bit-identical to sequential solve(spec)")
print(f"server stats: {server.stats.summary()}")
