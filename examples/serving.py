"""Serving a mixed selection workload — the three-family request wave.

Submits FacilityLocation, GraphCut and FeatureBased selection requests with
heterogeneous ground-set sizes and budgets to a :class:`SelectionServer`,
which coalesces them into padded per-(family, n-bucket) waves, answers each
wave with ONE batched-engine dispatch, and demultiplexes the responses.
Every selection is verified bit-identical to a direct ``maximize`` call.

    PYTHONPATH=src python examples/serving.py

Add a 2-D device mesh to shard the waves (batch x data axes):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serving.py --mesh 2x2
"""
import argparse

import numpy as np

from repro.core import (
    FacilityLocation,
    FeatureBased,
    GraphCut,
    create_kernel,
    maximize,
)
from repro.launch.serve import SelectionServer

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", default=None, help="BATCHxDATA grid, e.g. 2x2")
args = ap.parse_args()

rng = np.random.default_rng(0)


def embeddings(n):
    return rng.normal(size=(n, 16)).astype(np.float32)


# a mixed workload: 2 coverage queries, 2 representation+diversity queries,
# 2 feature-coverage queries — different ground-set sizes and budgets
requests = []
for n, budget in ((40, 6), (64, 8)):
    S = np.asarray(create_kernel(embeddings(n), metric="euclidean"))
    requests.append((FacilityLocation.from_kernel(S), budget))
for n, budget in ((40, 5), (48, 7)):
    S = np.asarray(create_kernel(embeddings(n), metric="euclidean"))
    requests.append((GraphCut.from_kernel(S, lam=0.3), budget))
for n, budget in ((40, 6), (56, 4)):
    feats = rng.uniform(0, 1, size=(n, 24)).astype(np.float32)
    requests.append((FeatureBased.from_features(feats, concave="sqrt"), budget))

mesh = None
if args.mesh:
    import jax

    b, d = (int(v) for v in args.mesh.lower().split("x"))
    mesh = jax.make_mesh((b, d), ("batch", "data"))

server = SelectionServer(mesh=mesh)
responses = server.select(requests)

print(f"{len(requests)} requests -> {server.stats.waves} waves\n")
for (fn, budget), resp in zip(requests, responses):
    ids = [i for i, _ in resp.selection]
    print(
        f"{type(fn).__name__:>16s} n={fn.n:3d} k={budget}  "
        f"wave(B={resp.wave_size}, n_bucket={resp.n_bucket}, "
        f"backend={resp.backend})  -> {ids}"
    )
    # the serving contract: identical to a direct single maximize call
    assert resp.selection == maximize(fn, budget), "serving must be exact"

print(f"\nall selections bit-identical to direct maximize calls")
print(f"server stats: {server.stats.summary()}")
