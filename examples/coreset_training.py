"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps with per-round submodular coreset selection (the paper's
"efficient training" application), checkpointing included.

Compares the final loss against a no-selection baseline on the same step
budget: the coreset run sees a mode-balanced diet from the skewed stream.

    PYTHONPATH=src python examples/coreset_training.py [--steps 200]
"""
import argparse
import dataclasses
import shutil
import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config  # noqa: E402
from repro.launch.train import run  # noqa: E402


def hundred_m_config():
    # ~100M params: 12 layers, d_model 768, GQA 12/4 heads, tied embeddings
    base = get_config("qwen3-0.6b")
    return dataclasses.replace(
        base,
        name="qwen3-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab=32768,
        param_dtype="float32",
        compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_coreset_ckpt")
    a = ap.parse_args()

    from repro.configs.base import register

    register(hundred_m_config())
    shutil.rmtree(a.ckpt_dir, ignore_errors=True)

    print("== coreset run (FacilityLocation selection every 10 batches) ==")
    sel_losses = run(
        "qwen3-100m",
        steps=a.steps,
        batch=a.batch,
        seq=a.seq,
        select_every=10,
        ckpt_dir=a.ckpt_dir,
        ckpt_every=max(a.steps // 4, 1),
        reduced=False,
        log_every=20,
    )

    print("== baseline run (stream order, no selection) ==")
    base_losses = run(
        "qwen3-100m",
        steps=a.steps,
        batch=a.batch,
        seq=a.seq,
        select_every=0,
        ckpt_dir=None,
        reduced=False,
        log_every=20,
    )

    k = max(a.steps // 10, 1)
    sel_tail = sum(sel_losses[-k:]) / k
    base_tail = sum(base_losses[-k:]) / k
    print(f"\nfinal-loss (mean of last {k}): coreset {sel_tail:.4f}  "
          f"baseline {base_tail:.4f}")
    print("coreset training", "WINS" if sel_tail <= base_tail else "trails",
          "on this stream")


if __name__ == "__main__":
    main()
