"""Targeted learning (paper §1, Figs. 7/10): augment training data with
unlabeled-pool samples that match a *target* distribution using FLQMI —
the query-only-kernel MI measure that needs just a (|Q| x |V|) kernel.

Scenario: the model underperforms on two rare modes; we have a small query
set from those modes and a large unlabeled pool. FLQMI picks pool items
matching the target; we verify the precision of the retrieval and the
eta trade-off.

    PYTHONPATH=src python examples/targeted_selection.py
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import get_config  # noqa: E402
from repro.data.pipeline import SyntheticTokens, embed_examples  # noqa: E402
from repro.data.selection import SelectorConfig, SubmodularSelector  # noqa: E402
from repro.models.model import init_params  # noqa: E402


def main():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, seq_len=64, n_modes=8, seed=0)

    # unlabeled pool: 128 examples across all 8 modes
    pool_idx = list(range(128))
    pool_emb = embed_examples(cfg, params, data.batch(pool_idx))

    # target: rare modes 2 and 5 (say the model underperforms there);
    # queries are held-out examples of those modes (disjoint index range)
    rare = {2, 5}
    q_idx = [i for i in range(1000, 1100) if data.mode_of(i) in rare][:6]
    q_emb = embed_examples(cfg, params, data.batch(q_idx))

    budget = 16
    for eta in (0.0, 1.0, 4.0):
        sel = SubmodularSelector(
            cfg,
            SelectorConfig(objective="targeted", budget=budget, eta=eta,
                           use_pallas_kernel=False),
        )
        chosen = sel.select(pool_emb, query_emb=q_emb)
        hits = sum(1 for i in chosen if data.mode_of(pool_idx[i]) in rare)
        print(f"eta={eta:4.1f}: {hits}/{budget} selected items are target-mode "
              f"(pool base rate {2 / 8:.0%})")

    # distributed variant of the same selection on a (1,1) mesh — the exact
    # program the multi-pod dry-run lowers at 512 devices
    from repro.core import create_kernel, FLQMI
    from repro.core.optimizers.distributed import distributed_flqmi_greedy
    from repro.launch.mesh import make_test_mesh

    S_qv = create_kernel(q_emb, pool_emb, metric="euclidean")
    fn = FLQMI.build(S_qv, eta=1.0)
    mesh = make_test_mesh((1, 1))
    order, gains = distributed_flqmi_greedy(
        S_qv, np.asarray(fn.modular), budget, mesh
    )
    hits = sum(1 for i in np.asarray(order) if data.mode_of(int(i)) in rare)
    print(f"distributed FLQMI: {hits}/{budget} target-mode "
          f"(matches serial: {list(np.asarray(order)[:5])}...)")


if __name__ == "__main__":
    main()
