"""Quickstart — the typed front door (paper §7, redesigned).

One request object, ``SelectionSpec``, travels unchanged through every
execution route; ``solve()`` is the single entry point.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FacilityLocation, SelectionSpec, create_kernel, solve

# 1. some data (rows = items to select from)
rng = np.random.default_rng(0)
ground_data = rng.normal(size=(43, 16)).astype(np.float32)

# 2. instantiate the function object (dense kernel built internally)...
kernel = create_kernel(ground_data, metric="euclidean", mode="dense")
obj_fl = FacilityLocation.from_kernel(kernel)

# 3. ...build a typed request and solve it — validation (optimizer name,
#    hyperparameters, stop rules) happens at SelectionSpec construction
result = solve(SelectionSpec(obj_fl, budget=10, optimizer="NaiveGreedy"))
print("selected (index, gain):")
for idx, gain in result.as_list():
    print(f"  {idx:3d}  {gain:8.4f}")

# the other optimizers, same decoupled function/optimizer paradigm —
# hyperparameters ride the spec (misspelled ones raise at construction)
for opt in ("LazyGreedy", "StochasticGreedy", "LazierThanLazyGreedy"):
    sel = solve(SelectionSpec(obj_fl, 10, opt)).as_list()
    print(f"{opt:22s} -> {[i for i, _ in sel]}")

# B requests = one vmap-ed wave: pass a list of specs
specs = [SelectionSpec(obj_fl, b, "LazyGreedy", screen_k=4) for b in (4, 6, 8)]
for spec, res in zip(specs, solve(specs, mode="batched")):
    print(f"batched budget={spec.budget}   -> {[i for i, _ in res.as_list()]}")

# sparse kernel mode (top-k neighbours), paper §8
sparse = create_kernel(ground_data, metric="euclidean", mode="sparse", num_neighbors=8)
obj_sparse = FacilityLocation.from_kernel(sparse)
sel = solve(SelectionSpec(obj_sparse, 10)).as_list()
print("sparse mode          ->", [i for i, _ in sel])
