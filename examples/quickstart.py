"""Quickstart — the submodlib-style two-step API (paper §7).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import FacilityLocation, create_kernel, maximize

# 1. some data (rows = items to select from)
rng = np.random.default_rng(0)
ground_data = rng.normal(size=(43, 16)).astype(np.float32)

# 2. instantiate the function object (dense kernel built internally)...
kernel = create_kernel(ground_data, metric="euclidean", mode="dense")
obj_fl = FacilityLocation.from_kernel(kernel)

# 3. ...and call maximize on it — exactly submodlib's usage pattern
greedy_list = maximize(obj_fl, budget=10, optimizer="NaiveGreedy")
print("selected (index, gain):")
for idx, gain in greedy_list:
    print(f"  {idx:3d}  {gain:8.4f}")

# the other optimizers, same decoupled function/optimizer paradigm
for opt in ("LazyGreedy", "StochasticGreedy", "LazierThanLazyGreedy"):
    sel = maximize(obj_fl, budget=10, optimizer=opt)
    print(f"{opt:22s} -> {[i for i, _ in sel]}")

# sparse kernel mode (top-k neighbours), paper §8
sparse = create_kernel(ground_data, metric="euclidean", mode="sparse", num_neighbors=8)
obj_sparse = FacilityLocation.from_kernel(sparse)
print("sparse mode          ->", [i for i, _ in maximize(obj_sparse, budget=10)])
