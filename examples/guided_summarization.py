"""Guided summarization (paper §1, §3): query-focused, privacy-preserving,
and jointly-guided subset selection with the CG / CMI measures.

A document collection (sentence embeddings, synthetic) is summarized three
ways:
  update summary      : FLCG — cover what's NOT in the already-seen set P
  query-focused       : FLVMI — cover what matches the query set Q
  joint (CMI)         : FLCMI — match Q while avoiding P

    PYTHONPATH=src python examples/guided_summarization.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FLCG,
    FLCMI,
    FLVMI,
    create_kernel,
    naive_greedy,
)


def make_collection(seed=0):
    """5 topics x 12 'sentences' in embedding space."""
    rng = np.random.default_rng(seed)
    topics = rng.normal(scale=4.0, size=(5, 16)).astype(np.float32)
    sents = np.concatenate(
        [t + rng.normal(scale=0.5, size=(12, 16)).astype(np.float32) for t in topics]
    )
    labels = np.repeat(np.arange(5), 12)
    return sents, labels, topics


def topic_histogram(sel, labels):
    h = np.bincount(labels[sel], minlength=5)
    return " ".join(f"t{t}:{c}" for t, c in enumerate(h))


def main():
    sents, labels, topics = make_collection()
    rng = np.random.default_rng(1)

    # P = previously-shown summary: 4 sentences from topic 0 AND 4 from topic 1
    p_rows = np.concatenate(
        [np.flatnonzero(labels == 0)[:6], np.flatnonzero(labels == 1)[:6]]
    )
    p_emb = sents[p_rows] + rng.normal(scale=0.1, size=(12, 16)).astype(np.float32)
    # Q = user query: topic 3
    q_emb = (topics[3] + rng.normal(scale=0.3, size=(4, 16))).astype(np.float32)

    S = np.asarray(create_kernel(sents, metric="euclidean"))
    S_vq = np.asarray(create_kernel(sents, q_emb, metric="euclidean"))
    S_vp = np.asarray(create_kernel(sents, p_emb, metric="euclidean"))
    budget = 8

    # CG/CMI summaries use the natural stopping rule (gain <= 0 means
    # everything informative-given-the-guide is already covered)
    sel_cg = [i for i, _ in naive_greedy(
        FLCG.build(S, S_vp, nu=2.5), budget).as_list()]
    sel_mi = [i for i, _ in naive_greedy(
        FLVMI.build(S, S_vq, eta=1.0), budget, False, False).as_list()]
    sel_cmi = [i for i, _ in naive_greedy(
        FLCMI.build(S, S_vq, S_vp, eta=1.0, nu=2.5), budget
    ).as_list()]

    print("topic histogram of each guided summary (5 topics, 12 sents each):")
    print(f"  update summary  (FLCG nu=2.5, avoid topics 0,1): {topic_histogram(sel_cg, labels)}")
    print(f"  query-focused   (FLVMI, match Q=topic 3)   : {topic_histogram(sel_mi, labels)}")
    print(f"  joint           (FLCMI, Q=3 minus P=0,1)   : {topic_histogram(sel_cmi, labels)}")

    h_cg = np.bincount(labels[sel_cg], minlength=5)
    h_mi = np.bincount(labels[sel_mi], minlength=5)
    h_cmi = np.bincount(labels[sel_cmi], minlength=5)
    assert h_cg[:2].sum() <= 1, "update summary must avoid the private topics"
    assert h_mi[3] >= h_mi.max() - 1, "query-focused summary must favour topic 3"
    assert h_cmi[3] >= 4 and h_cmi[:2].sum() == 0, "CMI: topic 3, never 0/1"
    print("guided-summarization behaviour — CONFIRMED")


if __name__ == "__main__":
    main()
