"""Memoization identities + submodularity property tests for every function.

For each function we check, on random instances:
  1. gain identity      — fn.gains(state)[j] == f(A + j) - f(A) (oracle)
  2. state consistency  — incremental state after updates reproduces f(A)
  3. submodularity      — diminishing returns f(j|A) >= f(j|B) for A ⊆ B
     (hypothesis-driven; skipped for the knowingly non-submodular ones:
      DisparitySum is supermodular, DisparityMin not submodular)
  4. monotonicity where the paper claims it (FL, SC, PSC, FB for monotone g)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propcheck import given, settings, st

from repro.common import mask_from_indices
from repro.core import (
    ConcaveOverModular,
    DisparityMin,
    DisparityMinSum,
    DisparitySum,
    FacilityLocation,
    FeatureBased,
    GraphCut,
    LogDet,
    ProbabilisticSetCover,
    SetCover,
    clustered,
    create_kernel,
)

N = 14  # small enough for exhaustive-ish property checks


def _build(name, rng):
    x = rng.normal(size=(N, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="cosine"))
    D = np.sqrt(
        np.maximum(((x[:, None] - x[None, :]) ** 2).sum(-1), 0)
    ).astype(np.float32)
    if name == "fl":
        return FacilityLocation.from_kernel(S)
    if name == "fl_rect":  # represented set != ground set
        y = rng.normal(size=(9, 6)).astype(np.float32)
        return FacilityLocation.from_kernel(np.asarray(create_kernel(y, x)))
    if name == "gc":
        return GraphCut.from_kernel(S, lam=0.3)
    if name == "gc_nonmono":
        return GraphCut.from_kernel(S, lam=0.8)
    if name == "logdet":
        return LogDet.from_kernel(S + 0.5 * np.eye(N, dtype=np.float32))
    if name == "sc":
        return SetCover.from_cover(
            rng.integers(0, 2, size=(N, 10)).astype(np.float32),
            rng.uniform(0.5, 2.0, 10).astype(np.float32),
        )
    if name == "psc":
        return ProbabilisticSetCover.from_probs(
            rng.uniform(0, 0.9, size=(N, 10)).astype(np.float32)
        )
    if name == "fb_sqrt":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(N, 7)).astype(np.float32), concave="sqrt"
        )
    if name == "fb_log":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(N, 7)).astype(np.float32), concave="log"
        )
    if name == "fb_inverse":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(N, 7)).astype(np.float32), concave="inverse"
        )
    if name == "dsum":
        return DisparitySum.from_distance(D)
    if name == "dminsum":
        return DisparityMinSum.from_distance(D)
    if name == "com":
        q = rng.normal(size=(4, 6)).astype(np.float32)
        return ConcaveOverModular.build(np.asarray(create_kernel(x, q)), eta=0.7)
    if name == "clustered_fl":
        labels = rng.integers(0, 3, size=N)
        return clustered(FacilityLocation.from_kernel, S, labels)
    raise KeyError(name)


ALL = [
    "fl",
    "fl_rect",
    "gc",
    "gc_nonmono",
    "logdet",
    "sc",
    "psc",
    "fb_sqrt",
    "fb_log",
    "fb_inverse",
    "dsum",
    "dminsum",
    "com",
    "clustered_fl",
]
# dsum is supermodular; dminsum is submodular only away from the |A| <= 1
# boundary under the f(singleton) = 0 convention (checked separately below)
SUBMODULAR = [f for f in ALL if f not in ("dsum", "dminsum")]
MONOTONE = ["fl", "fl_rect", "sc", "psc", "fb_sqrt", "fb_log", "fb_inverse", "com",
            "clustered_fl"]


def _rand_subset(rng, n, max_size):
    size = int(rng.integers(0, max_size + 1))
    return list(rng.choice(n, size=size, replace=False)) if size else []


@pytest.mark.parametrize("name", ALL)
def test_gain_identity(name, rng):
    fn = _build(name, rng)
    state = fn.init_state()
    mask = np.zeros(N, bool)
    for step in range(6):
        gains = np.asarray(fn.gains(state))
        oracle_j = int(rng.choice(np.flatnonzero(~mask)))
        oracle = float(fn.marginal_gain(jnp.asarray(mask), oracle_j))
        np.testing.assert_allclose(gains[oracle_j], oracle, rtol=2e-4, atol=2e-4)
        # also gains_at must agree with gains
        sub = np.asarray(fn.gains_at(state, jnp.asarray([oracle_j])))
        np.testing.assert_allclose(sub[0], gains[oracle_j], rtol=1e-5, atol=1e-5)
        state = fn.update(state, jnp.asarray(oracle_j))
        mask[oracle_j] = True


@pytest.mark.parametrize("name", ALL)
def test_state_value_consistency(name, rng):
    fn = _build(name, rng)
    state = fn.init_state()
    mask = np.zeros(N, bool)
    total = 0.0
    order = rng.permutation(N)[:7]
    for j in order:
        total += float(fn.gains(state)[j])
        state = fn.update(state, jnp.asarray(int(j)))
        mask[j] = True
    oracle = float(fn.evaluate(jnp.asarray(mask)))
    base = float(fn.evaluate(jnp.zeros(N, bool)))
    np.testing.assert_allclose(total + base, oracle, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", SUBMODULAR)
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_diminishing_returns(name, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    fn = _build(name, rng)
    a = set(_rand_subset(rng, N, 5))
    extra = set(_rand_subset(rng, N, 5))
    b = a | extra
    j = int(rng.choice([i for i in range(N) if i not in b]))
    mask_a = mask_from_indices(jnp.asarray(sorted(a) or [-1], jnp.int32), N)
    mask_b = mask_from_indices(jnp.asarray(sorted(b) or [-1], jnp.int32), N)
    ga = float(fn.marginal_gain(mask_a, j))
    gb = float(fn.marginal_gain(mask_b, j))
    assert ga >= gb - 1e-3, f"diminishing returns violated: f(j|A)={ga} < f(j|B)={gb}"


@pytest.mark.parametrize("name", MONOTONE)
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_monotone(name, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    fn = _build(name, rng)
    a = _rand_subset(rng, N, 6)
    rem = [i for i in range(N) if i not in a]
    j = int(rng.choice(rem))
    mask = mask_from_indices(jnp.asarray(a or [-1], jnp.int32), N)
    assert float(fn.marginal_gain(mask, j)) >= -1e-4


def test_dminsum_not_submodular_finding():
    """REPRODUCTION FINDING (EXPERIMENTS.md §Paper-claims): under the paper's
    literal formula f(X) = sum_{i in X} min_{j in X, j != i} d_ij, the
    function is NOT submodular (the paper claims it is, citing [6]).  This
    test pins a concrete counterexample so the finding stays documented."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(6, 2))
    D = np.sqrt(((x[:, None] - x[None, :]) ** 2).sum(-1))

    def f(X):
        X = list(X)
        if len(X) < 2:
            return 0.0
        return sum(min(D[i, j] for j in X if j != i) for i in X)

    A, B, j = {0, 1}, {0, 1, 2}, 5
    ga = f(A | {j}) - f(A)
    gb = f(B | {j}) - f(B)
    assert ga < gb  # diminishing returns VIOLATED


def test_fl_evaluate_state_identity(rng):
    fn = _build("fl", rng)
    state = fn.init_state()
    for j in [3, 7, 1]:
        state = fn.update(state, jnp.asarray(j))
    mask = mask_from_indices(jnp.asarray([3, 7, 1]), N)
    np.testing.assert_allclose(
        float(fn.evaluate_state(state)), float(fn.evaluate(mask)), rtol=1e-5
    )


def test_graph_cut_lambda_tradeoff(rng):
    """Higher lambda must not increase the within-set similarity of the
    greedy selection (paper: lambda trades representation for diversity)."""
    from repro.core import naive_greedy

    x = rng.normal(size=(40, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="cosine"))

    def within_sim(lam):
        fn = GraphCut.from_kernel(S, lam=lam)
        r = naive_greedy(fn, 8, False, False)
        idx = [i for i, _ in r.as_list()]
        sub = S[np.ix_(idx, idx)]
        return (sub.sum() - np.trace(sub)) / (len(idx) * (len(idx) - 1))

    assert within_sim(0.9) <= within_sim(0.1) + 1e-5


def test_clustered_blocks_cross_cluster(rng):
    """Clustered FL must ignore cross-cluster similarity entirely."""
    x = rng.normal(size=(N, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="cosine"))
    labels = np.arange(N) % 3
    fn = clustered(FacilityLocation.from_kernel, S, labels)
    mask = np.zeros(N, bool)
    mask[0] = True  # cluster 0
    # adding an element of another cluster contributes only its own cluster
    g = float(fn.marginal_gain(jnp.asarray(mask), 1))  # cluster 1
    fn_single = clustered(FacilityLocation.from_kernel, S, labels)
    g_alone = float(fn_single.marginal_gain(jnp.zeros(N, bool), 1))
    np.testing.assert_allclose(g, g_alone, rtol=1e-5)
