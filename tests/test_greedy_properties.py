"""Hypothesis property tests on system-level invariants of the greedy
optimizers (beyond the per-function identities in test_functions.py):

- greedy gain sequence is non-increasing for submodular functions
- the greedy prefix property: each prefix of the greedy order is itself the
  greedy solution for the smaller budget
- stochastic greedy expectation quality over seeds
- knapsack/cover feasibility under random costs
"""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propcheck import given, settings, st

from repro.common import mask_from_indices
from repro.core import (
    FacilityLocation,
    SetCover,
    cover_greedy,
    create_kernel,
    knapsack_greedy,
    naive_greedy,
    stochastic_greedy,
)


def _fl(rng, n=24):
    x = rng.normal(size=(n, 5)).astype(np.float32)
    return FacilityLocation.from_kernel(
        np.asarray(create_kernel(x, metric="euclidean"))
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), budget=st.integers(2, 10))
def test_greedy_gains_nonincreasing(seed, budget):
    rng = np.random.default_rng(seed)
    fn = _fl(rng)
    res = naive_greedy(fn, budget, False, False)
    gains = np.asarray(res.gains)
    assert (np.diff(gains) <= 1e-5).all(), gains


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_greedy_prefix_property(seed):
    rng = np.random.default_rng(seed)
    fn = _fl(rng)
    full = [i for i, _ in naive_greedy(fn, 8, False, False).as_list()]
    for b in (2, 4, 6):
        pre = [i for i, _ in naive_greedy(fn, b, False, False).as_list()]
        assert pre == full[:b]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_stochastic_quality_over_seeds(seed):
    rng = np.random.default_rng(0)
    fn = _fl(rng, n=48)
    ref = float(naive_greedy(fn, 8).value)
    st_val = float(stochastic_greedy(fn, 8, jax.random.PRNGKey(seed), 0.05).value)
    assert st_val >= 0.85 * ref  # per-seed floor (expectation is 1-1/e-eps)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), budget=st.floats(1.0, 6.0))
def test_knapsack_feasibility(seed, budget):
    rng = np.random.default_rng(seed)
    fn = _fl(rng)
    costs = rng.uniform(0.3, 2.0, fn.n).astype(np.float32)
    res = knapsack_greedy(fn, budget=budget, max_steps=fn.n, costs=costs)
    chosen = [i for i, _ in res.as_list()]
    assert sum(costs[i] for i in chosen) <= budget + 1e-5
    assert len(set(chosen)) == len(chosen)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), frac=st.floats(0.2, 0.9))
def test_cover_reaches_requested_coverage(seed, frac):
    rng = np.random.default_rng(seed)
    cover = rng.integers(0, 2, size=(20, 14)).astype(np.float32)
    # ensure every concept coverable
    cover[0] = 1.0
    fn = SetCover.from_cover(cover)
    total = float(fn.evaluate(jnp.ones(20, bool)))
    res = cover_greedy(fn, coverage=frac * total, max_steps=20)
    assert float(res.value) >= frac * total - 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_selected_indices_unique_and_valid(seed):
    rng = np.random.default_rng(seed)
    fn = _fl(rng)
    res = naive_greedy(fn, 12, False, False)
    idx = [i for i, _ in res.as_list()]
    assert len(set(idx)) == len(idx)
    assert all(0 <= i < fn.n for i in idx)
    # value telescoping == oracle
    np.testing.assert_allclose(
        float(res.value),
        float(fn.evaluate(mask_from_indices(res.order, fn.n))),
        rtol=1e-4,
    )
