import numpy as np
import pytest

# the largest reduced-arch configs dominate test_archs wall time; they stay
# covered by `make test-all` but are cut from the tier-1 fast suite
_HEAVY_ARCHS = (
    "jamba-1.5-large-398b",
    "whisper-small",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
)


def pytest_collection_modifyitems(config, items):
    for item in items:
        if "test_archs.py" in item.nodeid and any(
            a in item.nodeid for a in _HEAVY_ARCHS
        ):
            item.add_marker(pytest.mark.slow)


@pytest.fixture
def rng():
    """Function-scoped on purpose: the old session-scoped generator made
    every test's data depend on how many draws earlier-collected tests had
    taken, so ADDING a test file silently shifted the data of every later
    alphabetical file (and data-sensitive checks flaked).  A fresh generator
    per test keeps each test's data a pure function of its own draws."""
    return np.random.default_rng(0)


def make_points(rng, n, d=8):
    return rng.normal(size=(n, d)).astype(np.float32)


def make_dist(rng, n, d=8):
    x = make_points(rng, n, d)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    return np.sqrt(np.maximum(d2, 0)).astype(np.float32)
