import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_points(rng, n, d=8):
    return rng.normal(size=(n, d)).astype(np.float32)


def make_dist(rng, n, d=8):
    x = make_points(rng, n, d)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    return np.sqrt(np.maximum(d2, 0)).astype(np.float32)
