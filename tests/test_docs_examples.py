"""Documentation and examples can't rot: run them.

- tools/check_docs.py executes every ```python block in README.md and
  docs/*.md (the `make docs-check` gate) — run here so the fast tier fails
  when a documented API drifts.
- every examples/*.py runs end-to-end as a subprocess smoke check
  (examples assert their own correctness claims internally).
"""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

_ENV = {
    "PYTHONPATH": "src",
    "PATH": "/usr/bin:/bin:/usr/local/bin",
    "JAX_PLATFORMS": "cpu",
}


def _run(cmd, timeout):
    return subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout, env=_ENV, cwd=ROOT
    )


def test_docs_check():
    """`make docs-check` equivalent: all documented code blocks execute."""
    r = _run([sys.executable, "tools/check_docs.py"], timeout=500)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 failed" in r.stdout, r.stdout


_EXAMPLES = sorted(p.name for p in (ROOT / "examples").glob("*.py"))


def test_examples_are_all_covered():
    """Every example file has a smoke check below (fast or slow tier)."""
    assert set(_EXAMPLES) == set(_FAST_EXAMPLES) | set(_SLOW_EXAMPLES)


_FAST_EXAMPLES = [
    "quickstart.py",
    "targeted_selection.py",
    "guided_summarization.py",
    "serving.py",
]
# coreset_training drives a real training loop (selection + baseline arms,
# ~25 min on this CPU) — covered by `make test-all`
_SLOW_EXAMPLES = ["coreset_training.py"]


@pytest.mark.parametrize("example", _FAST_EXAMPLES)
def test_example_runs(example):
    r = _run([sys.executable, f"examples/{example}"], timeout=300)
    assert r.returncode == 0, f"{example} failed:\n{r.stdout}\n{r.stderr}"


@pytest.mark.slow
@pytest.mark.parametrize("example", _SLOW_EXAMPLES)
def test_example_runs_slow(example):
    r = _run([sys.executable, f"examples/{example}"], timeout=3600)
    assert r.returncode == 0, f"{example} failed:\n{r.stdout}\n{r.stderr}"


def test_serving_example_on_mesh():
    """examples/serving.py --mesh 2x2 on 4 forced host devices."""
    env = dict(_ENV, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run(
        [sys.executable, "examples/serving.py", "--mesh", "2x2"],
        capture_output=True,
        text=True,
        timeout=300,
        env=env,
        cwd=ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "bit-identical" in r.stdout
