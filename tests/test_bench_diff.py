"""tools/bench_diff.py: snapshot matching + regression flagging semantics."""
import json
import sys

import pytest

sys.path.insert(0, "tools")
import bench_diff  # noqa: E402


def _snap(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text(json.dumps({"bench": "batched_bench", "rows": rows}))
    return str(path)


ROW = {"family": "fl", "B": 8, "n": 1024, "budget": 24,
       "section": "naive_vs_lazy", "lazy_ms": 100.0, "lazy_qps": 80.0,
       "lazy_speedup": 2.5, "lazy_evals": 17440}


def test_identical_snapshots_pass(tmp_path, capsys):
    old = _snap(tmp_path, "old.json", [ROW])
    assert bench_diff.diff(old, old) == 0
    assert "no throughput regressions" in capsys.readouterr().out


def test_regression_flagged_and_exit_1(tmp_path, capsys):
    old = _snap(tmp_path, "old.json", [ROW])
    worse = dict(ROW, lazy_ms=130.0)  # +30% wall clock > 20% threshold
    new = _snap(tmp_path, "new.json", [worse])
    assert bench_diff.diff(old, new) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "lazy_ms" in out


def test_qps_drop_is_a_regression_but_gain_is_not(tmp_path):
    old = _snap(tmp_path, "old.json", [ROW])
    assert bench_diff.diff(old, _snap(tmp_path, "a.json", [dict(ROW, lazy_qps=50.0)])) == 1
    assert bench_diff.diff(old, _snap(tmp_path, "b.json", [dict(ROW, lazy_qps=200.0)])) == 0


def test_threshold_is_respected(tmp_path):
    old = _snap(tmp_path, "old.json", [ROW])
    new = _snap(tmp_path, "new.json", [dict(ROW, lazy_ms=115.0)])  # +15%
    assert bench_diff.diff(old, new) == 0  # under the 20% default
    assert bench_diff.diff(old, new, threshold=0.1) == 1


def test_eval_count_drift_is_a_note_not_a_regression(tmp_path, capsys):
    """Eval counts are hardware-independent: a change means the ALGORITHM
    changed. That is the test suite's jurisdiction, so bench_diff only
    surfaces it as a note."""
    old = _snap(tmp_path, "old.json", [ROW])
    new = _snap(tmp_path, "new.json", [dict(ROW, lazy_evals=99)])
    assert bench_diff.diff(old, new) == 0
    assert "algorithmic change" in capsys.readouterr().out


def test_bytes_drift_is_a_note_not_a_regression(tmp_path, capsys):
    """Peak-memory footprints (``*_bytes``) are analytic, not measured, so
    they compare exactly — a drift is a memory-shape change worth a NOTE,
    never a machine-speed regression."""
    row = dict(ROW, peak_bytes=32_000_000)
    old = _snap(tmp_path, "old.json", [row])
    assert bench_diff.diff(old, _snap(tmp_path, "same.json", [dict(row)])) == 0
    new = _snap(tmp_path, "new.json", [dict(row, peak_bytes=64_000_000)])
    assert bench_diff.diff(old, new) == 0  # note, not exit 1
    out = capsys.readouterr().out
    assert "peak_bytes" in out and "REGRESSION" not in out


def test_rows_matched_by_identity_fields(tmp_path, capsys):
    """A row whose identifying fields changed is 'dropped + new', never
    silently compared against a different configuration."""
    old = _snap(tmp_path, "old.json", [ROW])
    new = _snap(tmp_path, "new.json", [dict(ROW, n=2048, lazy_ms=500.0)])
    assert bench_diff.diff(old, new) == 0
    out = capsys.readouterr().out
    assert "row dropped" in out and "new row" in out


def test_eval_ratio_is_skipped_entirely(tmp_path, capsys):
    """eval_ratio is derived from the note-only eval counts — it must not be
    flagged as a throughput regression for the same underlying change."""
    row = dict(ROW, eval_ratio=11.3)
    old = _snap(tmp_path, "old.json", [row])
    new = _snap(tmp_path, "new.json", [dict(row, eval_ratio=5.0)])
    assert bench_diff.diff(old, new) == 0
    assert "eval_ratio" not in capsys.readouterr().out
