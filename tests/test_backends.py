"""Gain-backend layer: the trace-time kernel-vs-XLA decision table
(``choose_backend`` / ``kernel_enabled``) and the ``use_kernel=None`` wiring
through the function families."""
import numpy as np
import pytest

from repro.core.optimizers.backends import (
    KERNEL_MAX_BUDGET_FRACTION,
    KERNEL_MIN_N,
    backend_name,
    choose_backend,
    kernel_enabled,
    partial_sweep,
)


def test_choose_backend_decision_table():
    big = 4 * KERNEL_MIN_N
    cases = [
        # (n, budget, device) -> expected
        (big, None, "cpu", "xla"),  # interpret mode never wins
        (big, None, "gpu", "xla"),  # Pallas sweeps are TPU-targeted
        (KERNEL_MIN_N - 1, None, "tpu", "xla"),  # launch overhead dominates
        (KERNEL_MIN_N, None, "tpu", "kernel"),  # threshold is inclusive
        (big, None, "tpu", "kernel"),
        (big, 16, "tpu", "kernel"),  # small budget: streamed sweep wins
        # long greedy loops favor the memoized XLA path for the stateless
        # O(n^2)-streamed kernels
        (big, int(KERNEL_MAX_BUDGET_FRACTION * big) + 1, "tpu", "xla"),
        (big, int(KERNEL_MAX_BUDGET_FRACTION * big), "tpu", "kernel"),
    ]
    for n, budget, device, want in cases:
        assert choose_backend(n, budget, device) == want, (n, budget, device)


def test_choose_backend_defaults_to_current_device():
    # this container is CPU-only, so the deviceless call must resolve "xla"
    assert choose_backend(10 * KERNEL_MIN_N) == "xla"


def test_kernel_enabled_manual_flag_wins():
    # explicit flags ignore n / budget / device entirely
    assert kernel_enabled(True, n=2) is True
    assert kernel_enabled(False, n=10 * KERNEL_MIN_N) is False
    # None defers to the table (CPU here -> False even at huge n)
    assert kernel_enabled(None, n=10 * KERNEL_MIN_N) is False


@pytest.mark.parametrize("family", ["fl", "gc", "fb", "sc", "psc"])
def test_use_kernel_none_resolves_via_heuristic(family):
    """use_kernel=None instances resolve their backend at trace time: on this
    CPU container the table picks XLA, and the selections are identical to
    an explicit use_kernel=False build."""
    from repro.core import (
        FacilityLocation,
        FeatureBased,
        GraphCut,
        ProbabilisticSetCover,
        SetCover,
        create_kernel,
        naive_greedy,
    )

    # local generator: keep the session rng fixture's sequence untouched
    rng = np.random.default_rng(3)
    n = 24
    x = rng.normal(size=(n, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    build = {
        "fl": lambda uk: FacilityLocation.from_kernel(S, use_kernel=uk),
        "gc": lambda uk: GraphCut.from_kernel(S, lam=0.3, use_kernel=uk),
        "fb": lambda uk: FeatureBased.from_features(
            np.abs(S[:, :8]), use_kernel=uk
        ),
        "sc": lambda uk: SetCover.from_cover(
            (S[:, :12] > 0.5).astype(np.float32), use_kernel=uk
        ),
        "psc": lambda uk: ProbabilisticSetCover.from_probs(
            0.9 * S[:, :12], use_kernel=uk
        ),
    }[family]
    auto, plain = build(None), build(False)
    assert backend_name(auto) == "xla"  # CPU: the table declines the kernel
    r_auto = naive_greedy(auto, 5)
    r_plain = naive_greedy(plain, 5)
    assert list(np.asarray(r_auto.order)) == list(np.asarray(r_plain.order))
    np.testing.assert_array_equal(
        np.asarray(r_auto.gains), np.asarray(r_plain.gains)
    )


def test_partial_sweep_falls_back_to_gains_at():
    """Backends without a partial_sweep method (and the XLA default) serve
    gathered subsets through the function's gains_at reference."""
    import jax.numpy as jnp

    from repro.core import LogDet, create_kernel

    rng = np.random.default_rng(3)
    n = 16
    x = rng.normal(size=(n, 5)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean")) + 0.5 * np.eye(
        n, dtype=np.float32
    )
    fn = LogDet.from_kernel(S, max_select=8)
    st = fn.init_state()
    idx = jnp.asarray([7, 0, 3], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(partial_sweep(fn, st, idx)),
        np.asarray(fn.gains_at(st, idx)),
    )
