"""Distributed batched selection serving (launch/serve.py + coalesce.py +
the sharded batched engine in optimizers/distributed.py).

The load-bearing contract: every serving layer — padding, wave coalescing,
budget bucketing, the vmap x shard_map engine — returns selections
BIT-IDENTICAL to a Python loop of single ``maximize`` calls (ids, gains,
and, where the sweep width is unchanged, ``n_evals``).  A subprocess test
pins this on a real 4-device (2x2 batch x data) host-platform mesh.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureBased,
    GraphCut,
    batched_maximize,
    create_kernel,
    maximize,
    naive_greedy,
)
from repro.launch.coalesce import (
    SelectionRequest,
    bucket_size,
    coalesce,
    next_pow2,
    pad_function,
)
from repro.launch.serve import SelectionServer, _random_requests


def _build(kind, rng, n):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    if kind == "fl":
        return FacilityLocation.from_kernel(S)
    if kind == "fl_kernel":
        return FacilityLocation.from_kernel(S, use_kernel=True)
    if kind == "gc":
        return GraphCut.from_kernel(S, lam=0.3)
    if kind == "fb":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(n, 12)).astype(np.float32), concave="sqrt"
        )
    raise KeyError(kind)


# -- coalescing ---------------------------------------------------------------


def test_bucket_size():
    assert next_pow2(1) == 1 and next_pow2(5) == 8 and next_pow2(64) == 64
    assert bucket_size(33) == 64
    assert bucket_size(3, multiple=4) == 4
    assert bucket_size(5, multiple=4) == 8  # pow2 already divisible
    assert bucket_size(2, multiple=3) == 3  # non-pow2 mesh axis


@pytest.mark.parametrize("kind", ["fl", "gc", "fb"])
def test_pad_function_preserves_selection_exactly(kind, rng):
    """Zero-padding the candidate axis + a valid mask is bit-invisible."""
    fn = _build(kind, rng, 23)
    padded = pad_function(fn, 32)
    assert padded.n == 32
    valid = np.zeros((1, 32), bool)
    valid[:, :23] = True
    got = batched_maximize([padded], 6, valid=jnp.asarray(valid), return_result=True)[0]
    ref = naive_greedy(fn, 6)
    assert list(np.asarray(ref.order)) == list(np.asarray(got.order))
    np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(got.gains))


def test_coalesce_groups_and_pads(rng):
    """Mixed families/sizes coalesce into per-(family, shape) waves; the
    batch pads carry budget 0 and demux drops them."""
    reqs = [
        SelectionRequest(rid="a", fn=_build("fl", rng, 24), budget=4),
        SelectionRequest(rid="b", fn=_build("fl", rng, 24), budget=7),
        SelectionRequest(rid="c", fn=_build("gc", rng, 24), budget=3),
        SelectionRequest(rid="d", fn=_build("fl", rng, 40), budget=4),
    ]
    waves = coalesce(reqs, n_multiple=4, b_multiple=4)
    by_rids = {tuple(sorted(r.rid for r in w.requests)): w for w in waves}
    assert set(by_rids) == {("a", "b"), ("c",), ("d",)}

    w_ab = by_rids[("a", "b")]
    assert w_ab.n_bucket == 32 and w_ab.batch_size == 4
    assert w_ab.budgets == [4, 7, 0, 0]  # two batch pads, budget 0
    assert w_ab.max_budget == 8  # pow2 bucket of 7
    assert w_ab.n_padded_slots == 2
    assert w_ab.valid.shape == (4, 32) and w_ab.valid[:, :24].all()
    assert not w_ab.valid[:, 24:].any()
    assert by_rids[("d",)].n_bucket == 64

    demuxed = w_ab.demux(["r0", "r1", "r2", "r3"])
    assert demuxed == {"a": "r0", "b": "r1"}


def test_coalesce_splits_at_max_wave(rng):
    fn = _build("fl", rng, 16)
    reqs = [SelectionRequest(rid=i, fn=fn, budget=3) for i in range(5)]
    waves = coalesce(reqs, max_wave=2)
    assert sorted(len(w.requests) for w in waves) == [1, 2, 2]


def test_coalesce_rejects_unknown_family(rng):
    from repro.core import LogDet

    S = np.asarray(create_kernel(rng.normal(size=(8, 4)).astype(np.float32)))
    fn = LogDet.from_kernel(S + 0.5 * np.eye(8, dtype=np.float32))
    with pytest.raises(ValueError, match="padder"):
        coalesce([SelectionRequest(rid=0, fn=fn, budget=2)], n_multiple=16)


# -- the server, single device ------------------------------------------------


def test_server_bit_identical_to_maximize_loop(rng):
    """A mixed FL/GC/FB workload with heterogeneous n and budgets: every
    served selection equals its single `maximize` call, ids AND gains."""
    server = SelectionServer()
    requests = _random_requests(12, seed=3)
    responses = server.select(requests)
    assert len(responses) == len(requests)
    for (fn, budget), resp in zip(requests, responses):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]
    s = server.stats.summary()
    assert s["requests"] == 12 and s["waves"] >= 3 and s["qps"] > 0


def test_server_coalesces_same_shape_requests(rng):
    """Same-family same-bucket requests ride one wave (the serving win)."""
    server = SelectionServer(max_wave=8)
    fns = [_build("fl", rng, 24) for _ in range(6)]
    responses = server.select([(f, 4) for f in fns])
    assert server.stats.waves == 1
    assert all(r.wave_size == 6 for r in responses)
    for f, r in zip(fns, responses):
        assert r.selection == maximize(f, 4)


def test_server_lazy_greedy_single_device(rng):
    server = SelectionServer()
    fn = _build("fl", rng, 24)
    rid = server.submit(fn, 5, optimizer="LazyGreedy")
    out = server.flush()
    assert out[rid].selection == maximize(fn, 5, optimizer="LazyGreedy")


def test_server_screen_k_reaches_engine(rng):
    """A non-default screen_k must be honored (n_evals proves it ran).
    n=32 is already at its bucket, so even n_evals compares exactly."""
    server = SelectionServer()
    fn = _build("fl", rng, 32)
    rid = server.submit(fn, 5, optimizer="LazyGreedy", screen_k=3)
    out = server.flush()
    ref = maximize(fn, 5, optimizer="LazyGreedy", screen_k=3, return_result=True)
    assert out[rid].selection == [
        (int(i), float(g)) for i, g in zip(ref.order, ref.gains) if i >= 0
    ]
    assert int(out[rid].result.n_evals) == int(ref.n_evals)


def test_server_rejects_unknown_submit_options(rng):
    server = SelectionServer()
    with pytest.raises(TypeError, match="unknown option"):
        server.submit(_build("fl", rng, 16), 3, stopIfZeroGains=False)  # typo


def test_server_never_drops_submitted_requests(rng):
    """select() must not swallow responses to requests enqueued earlier via
    submit(): they ride the same flush and surface on the next flush()."""
    server = SelectionServer()
    fn_a, fn_b = _build("fl", rng, 16), _build("fl", rng, 24)
    rid_a = server.submit(fn_a, 3)
    resp_b = server.select([(fn_b, 4)])
    assert resp_b[0].selection == maximize(fn_b, 4)
    out = server.flush()  # nothing pending, but rid_a's answer is held here
    assert out[rid_a].selection == maximize(fn_a, 3)


def test_server_stop_flags_ride_the_wave_key(rng):
    """stopIfZeroGain/stopIfNegativeGain are part of the wave key and reach
    the engine: the same function served under different flags matches the
    corresponding single `maximize` calls (including the degenerate
    exhausted-budget tail when stopping is disabled)."""
    fn = _build("fl", rng, 8)
    server = SelectionServer()
    rid_stop = server.submit(fn, 8)
    rid_nostop = server.submit(fn, 8, stopIfZeroGain=False, stopIfNegativeGain=False)
    out = server.flush()
    assert server.stats.waves == 2  # different flags -> different waves
    assert out[rid_stop].selection == maximize(fn, 8)
    assert out[rid_nostop].selection == maximize(
        fn, 8, stopIfZeroGain=False, stopIfNegativeGain=False
    )


def test_server_rejects_lazy_on_mesh(rng):
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    server = SelectionServer(mesh=mesh)
    with pytest.raises(ValueError, match="NaiveGreedy"):
        server.submit(_build("fl", rng, 16), 3, optimizer="LazyGreedy")


# -- the sharded engine, in-process (1,1) mesh --------------------------------


@pytest.mark.parametrize("kind", ["fl", "fl_kernel", "gc", "fb"])
def test_sharded_engine_unit_mesh_bit_identical(kind, rng):
    """mesh=(1,1): the full shard_map+vmap program, collectives degenerate.
    Ids, gains, n_evals and value all equal the sequential loop."""
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    fns = [_build(kind, rng, 32) for _ in range(3)]
    budgets = [5, 3, 6]
    res = batched_maximize(fns, budgets, mesh=mesh, return_result=True)
    for fn, b, r in zip(fns, budgets, res):
        ref = naive_greedy(fn, b)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
        np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(r.gains))
        assert int(ref.n_evals) == int(r.n_evals)
        assert float(ref.value) == float(r.value)


def test_sharded_engine_rejects_bad_mesh_axes(rng):
    fns = [_build("fl", rng, 32) for _ in range(3)]
    with pytest.raises(ValueError, match="no axis"):
        batched_maximize(fns, 3, mesh=jax.make_mesh((1, 1), ("x", "data")))


def test_sharded_engine_rejects_gc_use_kernel(rng):
    """GraphCut(use_kernel=True) cannot keep the bit-identical contract on a
    mesh (Pallas stateless vs memoized sweep); it must refuse loudly."""
    fns = [_build("gc", rng, 32)]
    fns_k = [
        GraphCut.from_kernel(np.asarray(f.sim_ground), lam=0.3, use_kernel=True)
        for f in fns
    ]
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    with pytest.raises(ValueError, match="use_kernel"):
        batched_maximize(fns_k, 3, mesh=mesh)
    # single-device serving of the same instance is fine (and bit-identical)
    r = batched_maximize(fns_k, 3, return_result=True)[0]
    ref = naive_greedy(fns_k[0], 3)
    assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
    np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(r.gains))


def test_server_sharded_unit_mesh_bit_identical(rng):
    """The whole serving stack through the sharded engine on a (1,1) mesh."""
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    server = SelectionServer(mesh=mesh)
    requests = _random_requests(9, seed=5)
    responses = server.select(requests)
    for (fn, budget), resp in zip(requests, responses):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]


# -- the real thing: 4 host devices, 2x2 batch x data mesh --------------------

_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.core import (FacilityLocation, GraphCut, FeatureBased,
                            create_kernel, naive_greedy, batched_maximize,
                            maximize)
    from repro.launch.serve import SelectionServer, _random_requests

    rng = np.random.default_rng(0)

    def build(kind, n):
        x = rng.normal(size=(n, 8)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        if kind == "fl": return FacilityLocation.from_kernel(S)
        if kind == "gc": return GraphCut.from_kernel(S, lam=0.3)
        return FeatureBased.from_features(
            rng.uniform(0, 1, (n, 12)).astype(np.float32))

    mesh = jax.make_mesh((2, 2), ("batch", "data"))
    assert len(jax.devices()) == 4

    # engine-level: ids, gains, n_evals, value all bit-identical
    for kind in ["fl", "gc", "fb"]:
        fns = [build(kind, 32) for _ in range(4)]
        budgets = [6, 3, 5, 4]
        res = batched_maximize(fns, budgets, mesh=mesh, return_result=True)
        for fn, b, r in zip(fns, budgets, res):
            ref = naive_greedy(fn, b)
            assert list(np.asarray(ref.order)) == list(np.asarray(r.order)), kind
            assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains)), kind
            assert int(ref.n_evals) == int(r.n_evals), kind
            assert float(ref.value) == float(r.value), kind

    # server-level: mixed workload, padding + batch pads on the mesh
    server = SelectionServer(mesh=mesh)
    requests = _random_requests(10, seed=1)
    for (fn, budget), resp in zip(requests, server.select(requests)):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]
    assert server.stats.requests == 10
    print("SHARDED_SERVE_OK")
    """
)


def test_sharded_serving_four_devices():
    """Real 4-device (2x2 batch x data) subprocess run: the sharded batched
    engine AND the server return bit-identical results to sequential
    single-device maximize — ids, gains, n_evals — with live collectives."""
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        # JAX_PLATFORMS=cpu skips backend probing, which otherwise stalls a
        # clean-env subprocess for minutes before the first compile
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SHARDED_SERVE_OK" in r.stdout, r.stdout + r.stderr
