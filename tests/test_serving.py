"""Distributed batched selection serving (launch/serve.py + coalesce.py +
the sharded batched engine in optimizers/distributed.py).

The load-bearing contract: every serving layer — padding, wave coalescing,
budget bucketing, the vmap x shard_map engine — returns selections
BIT-IDENTICAL to a Python loop of single ``maximize`` calls (ids, gains,
and, where the sweep width is unchanged, ``n_evals``).  A subprocess test
pins this on a real 4-device (2x2 batch x data) host-platform mesh.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureBased,
    GraphCut,
    SelectionSpec,
    batched_maximize,
    create_kernel,
    maximize,
    naive_greedy,
)
from repro.launch.coalesce import (
    SelectionRequest,
    bucket_size,
    coalesce,
    next_pow2,
    pad_function,
)
from repro.launch.serve import SelectionServer, _random_requests


def _build(kind, rng, n):
    from repro.core import (
        GCMI,
        FLQMI,
        FLVMI,
        DisparityMin,
        DisparitySum,
        LogDet,
        ProbabilisticSetCover,
        SetCover,
    )

    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    if kind == "fl":
        return FacilityLocation.from_kernel(S)
    if kind == "fl_kernel":
        return FacilityLocation.from_kernel(S, use_kernel=True)
    if kind == "gc":
        return GraphCut.from_kernel(S, lam=0.3)
    if kind == "fb":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(n, 12)).astype(np.float32), concave="sqrt"
        )
    if kind == "sc":
        return SetCover.from_cover(
            rng.integers(0, 2, size=(n, 12)).astype(np.float32),
            rng.uniform(0.5, 2.0, 12).astype(np.float32),
        )
    if kind == "sc_kernel":
        return SetCover.from_cover(
            rng.integers(0, 2, size=(n, 12)).astype(np.float32), use_kernel=True
        )
    if kind == "psc":
        return ProbabilisticSetCover.from_probs(
            rng.uniform(0, 0.9, size=(n, 12)).astype(np.float32)
        )
    if kind == "dsum":
        return DisparitySum.from_distance(1.0 - S)
    if kind == "dmin":
        return DisparityMin.from_distance(1.0 - S)
    if kind == "flqmi":
        q = rng.normal(size=(5, 8)).astype(np.float32)
        return FLQMI.build(np.asarray(create_kernel(q, x, metric="euclidean")))
    if kind == "flvmi":
        q = rng.normal(size=(5, 8)).astype(np.float32)
        return FLVMI.build(S, np.asarray(create_kernel(x, q, metric="euclidean")))
    if kind == "gcmi":
        q = rng.normal(size=(5, 8)).astype(np.float32)
        return GCMI.build(
            np.asarray(create_kernel(x, q, metric="euclidean")), lam=0.4
        )
    if kind == "logdet":
        return LogDet.from_kernel(
            S + 0.5 * np.eye(n, dtype=np.float32), max_select=10
        )
    raise KeyError(kind)


# the empty-set gain is 0 for the dispersion functions, so their requests run
# with stopping disabled (see core/functions/disparity.py)
_NOSTOP = {"dsum", "dmin"}


def _stop_args(kind):
    return (False, False) if kind in _NOSTOP else (True, True)


# -- coalescing ---------------------------------------------------------------


def test_bucket_size():
    assert next_pow2(1) == 1 and next_pow2(5) == 8 and next_pow2(64) == 64
    assert bucket_size(33) == 64
    assert bucket_size(3, multiple=4) == 4
    assert bucket_size(5, multiple=4) == 8  # pow2 already divisible
    assert bucket_size(2, multiple=3) == 3  # non-pow2 mesh axis


@pytest.mark.parametrize(
    "kind",
    ["fl", "gc", "fb", "sc", "psc", "dsum", "dmin", "flqmi", "flvmi", "gcmi",
     "logdet"],
)
def test_pad_function_preserves_selection_exactly(kind, rng):
    """Zero-padding the candidate axis + a valid mask is bit-invisible."""
    fn = _build(kind, rng, 23)
    stop_zero, stop_neg = _stop_args(kind)
    padded = pad_function(fn, 32)
    assert padded.n == 32
    valid = np.zeros((1, 32), bool)
    valid[:, :23] = True
    got = batched_maximize(
        [padded],
        6,
        valid=jnp.asarray(valid),
        return_result=True,
        stopIfZeroGain=stop_zero,
        stopIfNegativeGain=stop_neg,
    )[0]
    ref = naive_greedy(fn, 6, stop_zero, stop_neg)
    assert list(np.asarray(ref.order)) == list(np.asarray(got.order))
    np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(got.gains))


def test_coalesce_groups_and_pads(rng):
    """Mixed families/sizes coalesce into per-(family, shape) waves; the
    batch pads carry budget 0 and demux drops them."""
    reqs = [
        SelectionRequest(rid="a", spec=SelectionSpec(_build("fl", rng, 24), 4)),
        SelectionRequest(rid="b", spec=SelectionSpec(_build("fl", rng, 24), 7)),
        SelectionRequest(rid="c", spec=SelectionSpec(_build("gc", rng, 24), 3)),
        SelectionRequest(rid="d", spec=SelectionSpec(_build("fl", rng, 40), 4)),
    ]
    waves = coalesce(reqs, n_multiple=4, b_multiple=4)
    by_rids = {tuple(sorted(r.rid for r in w.requests)): w for w in waves}
    assert set(by_rids) == {("a", "b"), ("c",), ("d",)}

    w_ab = by_rids[("a", "b")]
    assert w_ab.n_bucket == 32 and w_ab.batch_size == 4
    assert w_ab.budgets == [4, 7, 0, 0]  # two batch pads, budget 0
    assert w_ab.max_budget == 8  # pow2 bucket of 7
    assert w_ab.n_padded_slots == 2
    assert w_ab.valid.shape == (4, 32) and w_ab.valid[:, :24].all()
    assert not w_ab.valid[:, 24:].any()
    assert by_rids[("d",)].n_bucket == 64

    demuxed = w_ab.demux(["r0", "r1", "r2", "r3"])
    assert demuxed == {"a": "r0", "b": "r1"}


def test_coalesce_splits_at_max_wave(rng):
    fn = _build("fl", rng, 16)
    reqs = [
        SelectionRequest(rid=i, spec=SelectionSpec(fn, 3)) for i in range(5)
    ]
    waves = coalesce(reqs, max_wave=2)
    assert sorted(len(w.requests) for w in waves) == [1, 2, 2]


def _unsupported_family(rng):
    """DisparityMinSum deliberately registers no padder/ShardRule: its gains
    reduce over ALL rows of the distance matrix, so zero row-padding would
    shift them by ulps (see core/functions/disparity.py)."""
    from repro.core import DisparityMinSum

    d = rng.uniform(0, 2, size=(8, 8)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    return DisparityMinSum.from_distance(d)


def test_coalesce_rejects_unknown_family(rng):
    fn = _unsupported_family(rng)
    with pytest.raises(NotImplementedError, match="register_padder"):
        coalesce(
            [SelectionRequest(rid=0, spec=SelectionSpec(fn, 2))], n_multiple=16
        )


def test_server_rejects_unknown_family_with_clear_error(rng):
    """An unsupported family submitted to the SelectionServer must surface a
    NotImplementedError naming register_padder — not an opaque shape error
    from deep inside the engine — AT SUBMIT TIME, and must not poison
    co-pending valid requests."""
    server = SelectionServer()
    fn_ok = _build("fl", rng, 16)
    rid_ok = server.submit(fn_ok, 3)
    with pytest.raises(NotImplementedError, match="register_padder"):
        server.submit(_unsupported_family(rng), 3)
    out = server.flush()  # the valid request is unaffected by the rejection
    assert out[rid_ok].selection == maximize(fn_ok, 3)


def test_shard_rule_error_names_register_shard_rule(rng):
    """The mesh path's unknown-family error must name register_shard_rule."""
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    fn = _unsupported_family(rng)
    with pytest.raises(NotImplementedError, match="register_shard_rule"):
        batched_maximize([fn], 2, mesh=mesh)


# -- the server, single device ------------------------------------------------


def test_server_bit_identical_to_maximize_loop(rng):
    """A mixed FL/GC/FB workload with heterogeneous n and budgets: every
    served selection equals its single `maximize` call, ids AND gains."""
    server = SelectionServer()
    requests = _random_requests(12, seed=3)
    responses = server.select(requests)
    assert len(responses) == len(requests)
    for (fn, budget), resp in zip(requests, responses):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]
    s = server.stats.summary()
    assert s["requests"] == 12 and s["waves"] >= 3 and s["qps"] > 0


def test_server_coalesces_same_shape_requests(rng):
    """Same-family same-bucket requests ride one wave (the serving win)."""
    server = SelectionServer(max_wave=8)
    fns = [_build("fl", rng, 24) for _ in range(6)]
    responses = server.select([(f, 4) for f in fns])
    assert server.stats.waves == 1
    assert all(r.wave_size == 6 for r in responses)
    for f, r in zip(fns, responses):
        assert r.selection == maximize(f, 4)


def test_server_lazy_greedy_single_device(rng):
    server = SelectionServer()
    fn = _build("fl", rng, 24)
    rid = server.submit(fn, 5, optimizer="LazyGreedy")
    out = server.flush()
    assert out[rid].selection == maximize(fn, 5, optimizer="LazyGreedy")


def test_server_screen_k_reaches_engine(rng):
    """A non-default screen_k must be honored (n_evals proves it ran)."""
    server = SelectionServer()
    fn = _build("fl", rng, 32)
    rid = server.submit(fn, 5, optimizer="LazyGreedy", screen_k=3)
    out = server.flush()
    ref = maximize(fn, 5, optimizer="LazyGreedy", screen_k=3, return_result=True)
    assert out[rid].selection == [
        (int(i), float(g)) for i, g in zip(ref.order, ref.gains) if i >= 0
    ]
    assert int(out[rid].result.n_evals) == int(ref.n_evals)


def test_server_rejects_unknown_submit_options(rng):
    server = SelectionServer()
    with pytest.raises(TypeError, match="unknown option"):
        server.submit(_build("fl", rng, 16), 3, stopIfZeroGains=False)  # typo


def test_server_never_drops_submitted_requests(rng):
    """select() must not swallow responses to requests enqueued earlier via
    submit(): they ride the same flush and surface on the next flush()."""
    server = SelectionServer()
    fn_a, fn_b = _build("fl", rng, 16), _build("fl", rng, 24)
    rid_a = server.submit(fn_a, 3)
    resp_b = server.select([(fn_b, 4)])
    assert resp_b[0].selection == maximize(fn_b, 4)
    out = server.flush()  # nothing pending, but rid_a's answer is held here
    assert out[rid_a].selection == maximize(fn_a, 3)


def test_server_stop_flags_ride_the_wave_key(rng):
    """stopIfZeroGain/stopIfNegativeGain are part of the wave key and reach
    the engine: the same function served under different flags matches the
    corresponding single `maximize` calls (including the degenerate
    exhausted-budget tail when stopping is disabled)."""
    fn = _build("fl", rng, 8)
    server = SelectionServer()
    rid_stop = server.submit(fn, 8)
    rid_nostop = server.submit(fn, 8, stopIfZeroGain=False, stopIfNegativeGain=False)
    out = server.flush()
    assert server.stats.waves == 2  # different flags -> different waves
    assert out[rid_stop].selection == maximize(fn, 8)
    assert out[rid_nostop].selection == maximize(
        fn, 8, stopIfZeroGain=False, stopIfNegativeGain=False
    )


def test_server_disparity_stop_default():
    """Regression for the Disparity* serving footgun: the empty-set gain is
    0, so the library-wide stopIfZeroGain=True default used to silently
    serve EMPTY selections for dsum/dmin requests.  submit() now defaults
    stopIfZeroGain=False for those families — and an explicit flag wins."""
    rng = np.random.default_rng(13)  # local: keep the session rng sequence
    server = SelectionServer()
    fns = {k: _build(k, rng, 24) for k in ("dsum", "dmin")}
    rids = {k: server.submit(f, 5) for k, f in fns.items()}
    explicit = server.submit(fns["dsum"], 5, stopIfZeroGain=True)
    out = server.flush()
    for k, f in fns.items():
        resp = out[rids[k]]
        assert resp.selection, k  # non-empty: the footgun is closed
        ref = maximize(f, 5, stopIfZeroGain=False)
        assert resp.selection == ref, k
    assert out[explicit].selection == []  # manual flag still wins


# -- the sharded engine, in-process (1,1) mesh --------------------------------


@pytest.mark.parametrize("kind", ["fl", "fl_kernel", "gc", "fb"])
def test_sharded_engine_unit_mesh_bit_identical(kind, rng):
    """mesh=(1,1): the full shard_map+vmap program, collectives degenerate.
    Ids, gains, n_evals and value all equal the sequential loop."""
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    fns = [_build(kind, rng, 32) for _ in range(3)]
    budgets = [5, 3, 6]
    res = batched_maximize(fns, budgets, mesh=mesh, return_result=True)
    for fn, b, r in zip(fns, budgets, res):
        ref = naive_greedy(fn, b)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
        np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(r.gains))
        assert int(ref.n_evals) == int(r.n_evals)
        assert float(ref.value) == float(r.value)


@pytest.mark.parametrize(
    "kind", ["sc", "sc_kernel", "psc", "dsum", "dmin", "flqmi", "flvmi",
             "gcmi", "logdet"]
)
def test_sharded_engine_unit_mesh_new_families(kind, rng):
    """The serving-breadth families (SetCover family, Disparity, MI
    combinators, LogDet) through the full shard_map+vmap program on a (1,1)
    mesh: ids, gains, n_evals and value all equal the sequential loop."""
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    stop_zero, stop_neg = _stop_args(kind)
    fns = [_build(kind, rng, 32) for _ in range(3)]
    budgets = [5, 3, 6]
    res = batched_maximize(
        fns,
        budgets,
        mesh=mesh,
        return_result=True,
        stopIfZeroGain=stop_zero,
        stopIfNegativeGain=stop_neg,
    )
    for fn, b, r in zip(fns, budgets, res):
        ref = naive_greedy(fn, b, stop_zero, stop_neg)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
        np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(r.gains))
        assert int(ref.n_evals) == int(r.n_evals)
        assert float(ref.value) == float(r.value)


@pytest.mark.parametrize(
    "kind", ["fl", "fl_kernel", "gc", "fb", "sc", "psc", "dsum", "dmin",
             "flqmi", "flvmi", "gcmi", "logdet"]
)
def test_sharded_engine_unit_mesh_lazy_bit_identical(kind):
    """LazyGreedy on a (1,1) mesh: the full bucketed sharded-lazy program
    (sorted-prefix merge + gathered partial sweeps + level conds, collectives
    degenerate) is bit-identical to sequential lazy_greedy — ids, gains,
    n_evals, value — for every servable family."""
    from repro.core import lazy_greedy

    rng = np.random.default_rng(13)  # local: keep the session rng sequence
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    stop_zero, stop_neg = _stop_args(kind)
    fns = [_build(kind, rng, 32) for _ in range(3)]
    budgets = [5, 3, 6]
    res = batched_maximize(
        fns,
        budgets,
        mesh=mesh,
        optimizer="LazyGreedy",
        return_result=True,
        screen_k=6,
        stopIfZeroGain=stop_zero,
        stopIfNegativeGain=stop_neg,
    )
    for fn, b, r in zip(fns, budgets, res):
        ref = lazy_greedy(fn, b, 6, stop_zero, stop_neg)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
        np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(r.gains))
        assert int(ref.n_evals) == int(r.n_evals)
        assert float(ref.value) == float(r.value)


def test_server_lazy_on_mesh_bit_identical():
    """optimizer="LazyGreedy" through the whole serving stack on a mesh
    (formerly the pinned NaiveGreedy-only error path).  Padded waves keep
    ids/gains bit-identical; the n=32 requests sit at their bucket, so their
    n_evals compare exactly too."""
    from repro.core import lazy_greedy

    rng = np.random.default_rng(13)  # local: keep the session rng sequence
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    server = SelectionServer(mesh=mesh)
    fns = [_build("fl", rng, 32) for _ in range(3)] + [_build("gc", rng, 24)]
    rids = [server.submit(fn, 5, optimizer="LazyGreedy") for fn in fns]
    out = server.flush()
    for fn, rid in zip(fns, rids):
        ref = maximize(fn, 5, optimizer="LazyGreedy", return_result=True)
        assert out[rid].selection == [
            (int(i), float(g)) for i, g in zip(ref.order, ref.gains) if i >= 0
        ]
        if fn.n == 32:
            assert int(out[rid].result.n_evals) == int(ref.n_evals)


def test_server_new_families_bit_identical(rng):
    """Mixed SC / PSC / FLQMI / GCMI / LogDet workload through the server:
    every served selection equals its single `maximize` call."""
    from repro.launch.serve import _random_requests as rr

    server = SelectionServer()
    requests = rr(10, seed=11, families=("sc", "psc", "flqmi", "gcmi", "logdet"))
    responses = server.select(requests)
    for (fn, budget), resp in zip(requests, responses):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]


def test_server_disparity_bit_identical(rng):
    """Disparity requests need stopIfZeroGain=False (empty-set gain is 0);
    with it they serve bit-identically, including coalesced same-shape
    waves."""
    server = SelectionServer()
    fns = [_build(k, rng, 24) for k in ("dsum", "dsum", "dmin")]
    rids = [
        server.submit(f, 5, stopIfZeroGain=False, stopIfNegativeGain=False)
        for f in fns
    ]
    out = server.flush()
    for f, rid in zip(fns, rids):
        ref = maximize(f, 5, stopIfZeroGain=False, stopIfNegativeGain=False)
        assert out[rid].selection == ref


def test_sharded_engine_rejects_disparity_use_kernel(rng):
    """Disparity*(use_kernel=True) keeps the GraphCut policy: the stateless
    Pallas sweep cannot be reconciled with the memoized shard rule
    bit-identically, so the mesh path must refuse loudly."""
    from repro.core import DisparityMin, DisparitySum

    d = np.asarray(_build("dsum", rng, 32).dist)
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    for cls in (DisparitySum, DisparityMin):
        fn = cls.from_distance(d, use_kernel=True)
        with pytest.raises(ValueError, match="use_kernel"):
            batched_maximize([fn], 3, mesh=mesh)
        # single-device serving of the same instance stays bit-identical
        r = batched_maximize(
            [fn], 3, return_result=True,
            stopIfZeroGain=False, stopIfNegativeGain=False,
        )[0]
        ref = naive_greedy(fn, 3, False, False)
        assert list(np.asarray(ref.order)) == list(np.asarray(r.order))


def test_sharded_engine_rejects_bad_mesh_axes(rng):
    fns = [_build("fl", rng, 32) for _ in range(3)]
    with pytest.raises(ValueError, match="no axis"):
        batched_maximize(fns, 3, mesh=jax.make_mesh((1, 1), ("x", "data")))


def test_sharded_engine_rejects_gc_use_kernel(rng):
    """GraphCut(use_kernel=True) cannot keep the bit-identical contract on a
    mesh (Pallas stateless vs memoized sweep); it must refuse loudly."""
    fns = [_build("gc", rng, 32)]
    fns_k = [
        GraphCut.from_kernel(np.asarray(f.sim_ground), lam=0.3, use_kernel=True)
        for f in fns
    ]
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    with pytest.raises(ValueError, match="use_kernel"):
        batched_maximize(fns_k, 3, mesh=mesh)
    # single-device serving of the same instance is fine (and bit-identical)
    r = batched_maximize(fns_k, 3, return_result=True)[0]
    ref = naive_greedy(fns_k[0], 3)
    assert list(np.asarray(ref.order)) == list(np.asarray(r.order))
    np.testing.assert_array_equal(np.asarray(ref.gains), np.asarray(r.gains))


def test_server_sharded_unit_mesh_bit_identical(rng):
    """The whole serving stack through the sharded engine on a (1,1) mesh."""
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    server = SelectionServer(mesh=mesh)
    requests = _random_requests(9, seed=5)
    responses = server.select(requests)
    for (fn, budget), resp in zip(requests, responses):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]


# -- the real thing: 4 host devices, 2x2 batch x data mesh --------------------

_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.core import (FacilityLocation, GraphCut, FeatureBased,
                            create_kernel, naive_greedy, lazy_greedy,
                            batched_maximize, maximize)
    from repro.launch.serve import SelectionServer, _random_requests

    rng = np.random.default_rng(0)

    def build(kind, n):
        x = rng.normal(size=(n, 8)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        if kind == "fl": return FacilityLocation.from_kernel(S)
        if kind == "gc": return GraphCut.from_kernel(S, lam=0.3)
        return FeatureBased.from_features(
            rng.uniform(0, 1, (n, 12)).astype(np.float32))

    mesh = jax.make_mesh((2, 2), ("batch", "data"))
    assert len(jax.devices()) == 4

    # engine-level: ids, gains, n_evals, value all bit-identical, for both
    # the naive partition sweep and the bucketed sharded-lazy engine
    budgets = [6, 3, 5, 4]
    for kind in ["fl", "gc", "fb"]:
        fns = [build(kind, 32) for _ in range(4)]
        res = batched_maximize(fns, budgets, mesh=mesh, return_result=True)
        for fn, b, r in zip(fns, budgets, res):
            ref = naive_greedy(fn, b)
            assert list(np.asarray(ref.order)) == list(np.asarray(r.order)), kind
            assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains)), kind
            assert int(ref.n_evals) == int(r.n_evals), kind
            assert float(ref.value) == float(r.value), kind
    for kind in ["fl", "gc"]:
        fns = [build(kind, 32) for _ in range(4)]
        res = batched_maximize(fns, budgets, mesh=mesh, optimizer="LazyGreedy",
                               return_result=True, screen_k=6)
        for fn, b, r in zip(fns, budgets, res):
            ref = lazy_greedy(fn, b, 6)
            assert list(np.asarray(ref.order)) == list(np.asarray(r.order)), kind
            assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains)), kind
            assert int(ref.n_evals) == int(r.n_evals), kind

    # server-level: mixed workload, padding + batch pads on the mesh
    server = SelectionServer(mesh=mesh)
    requests = _random_requests(10, seed=1)
    for (fn, budget), resp in zip(requests, server.select(requests)):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]
    assert server.stats.requests == 10

    # server-level LazyGreedy on the mesh (the former pinned error path)
    fns_lazy = [build("fl", 32) for _ in range(3)]
    rids = [server.submit(fn, b, optimizer="LazyGreedy")
            for fn, b in zip(fns_lazy, [4, 6, 5])]
    out = server.flush()
    for fn, b, rid in zip(fns_lazy, [4, 6, 5], rids):
        ref = maximize(fn, b, optimizer="LazyGreedy", return_result=True)
        assert out[rid].selection == [
            (int(i), float(g)) for i, g in zip(ref.order, ref.gains) if i >= 0]
        assert int(out[rid].result.n_evals) == int(ref.n_evals)
    print("SHARDED_SERVE_OK")
    """
)


def test_sharded_serving_four_devices():
    """Real 4-device (2x2 batch x data) subprocess run: the sharded batched
    engine AND the server return bit-identical results to sequential
    single-device maximize — ids, gains, n_evals — with live collectives."""
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        # JAX_PLATFORMS=cpu skips backend probing, which otherwise stalls a
        # clean-env subprocess for minutes before the first compile
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SHARDED_SERVE_OK" in r.stdout, r.stdout + r.stderr


_MULTIDEV_BREADTH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.core import (SetCover, ProbabilisticSetCover, DisparitySum,
                            DisparityMin, FLQMI, FLVMI, GCMI, LogDet,
                            create_kernel, naive_greedy, batched_maximize,
                            maximize)
    from repro.launch.serve import SelectionServer, _random_requests

    rng = np.random.default_rng(0)

    def build(kind, n=32):
        x = rng.normal(size=(n, 8)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        if kind == "sc":
            return SetCover.from_cover(
                rng.integers(0, 2, size=(n, 12)).astype(np.float32))
        if kind == "sc_kernel":
            return SetCover.from_cover(
                rng.integers(0, 2, size=(n, 12)).astype(np.float32),
                use_kernel=True)
        if kind == "psc":
            return ProbabilisticSetCover.from_probs(
                rng.uniform(0, 0.9, size=(n, 12)).astype(np.float32))
        if kind == "dsum": return DisparitySum.from_distance(1.0 - S)
        if kind == "dmin": return DisparityMin.from_distance(1.0 - S)
        if kind == "flqmi":
            q = rng.normal(size=(5, 8)).astype(np.float32)
            return FLQMI.build(np.asarray(create_kernel(q, x, "euclidean")))
        if kind == "flvmi":
            q = rng.normal(size=(5, 8)).astype(np.float32)
            return FLVMI.build(S, np.asarray(create_kernel(x, q, "euclidean")))
        if kind == "gcmi":
            q = rng.normal(size=(5, 8)).astype(np.float32)
            return GCMI.build(
                np.asarray(create_kernel(x, q, "euclidean")), lam=0.4)
        return LogDet.from_kernel(
            S + 0.5 * np.eye(n, dtype=np.float32), max_select=10)

    mesh = jax.make_mesh((2, 2), ("batch", "data"))
    assert len(jax.devices()) == 4
    budgets = [6, 3, 5, 4]

    for kind in ["sc", "sc_kernel", "psc", "flqmi", "flvmi", "gcmi", "logdet"]:
        fns = [build(kind) for _ in range(4)]
        res = batched_maximize(fns, budgets, mesh=mesh, return_result=True)
        for fn, b, r in zip(fns, budgets, res):
            ref = naive_greedy(fn, b)
            assert list(np.asarray(ref.order)) == list(np.asarray(r.order)), kind
            assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains)), kind
            assert int(ref.n_evals) == int(r.n_evals), kind
            assert float(ref.value) == float(r.value), kind

    for kind in ["dsum", "dmin"]:  # empty-set gain is 0: stopping disabled
        fns = [build(kind) for _ in range(4)]
        res = batched_maximize(fns, budgets, mesh=mesh, return_result=True,
                               stopIfZeroGain=False, stopIfNegativeGain=False)
        for fn, b, r in zip(fns, budgets, res):
            ref = naive_greedy(fn, b, False, False)
            assert list(np.asarray(ref.order)) == list(np.asarray(r.order)), kind
            assert np.array_equal(np.asarray(ref.gains), np.asarray(r.gains)), kind

    server = SelectionServer(mesh=mesh)
    requests = _random_requests(
        12, seed=2, families=("sc", "psc", "flqmi", "gcmi", "logdet", "fl"))
    for (fn, budget), resp in zip(requests, server.select(requests)):
        ref = maximize(fn, budget)
        assert [i for i, _ in ref] == [i for i, _ in resp.selection]
        assert [g for _, g in ref] == [g for _, g in resp.selection]
    print("SHARDED_BREADTH_OK")
    """
)


@pytest.mark.slow
def test_sharded_serving_breadth_four_devices():
    """The full function x backend matrix on a real 2x2 mesh: SetCover family
    (incl. the per-shard Pallas sweep), Disparity, the FL/GC MI combinators
    and LogDet all serve bit-identically with live collectives.  @slow: ~9
    compiled programs; the fast tier covers the same families on the (1,1)
    in-process mesh."""
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_BREADTH_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SHARDED_BREADTH_OK" in r.stdout, r.stdout + r.stderr


def test_server_rejects_unknown_optimizer_at_submit():
    """A typo'd optimizer must fail at submit() — surfacing from the engine
    mid-flush would abort the flush after the pending queue was cleared,
    dropping every co-pending request."""
    rng = np.random.default_rng(13)  # local: keep the session rng sequence
    server = SelectionServer()
    fn = _build("fl", rng, 16)
    rid_ok = server.submit(fn, 3)
    with pytest.raises(ValueError, match="unknown optimizer"):
        server.submit(fn, 3, optimizer="lazygreedy")
    out = server.flush()  # the valid request is unaffected by the rejection
    assert out[rid_ok].selection == maximize(fn, 3)


# ---------------------------------------------------------------------------
# Per-group queues, failure discipline, backpressure, truthful latency.
# ---------------------------------------------------------------------------


def test_group_key_is_the_wave_identity(rng):
    """group_key (computed shape-only at submit time) partitions requests
    exactly as wave coalescing does: same family/bucket/optimizer share a
    key, different shapes or flags split — and budgets/deadlines never key."""
    from repro.launch.coalesce import group_key

    def req(fn, budget, **kw):
        return SelectionRequest(rid=0, spec=SelectionSpec(fn, budget, **kw))

    a = req(_build("fl", rng, 24), 3)
    b = req(_build("fl", rng, 24), 7, deadline_s=0.5)  # budget/deadline: no split
    c = req(_build("fl", rng, 48), 3)  # different bucket
    d = req(_build("gc", rng, 24), 3)  # different family
    e = req(_build("fl", rng, 24), 3, stopIfZeroGain=False)  # different flags
    f = req(_build("fl", rng, 24), 3, optimizer="LazyGreedy")  # different opt
    keys = [group_key(r) for r in (a, b, c, d, e, f)]
    assert keys[0] == keys[1]
    assert len({keys[0], keys[2], keys[3], keys[4], keys[5]}) == 5


def test_server_queues_per_group_and_group_states(rng):
    """Pending requests live in per-group queues; group_states() exposes the
    scheduling view (depth / oldest arrival / earliest deadline)."""
    server = SelectionServer()
    server.submit_spec(SelectionSpec(_build("fl", rng, 24), 3))
    server.submit_spec(SelectionSpec(_build("fl", rng, 24), 5, deadline_s=9.0))
    server.submit_spec(SelectionSpec(_build("gc", rng, 24), 3))
    states = server.group_states()
    assert sorted(depth for _, depth, _, _ in states) == [1, 2]
    assert server.pending_count == 3
    fl_state = next(s for s in states if s[1] == 2)
    assert fl_state[3] is not None  # the deadline_s=9.0 member surfaces
    gc_state = next(s for s in states if s[1] == 1)
    assert gc_state[3] is None
    out = server.flush()
    assert len(out) == 3 and server.pending_count == 0


def test_flush_error_loses_no_requests_or_responses(rng):
    """The poisoned-wave pin (the mid-flush drop bug): wave 2 of 3 fails —
    wave 1's computed responses are re-held, the failed wave AND the
    never-dispatched wave are re-enqueued, and the next flush answers
    everyone.  Zero requests, zero computed responses lost."""
    from repro.launch.serve import FlushError

    class Boom(RuntimeError):
        pass

    class PoisonServer(SelectionServer):
        armed = True

        def _dispatch(self, wave):
            if self.armed and wave.n_bucket == 64:
                raise Boom("engine on fire")
            return super()._dispatch(wave)

    server = PoisonServer()
    fn_good, fn_poison, fn_late = (
        _build("fl", rng, 32),
        _build("fl", rng, 64),
        _build("fl", rng, 16),
    )
    rid_good = server.submit_spec(SelectionSpec(fn_good, 4))
    rid_poison = server.submit_spec(SelectionSpec(fn_poison, 4))
    rid_late = server.submit_spec(SelectionSpec(fn_late, 3))

    with pytest.raises(FlushError) as excinfo:
        server.flush()
    e = excinfo.value
    assert isinstance(e.__cause__, Boom)
    assert e.failed_rids == [rid_poison]
    assert e.undispatched_rids == [rid_late]
    assert set(e.completed) == {rid_good}
    # unserved requests are back in their queues, arrival stamps intact
    assert server.pending_count == 2
    assert server.metrics.counters["flush_errors"] == 1
    assert server.metrics.counters["requeued"] == 2

    server.armed = False  # the engine recovers; nothing was lost
    out = server.flush()
    assert set(out) == {rid_good, rid_poison, rid_late}
    for fn, budget, rid in [(fn_good, 4, rid_good), (fn_poison, 4, rid_poison),
                            (fn_late, 3, rid_late)]:
        assert out[rid].selection == maximize(fn, budget)


def test_flush_error_cancel_escape_hatch(rng):
    """After a FlushError names a poisoned request, cancel(rid) removes it
    from its queue so the retry serves the survivors."""
    from repro.launch.serve import FlushError

    class PoisonServer(SelectionServer):
        def _dispatch(self, wave):
            if wave.n_bucket == 64:
                raise RuntimeError("this request always fails")
            return super()._dispatch(wave)

    server = PoisonServer()
    rid_ok = server.submit_spec(SelectionSpec(_build("fl", rng, 32), 4))
    rid_bad = server.submit_spec(SelectionSpec(_build("fl", rng, 64), 4))
    with pytest.raises(FlushError):
        server.flush()
    assert server.cancel(rid_bad)
    assert not server.cancel(rid_bad)  # already gone
    out = server.flush()  # survivors (and the held wave-1 response) surface
    assert set(out) == {rid_ok}


def test_latency_reports_queue_time_truthfully(rng):
    """The latency-lie fix: a request that waited in the queue reports that
    wait.  latency_s = queue_s + wave_s, and queue_s covers the dwell."""
    import time as _time

    server = SelectionServer()
    rid = server.submit_spec(SelectionSpec(_build("fl", rng, 24), 4))
    _time.sleep(0.25)
    resp = server.flush()[rid]
    assert resp.queue_s >= 0.25
    assert resp.wave_s > 0
    assert resp.latency_s == pytest.approx(resp.queue_s + resp.wave_s)
    assert resp.latency_s > resp.wave_s  # the old code reported only wave_s
    assert resp.deadline_missed is False
    m = server.metrics.snapshot()
    assert m["queue_s"]["count"] == 1 and m["queue_s"]["max"] >= 0.25


def test_server_backpressure_and_cancel_free_space(rng):
    """max_queue admission control: overflow raises ServerOverloaded and is
    counted; cancel() and flush() free space."""
    from repro.launch.serve import ServerOverloaded

    server = SelectionServer(max_queue=2)
    rid_a = server.submit_spec(SelectionSpec(_build("fl", rng, 24), 3))
    server.submit_spec(SelectionSpec(_build("gc", rng, 24), 3))
    with pytest.raises(ServerOverloaded, match="2/2"):
        server.submit_spec(SelectionSpec(_build("fl", rng, 24), 3))
    assert server.stats.rejections == 1
    assert server.cancel(rid_a)  # freeing a slot re-admits
    server.submit_spec(SelectionSpec(_build("fl", rng, 24), 3))
    out = server.flush()
    assert len(out) == 2
    with pytest.raises(ValueError, match="max_queue"):
        SelectionServer(max_queue=0)


def test_server_stats_bounded_with_stable_summary_keys(rng):
    """The unbounded wave_seconds fix: accounting memory is O(1) in flush
    count (fixed-size reservoir), while summary() keeps the historical keys
    and adds the latency/backpressure decomposition."""
    server = SelectionServer()
    fn = _build("fl", rng, 16)
    for _ in range(3):
        server.select([(fn, 3)])
    s = server.stats.summary()
    for key in ("requests", "waves", "slots", "padded_slots", "total_s", "qps"):
        assert key in s  # historical keys, stable
    for key in ("wave_p50_s", "wave_p99_s", "queue_p50_s", "queue_p99_s",
                "rejections", "deadline_misses"):
        assert key in s  # the new decomposition
    assert s["requests"] == 3 and s["waves"] == 3
    assert 0 < s["wave_p50_s"] <= s["wave_p99_s"] <= s["total_s"]
    # bounded: the reservoir never outgrows its capacity
    h = server.metrics.wave_s
    assert h.count == 3 and len(h._reservoir._sample) <= h._reservoir.capacity
