"""Optimizer correctness + the paper's approximation-quality claims (§2, §5.3).

- naive greedy >= (1 - 1/e) of the exhaustive optimum on small instances
  (paper: in practice ~0.98 — we assert the guarantee and report the ratio)
- lazy greedy (bound-screened) returns the identical set to naive greedy
- host Minoux heap returns the identical set with fewer evaluations
- stochastic / lazier-than-lazy reach >= 95% of the greedy value
- cover greedy reaches the requested coverage
- distributed shard_map greedy == serial greedy
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import mask_from_indices
from repro.core import (
    FacilityLocation,
    GraphCut,
    LogDet,
    SetCover,
    cover_greedy,
    create_kernel,
    distributed_fl_greedy,
    host_lazy_greedy,
    knapsack_greedy,
    lazier_than_lazy_greedy,
    lazy_greedy,
    maximize,
    naive_greedy,
    stochastic_greedy,
)


def _clustered_points(rng, n=60, d=5, k=6):
    centers = rng.normal(scale=4.0, size=(k, d))
    return (
        centers[rng.integers(0, k, n)] + rng.normal(scale=0.7, size=(n, d))
    ).astype(np.float32)


def _fns(rng, n=16):
    x = _clustered_points(rng, n=n)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return {
        "fl": FacilityLocation.from_kernel(S),
        "gc": GraphCut.from_kernel(S, lam=0.3),
        "logdet": LogDet.from_kernel(
            0.5 * S + 0.75 * np.eye(n, dtype=np.float32), max_select=6
        ),
        "sc": SetCover.from_cover(
            rng.integers(0, 2, size=(n, 12)).astype(np.float32)
        ),
    }


@pytest.mark.parametrize("name", ["fl", "gc", "logdet", "sc"])
def test_greedy_within_bound_of_optimum(name, rng):
    fn = _fns(rng, n=14)[name]
    budget = 4
    res = naive_greedy(fn, budget, False, False)
    best = -np.inf
    for combo in itertools.combinations(range(14), budget):
        mask = mask_from_indices(jnp.asarray(combo, jnp.int32), 14)
        best = max(best, float(fn.evaluate(mask)))
    got = float(fn.evaluate(mask_from_indices(res.order, fn.n)))
    ratio = got / best if best > 0 else 1.0
    assert ratio >= 1 - 1 / np.e - 1e-6, f"{name}: ratio {ratio:.4f}"
    # the paper observes ~0.98 in practice; these instances should be close
    assert ratio >= 0.9, f"{name}: ratio {ratio:.4f} unexpectedly low"


@pytest.mark.parametrize("name", ["fl", "gc", "logdet", "sc"])
def test_lazy_equals_naive(name, rng):
    fn = _fns(rng, n=40)[name]
    r_naive = naive_greedy(fn, 8, False, False)
    r_lazy = lazy_greedy(fn, 8, 8, False, False)
    assert r_naive.as_list() == r_lazy.as_list()
    assert int(r_lazy.n_evals) <= int(r_naive.n_evals)


@pytest.mark.parametrize("name", ["fl", "gc", "sc"])
def test_host_lazy_equals_naive(name, rng):
    fn = _fns(rng, n=40)[name]
    r_naive = naive_greedy(fn, 8)
    order, gains, n_evals = host_lazy_greedy(fn, 8)
    # ULP-level reduction-order noise can flip exact ties between the heap
    # path (single-column gains) and the vectorized sweep; the objective
    # value must agree to float precision regardless
    got = float(fn.evaluate(mask_from_indices(jnp.asarray(order), fn.n)))
    want = float(r_naive.value)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert n_evals <= int(r_naive.n_evals)


def test_stochastic_and_ltl_quality(rng):
    fn = _fns(rng, n=60)["fl"]
    ref = float(naive_greedy(fn, 10).value)
    st = float(stochastic_greedy(fn, 10, jax.random.PRNGKey(0), 0.01).value)
    ltl = float(
        lazier_than_lazy_greedy(fn, 10, jax.random.PRNGKey(0), 0.01).value
    )
    assert st >= 0.95 * ref
    assert ltl >= 0.95 * ref


def test_eval_count_ordering(rng):
    """Hardware-independent reproduction of the paper's Table 2 ordering:
    evaluations(naive) > evaluations(stochastic) > evaluations(lazy-family)."""
    fn = _fns(rng, n=60)["fl"]
    ev_naive = int(naive_greedy(fn, 10).n_evals)
    ev_st = int(stochastic_greedy(fn, 10, jax.random.PRNGKey(0), 0.01).n_evals)
    ev_lazy = int(lazy_greedy(fn, 10).n_evals)
    ev_ltl = int(
        lazier_than_lazy_greedy(fn, 10, jax.random.PRNGKey(0), 0.01).n_evals
    )
    assert ev_naive > ev_st
    assert ev_naive > ev_lazy
    assert ev_ltl <= ev_st + 60  # ltl adds one initial full sweep

def test_maximize_api(rng):
    fn = _fns(rng, n=30)["fl"]
    out = maximize(fn, budget=5, optimizer="NaiveGreedy")
    assert len(out) == 5 and all(isinstance(i, int) for i, _ in out)
    with pytest.raises(ValueError):
        maximize(fn, budget=5, optimizer="Nope")


def test_cover_greedy_reaches_coverage(rng):
    fn = _fns(rng, n=30)["sc"]
    total = float(fn.evaluate(jnp.ones(30, bool)))
    res = cover_greedy(fn, coverage=0.8 * total, max_steps=30)
    assert float(res.value) >= 0.8 * total


def test_knapsack_respects_budget(rng):
    fn = _fns(rng, n=30)["fl"]
    costs = rng.uniform(0.5, 2.0, 30).astype(np.float32)
    res = knapsack_greedy(fn, budget=4.0, max_steps=30, costs=costs)
    chosen = [i for i, _ in res.as_list()]
    assert sum(costs[i] for i in chosen) <= 4.0 + 1e-5


def test_distributed_matches_serial(rng):
    x = _clustered_points(rng, n=64)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    fn = FacilityLocation.from_kernel(S)
    ref = naive_greedy(fn, 12)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    order, gains = distributed_fl_greedy(S, 12, mesh)
    assert list(np.asarray(order)) == [i for i, _ in ref.as_list()]
    np.testing.assert_allclose(
        np.asarray(gains), np.asarray(ref.gains), rtol=1e-4, atol=1e-5
    )


def test_greedy_respects_stop_flags(rng):
    # a modular function with some negative gains
    n = 12
    w = rng.normal(size=n).astype(np.float32)
    cover = np.eye(n, dtype=np.float32)
    fn = SetCover.from_cover(cover, w)
    res = naive_greedy(fn, n, True, True)
    chosen = [i for i, _ in res.as_list()]
    assert all(w[i] > 0 for i in chosen)
    assert len(chosen) == int((w > 0).sum())
