"""Matrix-free similarity sources: dense parity, solve-mode exactness, and
the memory ceiling.

The tentpole contract: a :class:`FeatureSource` / :class:`KnnSource` backed
function must (a) agree with the dense-kernel path within float tolerance
on every sweep the backends issue, (b) return bit-identical ids / gains /
``n_evals`` through ``solve()`` sequential vs batched vs served — the same
serving contract the dense families carry — and (c) never materialize an
(n, n) intermediate, which is what lets selection reach n >= 10^6 on one
host (the ``@slow`` smoke below runs it).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import make_points
from repro.core import (
    FacilityLocation,
    FacilityLocationMF,
    GraphCut,
    GraphCutMF,
    SelectionSpec,
    create_kernel,
    knn_from_features,
    solve,
    sparsify_topk,
)
from repro.core.optimizers.backends import full_sweep, partial_sweep
from repro.core.sources import TILE, feature_source
from repro.kernels import ops

METRICS = ("dot", "cosine", "rbf")


def _tricky_points(rng, n=37, d=8):
    """Non-multiple-of-TILE n, a duplicate row, and a zero-norm row."""
    assert n % TILE != 0
    x = make_points(rng, n, d)
    x[5] = x[3]
    x[7] = 0.0
    return x


def _pairs(rng, metric, lam=0.4):
    x = _tricky_points(rng)
    S = create_kernel(x, metric=metric)
    return (
        (FacilityLocationMF.from_features(x, metric=metric),
         FacilityLocation.from_kernel(S)),
        (GraphCutMF.from_features(x, metric=metric, lam=lam),
         GraphCut.from_kernel(S, lam=lam)),
    )


def _close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


def _same(a, b, n_evals=True):
    assert list(np.asarray(a.order)) == list(np.asarray(b.order))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    if n_evals:
        assert int(a.n_evals) == int(b.n_evals)


# -- dense-kernel parity (full_sweep / partial_sweep / evaluate) --------------


@pytest.mark.parametrize("metric", METRICS + ("euclidean",))
def test_sweeps_match_dense_path(rng, metric):
    # the duplicate row puts d2 ~ 0 under catastrophic cancellation, and
    # euclidean's 1/(1 + sqrt(d2)) amplifies it — same formula both paths,
    # different (valid) accumulation orders, so euclidean gets a looser bar
    tol = 2e-3 if metric == "euclidean" else 2e-5
    for mf, dense in _pairs(rng, metric):
        st_mf, st_d = mf.init_state(), dense.init_state()
        _close(full_sweep(mf, st_mf), full_sweep(dense, st_d), tol)
        # advance both one greedy step and compare the updated sweep
        j = int(jnp.argmax(full_sweep(dense, st_d)))
        st_mf, st_d = mf.update(st_mf, j), dense.update(st_d, j)
        _close(full_sweep(mf, st_mf), full_sweep(dense, st_d), tol)
        idx = jnp.asarray([0, 3, 5, 7, 36, 12], jnp.int32)
        _close(partial_sweep(mf, st_mf, idx), partial_sweep(dense, st_d, idx), tol)
        mask = jnp.zeros((mf.n,), bool).at[jnp.asarray([j, 2, 7])].set(True)
        _close(mf.evaluate(mask), dense.evaluate(mask), tol)


@pytest.mark.parametrize("metric", METRICS)
def test_fl_gains_at_padding_is_neg_inf(rng, metric):
    """Source-level subset sweeps mask idx < 0 pad slots to NEG_INF (the
    engines' partial-sweep padding contract); live slots are bit-identical
    to the full sweep at the same indices."""
    x = _tricky_points(rng)
    for mf in (
        FacilityLocationMF.from_features(x, metric=metric),
        FacilityLocationMF.from_knn(
            *(lambda s: (s.indices, s.weights))(
                knn_from_features(x, 6, metric=metric)
            )
        ),
    ):
        st = mf.init_state()
        idx = jnp.asarray([4, -1, 9, -1], jnp.int32)
        g = np.asarray(mf.gains_at(st, idx))
        ref = np.asarray(full_sweep(mf, st))
        assert g[1] < -1e29 and g[3] < -1e29
        np.testing.assert_array_equal(g[[0, 2]], ref[[4, 9]])


@pytest.mark.parametrize("metric", METRICS)
def test_selection_matches_dense_path(rng, metric):
    """Acceptance: identical ids, gains within tolerance, dense vs MF."""
    for mf, dense in _pairs(rng, metric):
        r_mf = solve(SelectionSpec(mf, 5))
        r_d = solve(SelectionSpec(dense, 5))
        assert list(np.asarray(r_mf.order)) == list(np.asarray(r_d.order))
        _close(r_mf.gains, r_d.gains)
        assert int(r_mf.n_evals) == int(r_d.n_evals)


# -- solve-mode exactness: sequential vs batched vs served --------------------


@pytest.mark.parametrize("optimizer", ("NaiveGreedy", "LazyGreedy"))
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("family", ("fl", "gc"))
def test_solve_modes_bit_identical(rng, family, metric, optimizer):
    x = _tricky_points(rng)
    if family == "fl":
        fn = FacilityLocationMF.from_features(x, metric=metric)
    else:
        fn = GraphCutMF.from_features(x, metric=metric, lam=0.4)
    kw = {"screen_k": 8} if optimizer == "LazyGreedy" else {}
    spec = SelectionSpec(fn, 5, optimizer=optimizer, **kw)
    seq = solve(spec)
    _same(seq, solve([spec, spec], mode="batched")[0])
    _same(seq, solve([spec], mode="served")[0])  # pads n to its bucket


@pytest.mark.parametrize("family", ("fl", "gc"))
def test_knn_solve_modes_bit_identical(rng, family):
    x = make_points(rng, 41)
    src = knn_from_features(x, 6, metric="rbf")
    if family == "fl":
        fn = FacilityLocationMF.from_knn(src.indices, src.weights)
    else:
        fn = GraphCutMF.from_knn(src.indices, src.weights, lam=0.4)
    spec = SelectionSpec(fn, 4)
    seq = solve(spec)
    _same(seq, solve([spec, spec], mode="batched")[0])
    _same(seq, solve([spec], mode="served")[0])


# -- the sparse k-NN source ---------------------------------------------------


def test_knn_source_is_the_sparsified_dense_matrix(rng):
    x = make_points(rng, 29)
    S = sparsify_topk(create_kernel(x, metric="rbf"), 5)
    src = knn_from_features(x, 5, metric="rbf")
    _close(src.to_dense(), S)
    fl_knn = FacilityLocationMF.from_knn(src.indices, src.weights)
    fl_dense = FacilityLocation.from_kernel(src.to_dense())
    st_k, st_d = fl_knn.init_state(), fl_dense.init_state()
    _close(full_sweep(fl_knn, st_k), full_sweep(fl_dense, st_d))
    st_k, st_d = fl_knn.update(st_k, 11), fl_dense.update(st_d, 11)
    _close(full_sweep(fl_knn, st_k), full_sweep(fl_dense, st_d))
    gc_knn = GraphCutMF.from_knn(src.indices, src.weights, lam=0.3)
    gc_dense = GraphCut.from_kernel(src.to_dense(), lam=0.3)
    _close(full_sweep(gc_knn, gc_knn.init_state()),
           full_sweep(gc_dense, gc_dense.init_state()))
    mask = jnp.zeros((29,), bool).at[jnp.asarray([1, 11, 20])].set(True)
    _close(gc_knn.evaluate(mask), gc_dense.evaluate(mask))
    _close(fl_knn.evaluate(mask), fl_dense.evaluate(mask))


# -- fused Pallas sweeps vs jnp oracles (interpret mode off-TPU) --------------


@pytest.mark.parametrize("metric", METRICS + ("euclidean",))
def test_flmf_pallas_matches_ref(rng, metric):
    u, n, d = 45, 70, 12  # nothing tile-aligned
    x, y = make_points(rng, u, d), make_points(rng, n, d)
    if metric == "cosine":  # kernel contract: cosine rows arrive normalized
        x = x / np.linalg.norm(x, axis=1, keepdims=True)
        y = y / np.linalg.norm(y, axis=1, keepdims=True)
    xx, yy = (x * x).sum(1), (y * y).sum(1)
    curmax = np.abs(make_points(rng, u, 1))[:, 0]
    got = ops.flmf_gains(x, y, xx, yy, curmax, metric=metric)
    want = ops.flmf_gains_ref(x, y, curmax, metric=metric)
    _close(got, want)
    idx = jnp.asarray([3, 69, -1, 17], jnp.int32)
    got_at = np.asarray(
        ops.flmf_gains_at(x, y, xx, yy, curmax, idx, metric=metric)
    )
    assert got_at[2] < -1e29
    _close(got_at[[0, 1, 3]], np.asarray(want)[[3, 69, 17]])


@pytest.mark.parametrize("metric", METRICS + ("euclidean",))
def test_gcmf_pallas_matches_ref(rng, metric):
    n, d = 70, 12
    y = make_points(rng, n, d)
    if metric == "cosine":
        y = y / np.linalg.norm(y, axis=1, keepdims=True)
    yy = (y * y).sum(1)
    src = feature_source(y, metric=metric)
    total, diag = src.col_sums(), src.diag()
    selmask = np.zeros(n, np.float32)
    selmask[[4, 31, 66]] = 1.0
    lam = jnp.asarray(0.4, jnp.float32)
    got = ops.gcmf_gains(y, yy, selmask, total, diag, lam, metric=metric)
    want = ops.gcmf_gains_ref(y, selmask, total, lam, metric=metric, diag=diag)
    _close(got, want)
    idx = jnp.asarray([0, -1, 42], jnp.int32)
    got_at = np.asarray(
        ops.gcmf_gains_at(y, yy, selmask, total, diag, lam, idx, metric=metric)
    )
    assert got_at[1] < -1e29
    _close(got_at[[0, 2]], np.asarray(want)[[0, 42]])


# -- the memory ceiling: no (n, n) intermediate -------------------------------


def _assert_no_square(traced, n):
    """The jaxpr walk now lives in the lint package (the JAXPR rule runs
    it over a whole manifest of cells); this keeps the test suite and the
    lint gate on one implementation."""
    import sys

    sys.path.insert(0, str(__import__("pathlib").Path(__file__).resolve().parents[1]))
    from tools.lint.jaxpr_audit import square_intermediates

    problems = square_intermediates(traced.jaxpr, n, TILE)
    assert not problems, problems


def test_full_sweep_has_no_square_intermediate(rng):
    n, d = 50_000, 8
    x = make_points(rng, 64, d)
    y = make_points(rng, n, d)
    fn = FacilityLocationMF.from_features(x, y=y, metric="rbf")
    traced = jax.make_jaxpr(lambda f: full_sweep(f, f.init_state()))(fn)
    _assert_no_square(traced, n)


def test_greedy_has_no_square_intermediate(rng):
    from repro.core.optimizers.greedy import naive_greedy

    n, d = 50_000, 8
    x = make_points(rng, 64, d)
    y = make_points(rng, n, d)
    fn = FacilityLocationMF.from_features(x, y=y, metric="dot")
    traced = jax.make_jaxpr(lambda f: naive_greedy(f, 3))(fn)
    _assert_no_square(traced, n)


# -- million-point smoke (slow tier) ------------------------------------------


@pytest.mark.slow
def test_million_point_fl_feature_source(rng):
    """FL selection over n = 10^6 candidates on one host: the represented
    set is a small sample (the summarization shape), candidates stream in
    feature tiles — peak bytes O(n * d), never n^2 (the jaxpr walk pins
    the ceiling; this runs the real thing)."""
    n, d, u = 1_000_000, 8, 512
    y = make_points(rng, n, d)
    x = y[rng.choice(n, size=u, replace=False)]
    fn = FacilityLocationMF.from_features(x, y=y, metric="dot")
    traced = jax.make_jaxpr(lambda f: full_sweep(f, f.init_state()))(fn)
    _assert_no_square(traced, n)
    res = solve(SelectionSpec(fn, 3))
    order = [i for i in np.asarray(res.order) if i >= 0]
    assert len(order) == 3 and len(set(order)) == 3
    assert all(0 <= i < n for i in order)
    gains = np.asarray(res.gains)[:3]
    assert np.all(np.diff(gains) <= 1e-3)  # greedy gains are non-increasing


@pytest.mark.slow
def test_million_point_fl_knn_source(rng):
    """The sparse k-NN source rides the same backend contract at n = 10^6:
    O(n * k) scatter sweeps, no similarity matrix."""
    n, k = 1_000_000, 8
    indices = rng.integers(0, n, size=(n, k)).astype(np.int32)
    weights = rng.random(size=(n, k)).astype(np.float32)
    fn = FacilityLocationMF.from_knn(indices, weights, n_cols=n)
    res = solve(SelectionSpec(fn, 4))
    order = [i for i in np.asarray(res.order) if i >= 0]
    assert len(order) == 4 and len(set(order)) == 4
