"""Streaming optimizers and selection sessions: guarantees, determinism,
constraints, and the gains_at negative-index contract.

The property layer pins the theory: SieveStreaming's (1/2 - eps) factor
against NaiveGreedy (a lower bound on OPT) for every monotone servable
family, at eps in {0.1, 0.2}.  The determinism layer pins the session
replay contract: a session fed 10 deltas returns ids, gains AND n_evals
bit-identical to one direct ``solve()`` over the concatenated stream — off
mesh and on a mesh — and one big extend equals many small ones.  The
constraint layer covers ``optimizers/constrained.py`` offline (matroid /
knapsack greedy) and through the streaming accept rule (constraint as a
spec flag).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propcheck import given, settings, st

from repro.common import NEG_INF
from repro.core import (
    DifferenceFunction,
    FacilityLocation,
    FacilityLocationMF,
    FeatureBased,
    GraphCut,
    Knapsack,
    PartitionMatroid,
    SelectionSpec,
    SetCover,
    create_kernel,
    knapsack_greedy,
    matroid_greedy,
    sieve_streaming,
    solve,
    threshold_greedy,
)
from repro.core.optimizers.constrained import (
    as_constraint,
    streaming_add,
    streaming_feasible,
    streaming_state,
)
from repro.launch.serve import SelectionServer, _random_function
from repro.launch.sessions import SessionClosed, resolve_extender, resolve_restrictor


def _value(res) -> float:
    return float(np.asarray(res.gains).sum())


def _same(a, b, n_evals=True):
    assert list(np.asarray(a.order)) == list(np.asarray(b.order))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    if n_evals:
        assert int(a.n_evals) == int(b.n_evals)


def _fl(rng, n=32):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return FacilityLocation.from_kernel(S)


# every monotone family the server can coalesce (dispersion families are
# non-monotone; LogDet's guarantee needs the restricted-strong-concavity
# form, so it is exercised by the generic route tests instead)
MONOTONE_SERVABLE = ("fl", "fb", "sc", "psc", "gcmi", "flqmi")


# -- the (1/2 - eps) guarantee ------------------------------------------------


@pytest.mark.parametrize("family", MONOTONE_SERVABLE)
@pytest.mark.parametrize("epsilon", [0.1, 0.2])
@settings(max_examples=2, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       budget=st.integers(min_value=2, max_value=5))
def test_sieve_half_minus_eps_guarantee(family, epsilon, seed, budget):
    """f(sieve) >= (1/2 - eps) * OPT for monotone submodular f; NaiveGreedy
    lower-bounds OPT, so the sieve value must clear (1/2 - eps) * greedy."""
    rng = np.random.default_rng(seed)
    fn = _random_function(family, 28, rng)
    greedy = solve(SelectionSpec(fn, budget))
    sieve = solve(SelectionSpec(fn, budget, "SieveStreaming", epsilon=epsilon))
    bound = (0.5 - epsilon) * _value(greedy)
    assert _value(sieve) >= bound - 1e-5, (
        f"{family}: sieve {_value(sieve):.6f} < (1/2-{epsilon}) * "
        f"greedy {_value(greedy):.6f}"
    )


@pytest.mark.parametrize("family", MONOTONE_SERVABLE)
@settings(max_examples=2, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       budget=st.integers(min_value=2, max_value=5))
def test_threshold_greedy_guarantee(family, seed, budget):
    """Multi-pass threshold greedy carries (1 - 1/e - eps) for monotone f."""
    eps = 0.1
    rng = np.random.default_rng(seed)
    fn = _random_function(family, 28, rng)
    greedy = solve(SelectionSpec(fn, budget))
    tg = solve(SelectionSpec(fn, budget, "ThresholdGreedy",
                             epsilon=eps, buffer_size=8))
    bound = (1.0 - 1.0 / np.e - eps) * _value(greedy)
    assert _value(tg) >= bound - 1e-5


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_streaming_values_telescope(seed):
    """Reported gains telescope to f(S) exactly — no drift between the
    streaming accept rule's memoized state and the oracle."""
    rng = np.random.default_rng(seed)
    fn = _random_function("fb", 24, rng)
    for res in (sieve_streaming(fn, 4, epsilon=0.2),
                threshold_greedy(fn, 4, epsilon=0.2, buffer_size=6)):
        ids = [int(j) for j in np.asarray(res.order) if j >= 0]
        mask = np.zeros(24, bool)
        mask[ids] = True
        np.testing.assert_allclose(
            _value(res), float(fn.evaluate(jnp.asarray(mask))), rtol=1e-5
        )


# -- session replay determinism ----------------------------------------------


@pytest.mark.parametrize("optimizer", ["SieveStreaming", "ThresholdGreedy"])
@pytest.mark.parametrize("on_mesh", [False, True])
def test_session_ten_deltas_bit_identical_to_direct_solve(rng, optimizer, on_mesh):
    """The acceptance bar: 10 feature deltas through a session == one
    solve() over the concatenated stream — ids, gains, n_evals — on and off
    mesh."""
    rows = rng.uniform(0, 1, size=(44, 6)).astype(np.float32)
    mesh = jax.make_mesh((1, 1), ("batch", "data")) if on_mesh else None
    server = SelectionServer(mesh=mesh)
    spec = SelectionSpec(FeatureBased.from_features(rows[:4]), 5, optimizer,
                         epsilon=0.1)
    sess = server.open_session(spec)
    upd = None
    for lo in range(4, 44, 4):  # 10 deltas of 4 rows
        upd = sess.extend(features=rows[lo:lo + 4])
    assert sess.deltas_absorbed == 10 and upd.seq == 10 and upd.n_total == 44
    direct = solve(SelectionSpec(FeatureBased.from_features(rows), 5, optimizer,
                                 epsilon=0.1))
    _same(direct, upd.result)
    assert [j for j, _ in upd.selection] == [
        int(j) for j in np.asarray(direct.order) if j >= 0
    ]
    sess.close()


def test_session_single_extend_equals_many_deltas(rng):
    """Extenders are concatenation-associative bit-for-bit, so one big
    extend and many small ones build the same stream — including the
    matrix-free FeatureSource path (never materializes n x n)."""
    rows = rng.normal(size=(36, 7)).astype(np.float32)
    server = SelectionServer()

    def run(chunks):
        sess = server.open_session(
            SelectionSpec(FacilityLocationMF.from_features(rows[:6]), 4,
                          "SieveStreaming", epsilon=0.1)
        )
        for c in chunks:
            upd = sess.extend(features=c)
        sess.close()
        return upd

    many = run([rows[lo:lo + 6] for lo in range(6, 36, 6)])
    one = run([rows[6:]])
    _same(many.result, one.result)
    direct = solve(SelectionSpec(FacilityLocationMF.from_features(rows), 4,
                                 "SieveStreaming", epsilon=0.1))
    _same(direct, one.result)


def test_session_arrival_order_is_replayed_deterministically(rng):
    """Same seed + same delta order -> bit-identical updates at every step,
    including the shuffled (seeded) arrival order."""
    rows = rng.uniform(0, 1, size=(30, 5)).astype(np.float32)
    server = SelectionServer()

    def run():
        sess = server.open_session(
            SelectionSpec(FeatureBased.from_features(rows[:10]), 4,
                          "SieveStreaming", epsilon=0.2, seed=7)
        )
        ups = [sess.extend(features=rows[lo:lo + 10]) for lo in (10, 20)]
        sess.close()
        return ups

    a, b = run(), run()
    for ua, ub in zip(a, b):
        _same(ua.result, ub.result)
        assert ua.selection == ub.selection


def test_session_indices_mode_maps_universe_ids(rng):
    """Indices mode: the restricted function preserves the universe
    function's values, and updates report universe ids."""
    uni = _fl(rng, n=30)
    server = SelectionServer()
    sess = server.open_session(SelectionSpec(uni, 4))
    sess.extend(indices=[3, 7, 11])
    upd = sess.extend(indices=[0, 7, 20, 25, 14])  # 7 repeats: ignored
    assert upd.n_total == 7 and upd.n_delta == 4
    ids = [j for j, _ in upd.selection]
    assert set(ids) <= {3, 7, 11, 0, 20, 25, 14}
    mask = np.zeros(30, bool)
    mask[ids] = True
    np.testing.assert_allclose(
        float(uni.evaluate(jnp.asarray(mask))),
        _value(upd.result), rtol=1e-5,
    )
    sess.close()


def test_session_indices_mode_graph_cut_value_preserving(rng):
    x = rng.normal(size=(24, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    uni = GraphCut.from_kernel(S, lam=0.4)
    active = np.asarray([1, 4, 9, 13, 17, 21], np.int32)
    sub = resolve_restrictor(GraphCut)(uni, active)
    # the restricted f agrees with the universe f on subsets of active
    local = jnp.asarray([True, False, True, True, False, False])
    mask = np.zeros(24, bool)
    mask[active[np.asarray(local)]] = True
    np.testing.assert_allclose(
        float(sub.evaluate(local)), float(uni.evaluate(jnp.asarray(mask))),
        rtol=1e-5,
    )


def test_session_mode_and_lifecycle_discipline(rng):
    rows = rng.uniform(0, 1, size=(12, 4)).astype(np.float32)
    server = SelectionServer()
    sess = server.open_session(SelectionSpec(FeatureBased.from_features(rows[:6]), 3))
    assert sess.mode is None
    sess.extend(features=rows[6:9])
    assert sess.mode == "features"
    with pytest.raises(ValueError, match="features.*mode"):
        sess.extend(indices=[0])
    with pytest.raises(TypeError, match="exactly one"):
        sess.extend()
    with pytest.raises(TypeError, match="exactly one"):
        sess.extend(features=rows[9:], indices=[0])
    sess.close()
    sess.close()  # idempotent
    with pytest.raises(SessionClosed):
        sess.extend(features=rows[9:])

    s2 = server.open_session(SelectionSpec(_fl(np.random.default_rng(0), 10), 3))
    with pytest.raises(ValueError, match="universe"):
        s2.extend(indices=[99])
    with pytest.raises(TypeError, match="SelectionSpec"):
        server.open_session("not a spec")

    # unregistered family names the registry hook
    from repro.core import DisparitySum
    d = np.ones((6, 6), np.float32) - np.eye(6, dtype=np.float32)
    s3 = server.open_session(
        SelectionSpec(DisparitySum.from_distance(d), 2, stopIfZeroGain=False)
    )
    with pytest.raises(NotImplementedError, match="register_feature_extender"):
        s3.extend(features=np.ones((1, 6), np.float32))
    with pytest.raises(NotImplementedError, match="register_restrictor"):
        s3.extend(indices=[0])


def test_session_metrics_roll_up(rng):
    rows = rng.uniform(0, 1, size=(24, 5)).astype(np.float32)
    server = SelectionServer()
    sess = server.open_session(SelectionSpec(FeatureBased.from_features(rows[:8]), 3))
    u1 = sess.extend(features=rows[8:16])
    u2 = sess.extend(features=rows[16:])
    sess.close()
    c = server.metrics.counters
    assert c["sessions_opened"] == 1 and c["sessions_closed"] == 1
    assert c["session_deltas"] == 2
    # first update churns the whole selection in (prev = empty set)
    assert u1.churn == len(u1.selection)
    assert c["session_churn"] == u1.churn + u2.churn == sess.churn_total
    snap = server.metrics.snapshot()
    assert snap["delta_s"]["count"] == 2
    assert sess.last_update is u2 and u2.latency_s > 0


def test_session_hooks_resolve_along_mro():
    """Registry resolution walks the MRO (like padders and shard rules): the
    info-measure constructors return base-family instances, and subclasses
    inherit session coverage without re-registering."""
    from repro.core import sc_mi

    eye = np.eye(6, dtype=np.float32)
    fn = sc_mi(eye, np.ones(6, np.float32), eye[:2])
    assert resolve_extender(type(fn)) is resolve_extender(SetCover)

    class CustomSC(SetCover):
        pass

    assert resolve_extender(CustomSC) is resolve_extender(SetCover)
    assert resolve_restrictor(CustomSC) is resolve_restrictor(SetCover)


# -- gains_at negative-index contract ----------------------------------------


def test_gains_at_negative_indices_masked_dense(rng):
    """The -1-padded ``order`` buffer footgun: a dense gather would wrap
    idx=-1 to the LAST element; the contract masks it to NEG_INF instead,
    and idx >= 0 stays bit-identical to a negatives-free gains_at call
    (the mask rewrites ONLY negative lanes; the full sweep may use a
    different — equally valid — float contraction order)."""
    fn = _fl(rng, n=16)
    state = fn.init_state()
    full = np.asarray(fn.gains(state))
    idxs = jnp.asarray([-1, 0, 5, -3, 15], jnp.int32)
    g = np.asarray(fn.gains_at(state, idxs))
    assert g[0] == NEG_INF and g[3] == NEG_INF
    clean = np.asarray(fn.gains_at(state, jnp.asarray([0, 5, 15], jnp.int32)))
    np.testing.assert_array_equal(g[[1, 2, 4]], clean)
    np.testing.assert_allclose(g[[1, 2, 4]], full[[0, 5, 15]], rtol=1e-5)


@pytest.mark.parametrize("make", [
    lambda rng: FeatureBased.from_features(
        rng.uniform(0, 1, size=(16, 6)).astype(np.float32)),
    lambda rng: SetCover.from_cover(
        rng.integers(0, 2, size=(16, 10)).astype(np.float32)),
    lambda rng: FacilityLocationMF.from_features(
        rng.normal(size=(16, 6)).astype(np.float32), metric="dot"),
])
def test_gains_at_negative_indices_masked_all_families(rng, make):
    fn = make(rng)
    state = fn.init_state()
    full = np.asarray(fn.gains(state))
    idxs = jnp.asarray([-1, 3, -2, 7], jnp.int32)
    g = np.asarray(fn.gains_at(state, idxs))
    assert g[0] == NEG_INF and g[2] == NEG_INF
    clean = np.asarray(fn.gains_at(state, jnp.asarray([3, 7], jnp.int32)))
    np.testing.assert_array_equal(g[[1, 3]], clean)
    np.testing.assert_allclose(g[[1, 3]], full[[3, 7]], rtol=1e-5)


def test_gains_at_negative_indices_difference_function(rng):
    """Combinators subtract gains: NEG_INF - NEG_INF would be 0 (a ghost
    candidate with zero gain) without the outer re-mask."""
    f1 = FeatureBased.from_features(rng.uniform(0, 1, (12, 5)).astype(np.float32))
    f2 = FeatureBased.from_features(rng.uniform(0, 1, (12, 5)).astype(np.float32))
    diff = DifferenceFunction.build(f1, f2, 12)
    g = np.asarray(diff.gains_at(diff.init_state(), jnp.asarray([-1, 2])))
    assert g[0] == NEG_INF
    assert np.isfinite(g[1])


def test_solve_routes_unchanged_by_negative_index_mask(rng):
    """The mask only rewrites idx < 0 lanes; LazyGreedy (the heaviest
    gains_at consumer) stays bit-identical to NaiveGreedy selections."""
    fn = _fl(rng, n=24)
    _same(solve(SelectionSpec(fn, 5)),
          solve(SelectionSpec(fn, 5, "LazyGreedy", screen_k=8)), n_evals=False)


# -- constraints: offline + streaming accept path ----------------------------


def test_constraint_validation():
    with pytest.raises(ValueError, match="positive"):
        Knapsack(costs=(1.0, -1.0), budget=2.0)
    with pytest.raises(ValueError, match="budget"):
        Knapsack(costs=(1.0,), budget=0.0)
    with pytest.raises(ValueError, match="index caps"):
        PartitionMatroid(labels=(0, 3), caps=(1, 1))
    with pytest.raises(TypeError, match="constraint must be"):
        as_constraint("knapsack")
    assert as_constraint(None) is None
    k = Knapsack(costs=[1, 2], budget=2.5)
    assert as_constraint(k) is k and hash(k) == hash(Knapsack((1.0, 2.0), 2.5))


def test_streaming_constraint_helpers_unit():
    k = Knapsack(costs=(1.0, 2.0, 3.0), budget=3.0)
    cs = streaming_state(k, width=2)
    assert cs.shape == (2,)
    ok = streaming_feasible(k, cs, jnp.int32(2))  # cost 3 fits budget 3
    np.testing.assert_array_equal(np.asarray(ok), [True, True])
    cs = streaming_add(k, cs, jnp.int32(2), jnp.asarray([True, False]))
    np.testing.assert_array_equal(np.asarray(cs), [3.0, 0.0])
    ok = streaming_feasible(k, cs, jnp.int32(0))  # selector 0 is full
    np.testing.assert_array_equal(np.asarray(ok), [False, True])

    m = PartitionMatroid(labels=(0, 0, 1), caps=(1, 2))
    cm = streaming_state(m, width=2)
    assert cm.shape == (2, 2)
    cm = streaming_add(m, cm, jnp.int32(0), jnp.asarray([True, True]))
    ok = streaming_feasible(m, cm, jnp.int32(1))  # part 0 is at cap 1
    np.testing.assert_array_equal(np.asarray(ok), [False, False])
    ok = streaming_feasible(m, cm, jnp.int32(2))  # part 1 still open
    np.testing.assert_array_equal(np.asarray(ok), [True, True])

    # unconstrained lowers to all-True and identity
    cs0 = streaming_state(None, width=3)
    assert bool(streaming_feasible(None, cs0, jnp.int32(0)).all())
    assert streaming_add(None, cs0, jnp.int32(0), jnp.asarray([True] * 3)) is cs0


def test_matroid_greedy_offline_feasible_and_monotone(rng):
    fn = _fl(rng, n=18)
    labels = tuple(int(v) for v in rng.integers(0, 3, size=18))
    tight = PartitionMatroid(labels=labels, caps=(1, 1, 1))
    loose = PartitionMatroid(labels=labels, caps=(2, 2, 2))
    r_tight = matroid_greedy(fn, tight, max_steps=6)
    r_loose = matroid_greedy(fn, loose, max_steps=6)
    for res, cons in ((r_tight, tight), (r_loose, loose)):
        ids = [int(j) for j in np.asarray(res.order) if j >= 0]
        assert len(ids) == len(set(ids))
        counts = np.zeros(len(cons.caps), int)
        for j in ids:
            counts[cons.labels[j]] += 1
        assert (counts <= np.asarray(cons.caps)).all()
        gains = [g for g in np.asarray(res.gains) if g > 0]
        assert gains == sorted(gains, reverse=True)  # greedy gains decrease
    # relaxing every cap can only help a monotone objective
    assert _value(r_loose) >= _value(r_tight) - 1e-6


def test_knapsack_greedy_offline_respects_budget(rng):
    fn = _fl(rng, n=16)
    costs = rng.uniform(0.5, 2.0, size=16).astype(np.float32)
    res = knapsack_greedy(fn, jnp.asarray(3.0), max_steps=8, costs=costs)
    ids = [int(j) for j in np.asarray(res.order) if j >= 0]
    assert ids and sum(costs[j] for j in ids) <= 3.0 + 1e-6


@pytest.mark.parametrize("optimizer", ["SieveStreaming", "ThresholdGreedy"])
def test_streaming_knapsack_accept_rule(rng, optimizer):
    fn = _fl(rng, n=20)
    costs = tuple(float(c) for c in rng.uniform(0.5, 1.5, size=20))
    cons = Knapsack(costs=costs, budget=2.5)
    res = solve(SelectionSpec(fn, 6, optimizer, epsilon=0.1, constraint=cons))
    ids = [int(j) for j in np.asarray(res.order) if j >= 0]
    assert ids and sum(costs[j] for j in ids) <= 2.5 + 1e-6


@pytest.mark.parametrize("optimizer", ["SieveStreaming", "ThresholdGreedy"])
def test_streaming_matroid_accept_rule(rng, optimizer):
    fn = _fl(rng, n=20)
    labels = tuple(int(v) for v in rng.integers(0, 3, size=20))
    cons = PartitionMatroid(labels=labels, caps=(2, 1, 2))
    res = solve(SelectionSpec(fn, 6, optimizer, epsilon=0.1, constraint=cons))
    ids = [int(j) for j in np.asarray(res.order) if j >= 0]
    assert ids
    counts = np.zeros(3, int)
    for j in ids:
        counts[labels[j]] += 1
    assert (counts <= np.asarray(cons.caps)).all()


def test_constrained_streaming_served_equals_sequential(rng):
    """The constraint rides the OptimizerSpec as static metadata, so a
    constrained streaming request coalesces and serves bit-identically."""
    fn = _fl(rng, n=24)
    cons = PartitionMatroid(
        labels=tuple(int(v) for v in np.arange(24) % 3), caps=(2, 2, 2)
    )
    spec = SelectionSpec(fn, 5, "SieveStreaming", epsilon=0.1, constraint=cons)
    seq = solve(spec)
    server = SelectionServer()
    _same(seq, server.select([spec])[0].result)


def test_streaming_session_under_constraint(rng):
    """Sessions and constraints compose: every update's selection respects
    the knapsack, and the final one equals the direct constrained solve."""
    rows = rng.uniform(0, 1, size=(24, 5)).astype(np.float32)
    costs = tuple(float(c) for c in rng.uniform(0.4, 1.2, size=24))
    cons = Knapsack(costs=costs, budget=2.0)
    server = SelectionServer()
    sess = server.open_session(
        SelectionSpec(FeatureBased.from_features(rows[:8]), 5, "SieveStreaming",
                      epsilon=0.1, constraint=cons)
    )
    for lo in (8, 16):
        upd = sess.extend(features=rows[lo:lo + 8])
        spend = sum(costs[j] for j, _ in upd.selection)
        assert spend <= 2.0 + 1e-6
    direct = solve(SelectionSpec(FeatureBased.from_features(rows), 5,
                                 "SieveStreaming", epsilon=0.1, constraint=cons))
    _same(direct, upd.result)
    sess.close()
