"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-gradient step plus prefill+decode on CPU, asserting shapes and no NaNs.
The FULL configs are exercised only via the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ALL_ARCHS
from repro.configs.base import get_config
from repro.models.model import (
    decode_step,
    init_params,
    prefill,
    train_forward,
)

B, L = 2, 64


def _batch(cfg, rng, seq=L):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, seq)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_positions, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def nprng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_forward_and_grad(arch, nprng):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, nprng)

    def loss_fn(p):
        loss, _ = train_forward(cfg, p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # a reasonable xent at init: close to log(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), f"{arch}: NaN grads"
    gnorm = float(
        jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32)**2) for l in leaves))
    )
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, nprng):
    """Prefill then one decode step; logits finite and decode agrees with a
    from-scratch forward over the extended sequence (teacher-forcing check)."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, nprng, seq=32)
    logits_p, caches = prefill(cfg, params, batch, max_len=40)
    assert logits_p.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits_p)).all(), f"{arch}: prefill NaN"

    next_tok = jnp.argmax(logits_p[:, -1], -1).astype(jnp.int32)[:, None]
    logits_d, caches = decode_step(cfg, params, caches, next_tok, jnp.asarray(32))
    assert logits_d.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits_d)).all(), f"{arch}: decode NaN"

    # teacher-forcing consistency: running the 33-token prefix through
    # prefill must reproduce the decode logits (same math, different path)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    # prefill pads are invisible: compare last-token logits
    logits_ref, _ = prefill(cfg, params, ext, max_len=40)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]),
        np.asarray(logits_ref[:, 0]),
        rtol=2e-2,
        atol=2e-2,
    )


def test_param_counts_match_assignment_scale():
    """Full-config parameter counts are in the right ballpark (the assignment
    names the scale in the arch id)."""
    expect = {
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "deepseek-v2-236b": (1.8e11, 2.9e11),
        "jamba-1.5-large-398b": (3.0e11, 5.0e11),
        "starcoder2-3b": (2.4e9, 4.5e9),
        "qwen3-0.6b": (4e8, 9e8),
        "internlm2-20b": (1.6e10, 2.6e10),
        "command-r-plus-104b": (0.8e11, 1.4e11),
        "qwen2-vl-7b": (5e9, 9e9),
        "mamba2-370m": (2.5e8, 5e8),
        "whisper-small": (1.5e8, 4e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert 2.0e10 <= active <= 4.5e10, f"active {active:.3e}"  # "a32b"


@pytest.mark.parametrize("arch", ["mamba2-370m", "jamba-1.5-large-398b"])
def test_ssm_decode_matches_prefill_exactly(arch, nprng):
    """The recurrent decode state after prefill must continue the sequence:
    decode logits at position L must match prefill over L+1 tokens."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(nprng.integers(0, cfg.vocab, (1, 33)), jnp.int32)
    logits_p, caches = prefill(cfg, params, {"tokens": toks[:, :32]}, max_len=40)
    logits_d, _ = decode_step(cfg, params, caches, toks[:, 32:33], jnp.asarray(32))
    logits_ref, _ = prefill(cfg, params, {"tokens": toks}, max_len=40)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(logits_ref[:, 0]), rtol=2e-2, atol=2e-2
    )
