"""Fault-tolerant serving: injection, retry/backoff, fallback, crash-safe
sessions.

The contract under test (ISSUE: robustness tentpole):

- every submitted rid resolves to EXACTLY ONE response or one typed
  ``RequestFailed`` — never silently dropped, never double-delivered —
  under every fault class the :mod:`repro.launch.faults` harness can arm;
- recovered selections are bit-identical (ids / gains / n_evals) to
  sequential ``solve()`` — retries, backend fallback, and single-device
  fallback change WHERE the work runs, never what it returns;
- one poison request can never re-poison its group: co-travellers survive
  via singleton-wave isolation, the poison quarantines typed;
- journaled sessions replay to bit-identical state on a fresh server.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureBased,
    GraphCut,
    SelectionSpec,
    create_kernel,
    solve,
)
from repro.launch import faults
from repro.launch.async_serve import AsyncSelectionServer
from repro.launch.faults import FaultPlan, FaultSpec, InjectedFault
from repro.launch.resilience import (
    SINGLE_ATTEMPT,
    BreakerBoard,
    CircuitBreaker,
    RequestFailed,
    RetryPolicy,
)
from repro.launch.serve import SelectionServer
from repro.launch.sessions import SessionJournal, restore_sessions

# no-backoff policy: fault-matrix cells retry instantly, tests stay fast
POLICY = RetryPolicy(max_attempts=3, backoff_s=0.0, jitter=0.0)


def _fl_spec(rng, n=32, budget=4, use_kernel=False):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return SelectionSpec(FacilityLocation.from_kernel(S, use_kernel=use_kernel), budget)


def _same(seq, resp):
    got = resp.result
    assert list(np.asarray(seq.order)) == list(np.asarray(got.order))
    np.testing.assert_array_equal(np.asarray(seq.gains), np.asarray(got.gains))
    assert int(seq.n_evals) == int(got.n_evals)


def _mesh1x1():
    import jax

    return jax.make_mesh((1, 1), ("batch", "data"))


# ---------------------------------------------------------------------------
# faults.py units: addressing, budgets, determinism
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="site"):
        FaultSpec(site="nope")
    with pytest.raises(ValueError, match="times"):
        FaultSpec(site="dispatch", times=0)
    with pytest.raises(ValueError, match="rate"):
        FaultSpec(site="dispatch", rate=1.5)
    with pytest.raises(ValueError, match="after"):
        FaultSpec(site="dispatch", after=-1)
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec(site="dispatch", delay_s=-0.1)


def test_fault_spec_addressing():
    fs = FaultSpec(site="dispatch", family="FacilityLocation", backend="pallas-*")
    assert fs.matches("dispatch", {"family": "FacilityLocation", "backend": "pallas-fl"})
    assert not fs.matches("dispatch", {"family": "GraphCut", "backend": "pallas-fl"})
    assert not fs.matches("dispatch", {"family": "FacilityLocation", "backend": "xla"})
    assert not fs.matches("kernel", {"family": "FacilityLocation", "backend": "pallas-fl"})
    rid = FaultSpec(site="dispatch", rid=7)
    assert rid.matches("dispatch", {"rids": (3, 7)})
    assert not rid.matches("dispatch", {"rids": (3, 4)})
    mesh = FaultSpec(site="dispatch", mesh=True)
    assert mesh.matches("dispatch", {"mesh": True})
    assert not mesh.matches("dispatch", {"mesh": False})


def test_fault_plan_times_after_budgets():
    plan = FaultPlan([FaultSpec(site="dispatch", times=2, after=1)])
    fired = [plan.fires("dispatch", {}) is not None for _ in range(5)]
    assert fired == [False, True, True, False, False]  # skip 1, fire 2, stop
    assert plan.counts() == [{"site": "dispatch", "matched": 5, "fired": 2}]


def test_fault_plan_rate_is_seeded_deterministic():
    draws = []
    for _ in range(2):
        plan = FaultPlan([FaultSpec(site="dispatch", times=None, rate=0.5)], seed=7)
        draws.append([plan.fires("dispatch", {}) is not None for _ in range(32)])
    assert draws[0] == draws[1]
    assert any(draws[0]) and not all(draws[0])


def test_inject_raises_only_while_armed_and_suspends():
    faults.check("dispatch")  # unarmed: no-op
    plan = FaultPlan([FaultSpec(site="dispatch", times=None)])
    with faults.inject(plan):
        with faults.suspended():
            faults.check("dispatch")  # suspended: no-op, budget untouched
        with pytest.raises(InjectedFault) as ei:
            faults.check("dispatch", family="X")
        assert ei.value.site == "dispatch" and ei.value.attrs["family"] == "X"
    faults.check("dispatch")  # disarmed again
    assert plan.counts()[0]["fired"] == 1


# ---------------------------------------------------------------------------
# resilience.py units: policy, backoff, breakers
# ---------------------------------------------------------------------------


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff_mult"):
        RetryPolicy(backoff_mult=0.5)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=2.0)
    with pytest.raises(ValueError, match="timeout_s"):
        RetryPolicy(timeout_s=0.0)
    assert SINGLE_ATTEMPT.max_attempts == 1


def test_backoff_schedule_and_deterministic_jitter():
    p = RetryPolicy(backoff_s=0.01, backoff_mult=2.0, max_backoff_s=0.05, jitter=0.0)
    assert p.backoff(1) == pytest.approx(0.01)
    assert p.backoff(2) == pytest.approx(0.02)
    assert p.backoff(10) == pytest.approx(0.05)  # capped
    j = RetryPolicy(backoff_s=0.01, jitter=0.5)
    a, b = j.backoff(2, seed="rid-9"), j.backoff(2, seed="rid-9")
    assert a == b  # same (seed, attempt) -> same jitter, rerun-reproducible
    assert j.backoff(2, seed="rid-9") != j.backoff(2, seed="rid-10")
    assert 0.01 <= a <= 0.03 or 0.005 <= a <= 0.03


def test_retry_policy_rides_spec_round_trip(rng):
    pol = RetryPolicy(max_attempts=5, timeout_s=2.0)
    spec = _fl_spec(rng)
    with_retry = SelectionSpec(spec.fn, spec.budget, retry=pol)
    assert with_retry.retry == pol
    assert with_retry.static_key != spec.static_key  # retry is spec identity
    back = SelectionSpec.from_dict(with_retry.to_dict())
    assert back.retry == pol


def test_circuit_breaker_transitions():
    clock = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=lambda: clock[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed"  # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clock[0] = 11.0
    assert br.allow() and br.state == "half_open"  # probe passes
    br.record_failure()
    assert br.state == "open"  # failed probe: fresh cooldown
    clock[0] = 22.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.allow()


def test_breaker_board_labels_and_listener():
    seen = []
    board = BreakerBoard(threshold=1, cooldown_s=600.0)
    board.bind(lambda label, state: seen.append((label, state)))
    key = ("FacilityLocation", "kernel")
    assert board.allow(key)
    board.record_failure(key)
    assert not board.allow(key)
    assert seen == [("FacilityLocation/kernel", "open")]
    assert board.states() == {"FacilityLocation/kernel": "open"}


# ---------------------------------------------------------------------------
# The fault matrix: every fault class x {sync, async, session} x on/off mesh.
# A transient (times=1) fault at each boundary; every rid must resolve to
# exactly one response, bit-identical to sequential solve().
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh_on", [False, True], ids=["nomesh", "mesh1x1"])
@pytest.mark.parametrize("route", ["sync", "async", "session"])
@pytest.mark.parametrize("site", ["dispatch", "padder", "kernel"])
def test_fault_matrix_every_rid_resolves_bit_identical(rng, site, route, mesh_on):
    use_kernel = site == "kernel"  # the kernel boundary needs a fused backend
    specs = [
        _fl_spec(rng, n=32, budget=4, use_kernel=use_kernel),
        _fl_spec(rng, n=32, budget=3, use_kernel=use_kernel),
    ]
    expected = [solve(s) for s in specs]  # outside the armed plan
    mesh = _mesh1x1() if mesh_on else None
    server = SelectionServer(mesh=mesh, retry_policy=POLICY)
    plan = FaultPlan([FaultSpec(site=site, times=1)])

    if route == "sync":
        rids = [server.submit_spec(s) for s in specs]
        with faults.inject(plan):
            out = server.flush()
        assert not server.take_failures()
        assert sorted(out) == sorted(rids)  # exactly once each
        for rid, want in zip(rids, expected):
            _same(want, out[rid])
    elif route == "async":
        with AsyncSelectionServer(
            server, max_pending=100, flush_interval=600.0
        ) as front:
            with faults.inject(plan):
                futures = [front.submit(s) for s in specs]
                for _ in range(4):  # padder faults need a re-drain round
                    front.flush_now()
                    if all(f.done() for f in futures):
                        break
                responses = [f.result(timeout=60) for f in futures]
        for want, resp in zip(expected, responses):
            _same(want, resp)
    else:  # session
        f0 = rng.uniform(0, 1, size=(12, 6)).astype(np.float32)
        d1 = rng.uniform(0, 1, size=(6, 6)).astype(np.float32)
        base = SelectionSpec(
            FeatureBased.from_features(f0, concave="sqrt", use_kernel=use_kernel),
            5,
            retry=POLICY,
        )
        session = server.open_session(base)
        with faults.inject(plan):
            upd = session.extend(features=d1)
        want = solve(
            SelectionSpec(
                FeatureBased.from_features(
                    np.concatenate([f0, d1]), concave="sqrt", use_kernel=use_kernel
                ),
                5,
            )
        )
        assert upd.selection == want.as_list()
        assert int(upd.result.n_evals) == int(want.n_evals)
    assert plan.counts()[0]["fired"] == 1  # the fault really hit live code
    assert server.metrics.counters["flush_errors"] >= 1
    assert server.metrics.counters["quarantined_total"] == 0


@pytest.mark.parametrize("mesh_on", [False, True], ids=["nomesh", "mesh1x1"])
def test_fault_matrix_session_extend_boundary(rng, mesh_on):
    """The session-extend fault fires BEFORE the delta is built: the stream
    is untouched, a client retry absorbs the delta exactly once."""
    mesh = _mesh1x1() if mesh_on else None
    server = SelectionServer(mesh=mesh, retry_policy=POLICY)
    f0 = rng.uniform(0, 1, size=(12, 6)).astype(np.float32)
    d1 = rng.uniform(0, 1, size=(6, 6)).astype(np.float32)
    base = SelectionSpec(FeatureBased.from_features(f0, concave="sqrt"), 5)
    session = server.open_session(base, sid="sx")
    with faults.inject(FaultPlan([FaultSpec(site="session-extend", session="sx")])):
        with pytest.raises(InjectedFault):
            session.extend(features=d1)
        assert session._seq == 0  # stream untouched: the delta did not commit
        upd = session.extend(features=d1)  # client retry
    want = solve(
        SelectionSpec(
            FeatureBased.from_features(np.concatenate([f0, d1]), concave="sqrt"), 5
        )
    )
    assert upd.seq == 1 and upd.selection == want.as_list()


# ---------------------------------------------------------------------------
# Quarantine, isolation, fallback, timeout
# ---------------------------------------------------------------------------


def test_poison_quarantined_without_repoisoning_group(rng):
    """A persistently-failing request fails typed after max_attempts; its
    co-traveller in the SAME wave still gets its bit-identical answer."""
    server = SelectionServer(retry_policy=POLICY)
    sa, sb = _fl_spec(rng), _fl_spec(rng, budget=5)
    ra, rb = server.submit_spec(sa), server.submit_spec(sb)
    want_b = solve(sb)
    with faults.inject(FaultPlan([FaultSpec(site="dispatch", rid=ra, times=None)])):
        out = server.flush()
    assert rb in out and ra not in out
    _same(want_b, out[rb])
    fails = server.take_failures()
    assert set(fails) == {ra}
    err = fails[ra]
    assert isinstance(err, RequestFailed) and err.reason == "quarantined"
    assert len(err.attempts) == POLICY.max_attempts  # full history carried
    assert err.attempts[0]["attempt"] == 1 and "InjectedFault" in err.attempts[0]["error"]
    assert server.take_failures() == {}  # delivered exactly once
    assert server.metrics.counters["quarantined_total"] == 1


def test_kernel_breaker_trips_pallas_to_xla_fallback(rng):
    """Persistent kernel faults open the (family, kernel) breaker; dispatch
    reroutes use_kernel=False and the degraded result is bit-identical."""
    spec = _fl_spec(rng, use_kernel=True)
    want = solve(spec)
    server = SelectionServer(retry_policy=POLICY, breakers=BreakerBoard(threshold=1))
    rid = server.submit_spec(spec)
    with faults.inject(
        FaultPlan([FaultSpec(site="kernel", backend="pallas-*", times=None)])
    ):
        out = server.flush()
    resp = out[rid]
    _same(want, resp)
    assert resp.backend == "xla" and resp.degraded == "xla"
    assert server.breakers.states() == {"FacilityLocation/kernel": "open"}
    assert server.stats.snapshot()["breakers"] == {"FacilityLocation/kernel": "open"}
    assert server.stats.summary()["breaker_state"] == {
        "FacilityLocation/kernel": "open"
    }
    assert server.metrics.counters["fallbacks_total"] >= 1
    assert not server.take_failures()


def test_mesh_breaker_trips_to_single_device_fallback(rng):
    """Persistent dispatch faults ON the mesh open the (family, mesh)
    breaker; the wave re-dispatches single-device, bit-identical."""
    spec = _fl_spec(rng)
    want = solve(spec)
    server = SelectionServer(
        mesh=_mesh1x1(), retry_policy=POLICY, breakers=BreakerBoard(threshold=1)
    )
    rid = server.submit_spec(spec)
    with faults.inject(
        FaultPlan([FaultSpec(site="dispatch", mesh=True, times=None)])
    ):
        out = server.flush()
    resp = out[rid]
    _same(want, resp)
    assert resp.degraded == "single-device"
    assert server.breakers.states()["FacilityLocation/mesh"] == "open"
    assert not server.take_failures()


def test_timeout_s_fails_typed_instead_of_retrying(rng):
    server = SelectionServer(
        retry_policy=RetryPolicy(max_attempts=100, backoff_s=0.0, jitter=0.0,
                                 timeout_s=0.001)
    )
    rid = server.submit_spec(_fl_spec(rng))
    with faults.inject(
        FaultPlan([FaultSpec(site="dispatch", times=1, delay_s=0.01)])
    ):
        out = server.flush()
    assert rid not in out
    fails = server.take_failures()
    assert fails[rid].reason == "timeout"
    assert len(fails[rid].attempts) == 1  # the budget lapsed, no retry storm


def test_legacy_flush_error_contract_without_policy(rng):
    """No RetryPolicy anywhere: flush() keeps the single-attempt FlushError
    semantics exactly (the pre-resilience contract other tests pin)."""
    from repro.launch.serve import FlushError

    server = SelectionServer()
    rid = server.submit_spec(_fl_spec(rng))
    with faults.inject(FaultPlan([FaultSpec(site="dispatch", times=1)])):
        with pytest.raises(FlushError) as ei:
            server.flush()
    assert ei.value.failed_rids == [rid]
    out = server.flush()  # requeued by the failed flush; next one serves it
    assert rid in out


def test_per_request_retry_policy_beats_server_default(rng):
    """spec.retry wins over the server-wide policy: a 1-attempt spec
    quarantines immediately while the server default would have retried."""
    server = SelectionServer(retry_policy=POLICY)
    spec = SelectionSpec(_fl_spec(rng).fn, 4, retry=SINGLE_ATTEMPT)
    rid = server.submit_spec(spec)
    with faults.inject(FaultPlan([FaultSpec(site="dispatch", times=None)])):
        out = server.flush()
    assert rid not in out
    fails = server.take_failures()
    assert fails[rid].reason == "quarantined" and len(fails[rid].attempts) == 1


# ---------------------------------------------------------------------------
# Async front end: typed failures resolve futures, nothing strands
# ---------------------------------------------------------------------------


def test_async_quarantine_resolves_future_with_typed_error(rng):
    server = SelectionServer(retry_policy=POLICY)
    sa, sb = _fl_spec(rng), _fl_spec(rng, budget=5)
    want_b = solve(sb)
    with AsyncSelectionServer(server, max_pending=100, flush_interval=600.0) as front:
        fa = front.submit(sa)
        fb = front.submit(sb)
        ra = next(iter([rid for rid, f in front._futures.items() if f is fa]))
        with faults.inject(
            FaultPlan([FaultSpec(site="dispatch", rid=ra, times=None)])
        ):
            front.flush_now()
        with pytest.raises(RequestFailed) as ei:
            fa.result(timeout=60)
        assert ei.value.reason == "quarantined"
        _same(want_b, fb.result(timeout=60))  # co-traveller survived
    assert server.metrics.counters["quarantined_total"] == 1


# ---------------------------------------------------------------------------
# Crash-safe sessions: journal + restore, bit-identical replay
# ---------------------------------------------------------------------------


def test_session_journal_restore_bit_identical_features(rng, tmp_path):
    journal = SessionJournal(tmp_path / "journal")
    f0 = rng.uniform(0, 1, size=(16, 12)).astype(np.float32)
    spec = SelectionSpec(FeatureBased.from_features(f0, concave="sqrt"), 5)
    server = SelectionServer()
    session = server.open_session(spec, sid="alpha", journal=journal)
    for shape in [(8, 12), (4, 12), (2, 12)]:
        upd = session.extend(
            features=rng.uniform(0, 1, size=shape).astype(np.float32)
        )
    # "crash": a NEW server restores from the journal alone (plus base spec)
    server2 = SelectionServer()
    restored = restore_sessions(server2, journal, {"alpha": spec})
    r = restored["alpha"]
    assert r.sid == "alpha" and r._seq == 3 and r.mode == "features"
    assert r.last_update.selection == upd.selection
    assert int(r.last_update.result.n_evals) == int(upd.result.n_evals)
    assert r.deltas_absorbed == 3 and r.churn_total == session.churn_total
    # a post-restore delta journals as step 4 and matches a direct solve
    d4 = rng.uniform(0, 1, size=(3, 12)).astype(np.float32)
    u4 = r.extend(features=d4)
    assert [d["seq"] for d in journal.deltas("alpha")] == [1, 2, 3, 4]
    assert u4.seq == 4


def test_session_journal_restore_indices_mode(rng, tmp_path):
    journal = SessionJournal(tmp_path / "journal")
    x = rng.normal(size=(24, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    spec = SelectionSpec(FacilityLocation.from_kernel(S), 4)
    server = SelectionServer()
    session = server.open_session(spec, sid="idx", journal=journal)
    session.extend(indices=[3, 1, 8, 3])  # dup journaled raw, dedup on replay
    upd = session.extend(indices=[5, 2, 19, 11])
    server2 = SelectionServer()
    r = restore_sessions(server2, journal, {"idx": spec})["idx"]
    assert r.mode == "indices" and r._active == session._active
    assert r.last_update.selection == upd.selection


def test_restore_sessions_requires_base_spec(rng, tmp_path):
    journal = SessionJournal(tmp_path / "journal")
    f0 = rng.uniform(0, 1, size=(8, 4)).astype(np.float32)
    spec = SelectionSpec(FeatureBased.from_features(f0), 3)
    server = SelectionServer()
    server.open_session(spec, sid="orphan", journal=journal).extend(
        features=rng.uniform(0, 1, size=(2, 4)).astype(np.float32)
    )
    with pytest.raises(KeyError, match="orphan"):
        restore_sessions(SelectionServer(), journal, {})


def test_journal_append_is_atomic_against_partial_step(rng, tmp_path):
    """A torn write (leftover .tmp dir from a crash mid-append) is invisible
    to replay: only published steps count."""
    journal = SessionJournal(tmp_path / "journal")
    f0 = rng.uniform(0, 1, size=(8, 4)).astype(np.float32)
    spec = SelectionSpec(FeatureBased.from_features(f0), 3)
    server = SelectionServer()
    s = server.open_session(spec, sid="torn", journal=journal)
    s.extend(features=rng.uniform(0, 1, size=(2, 4)).astype(np.float32))
    # simulate a crash mid-append of delta 2
    (tmp_path / "journal" / "torn" / "step_0000000002.tmp").mkdir()
    assert [d["seq"] for d in journal.deltas("torn")] == [1]
    r = restore_sessions(SelectionServer(), journal, {"torn": spec})["torn"]
    assert r._seq == 1


# ---------------------------------------------------------------------------
# Metrics: decorrelated reservoirs, resilience counters
# ---------------------------------------------------------------------------


def test_histogram_reservoirs_are_decorrelated_per_metric():
    """Identical streams into two ServerMetrics histograms must not retain
    identical samples (the shared-seed bug: every reservoir evicted the
    same slots on the same ticks)."""
    from repro.launch.metrics import ServerMetrics

    m = ServerMetrics(reservoir_size=8)
    for v in range(512):
        m.queue_s.record(float(v))
        m.wave_s.record(float(v))
    a = sorted(m.queue_s._reservoir._sample)
    b = sorted(m.wave_s._reservoir._sample)
    assert a != b
    # ...and reproducible: a fresh server retains the exact same samples
    m2 = ServerMetrics(reservoir_size=8)
    for v in range(512):
        m2.queue_s.record(float(v))
    assert sorted(m2.queue_s._reservoir._sample) == a


def test_resilience_counters_have_stable_keys(rng):
    server = SelectionServer(retry_policy=POLICY)
    rid = server.submit_spec(_fl_spec(rng))
    with faults.inject(FaultPlan([FaultSpec(site="dispatch", times=1)])):
        out = server.flush()
    assert rid in out and out[rid].attempts == 2
    snap = server.stats.snapshot()
    for key in ("retries_total", "fallbacks_total", "quarantined_total"):
        assert key in snap["counters"]
    assert snap["counters"]["retries_total"] == 1
    summary = server.stats.summary()
    for key in (
        "retries_total",
        "fallbacks_total",
        "quarantined_total",
        "breaker_state",
    ):
        assert key in summary
