"""Deterministic fallback for ``hypothesis`` in minimal environments.

The property-test modules import hypothesis as:

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ModuleNotFoundError:
        from _propcheck import given, settings, st

When hypothesis is installed the real library is used unchanged.  When it is
not, this shim replays each ``@given`` test over a seeded parameter grid:
``max_examples`` draws from the declared strategies, seeded per-test from a
stable hash of the test name, so failures reproduce run-to-run.  Only the
strategy surface the suite actually uses is implemented (``integers``,
``floats``, ``data``).
"""
from __future__ import annotations

import functools
import hashlib
import inspect

import numpy as np

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def sample(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value, self.max_value = min_value, max_value

    def sample(self, rng):
        # hypothesis bounds are inclusive on both ends
        return int(rng.integers(self.min_value, self.max_value + 1))


class _Floats(_Strategy):
    def __init__(self, min_value: float, max_value: float):
        self.min_value, self.max_value = min_value, max_value

    def sample(self, rng):
        return float(rng.uniform(self.min_value, self.max_value))


class _Data(_Strategy):
    pass


class DataObject:
    """Interactive draw handle mirroring hypothesis' ``st.data()``."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.sample(self._rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Floats:
        return _Floats(min_value, max_value)

    @staticmethod
    def data() -> _Data:
        return _Data()


st = _Strategies()


def _stable_seed(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "big")


def given(**strategies):
    """Replay the test over a deterministic grid of strategy draws."""

    def decorate(test_fn):
        @functools.wraps(test_fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_pc_max_examples", _DEFAULT_MAX_EXAMPLES)
            base = _stable_seed(test_fn.__qualname__)
            for example in range(n):
                rng = np.random.default_rng((base, example))
                drawn = {
                    name: DataObject(rng) if isinstance(strat, _Data) else strat.sample(rng)
                    for name, strat in strategies.items()
                }
                test_fn(*args, **kwargs, **drawn)

        # hide strategy-bound parameters from pytest's fixture resolution
        sig = inspect.signature(test_fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values() if p.name not in strategies]
        )
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record max_examples on the (given-wrapped) test; deadline is a no-op."""

    def decorate(fn):
        fn._pc_max_examples = max_examples
        return fn

    return decorate
