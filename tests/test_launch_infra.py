"""Launch/dry-run infrastructure units: HLO collective parser, sharding
rules (divisibility filter, policy), roofline math, serve engine, and the
distributed stochastic greedy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed.sharding import (
    auto_policy,
    axis_size,
    data_axes,
    filter_divisible,
    param_specs,
)
from repro.launch.dryrun import collective_bytes_from_hlo
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import SHAPES, cell_applicable, input_specs


def test_collective_parser_counts_result_bytes():
    hlo = """
  %x = f32[16,1024]{1,0} parameter(0)
  %ag = f32[256,1024]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[512]{0} all-reduce(%y), to_apply=%sum
  %start = f32[64]{0} all-reduce-start(%z)
  %done = f32[64]{0} all-reduce-done(%start)
  ROOT %t = (f32[8]{0}) tuple(%ag)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 256 * 1024 * 4
    # -done excluded, -start counted once
    assert out["all-reduce"] == 512 * 2 + 64 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["counts"]["all-reduce"] == 2


def test_filter_divisible_drops_uneven_axes():
    mesh = make_test_mesh((1, 1), ("data", "model"))
    # model axis size 1 always divides; fake a larger mesh via axis_size math
    spec = filter_divisible(P("model", "data"), (28, 64), mesh)
    assert spec == P("model", "data")  # size-1 axes divide everything
    assert axis_size(mesh, ("data", "model")) == 1


def test_param_specs_policies():
    mesh = make_test_mesh((1, 1), ("data", "model"))
    params = {
        "layers": {"attn": {"wq": jnp.zeros((2, 64, 64))}},
        "m": {"layers": {"attn": {"wq": jnp.zeros((2, 64, 64))}}},
    }
    fsdp = param_specs(params, mesh, "fsdp")
    dp = param_specs(params, mesh, "dp")
    # dp policy: compute weights replicated, optimizer moments still sharded
    assert dp["layers"]["attn"]["wq"] == P(None, None, None)
    assert dp["m"]["layers"]["attn"]["wq"] == fsdp["m"]["layers"]["attn"]["wq"]


def test_auto_policy_thresholds():
    assert auto_policy(get_config("whisper-small").param_count()) == "dp"
    assert auto_policy(get_config("qwen3-0.6b").param_count()) == "dp"
    assert auto_policy(get_config("mamba2-370m").param_count()) == "dp"
    assert auto_policy(get_config("kimi-k2-1t-a32b").param_count()) == "fsdp"
    assert auto_policy(get_config("command-r-plus-104b").param_count()) == "fsdp"


def test_cell_applicability_rules():
    # long_500k only for sub-quadratic archs
    assert cell_applicable("mamba2-370m", "long_500k")
    assert cell_applicable("jamba-1.5-large-398b", "long_500k")
    for arch in ("qwen3-0.6b", "kimi-k2-1t-a32b", "whisper-small"):
        assert not cell_applicable(arch, "long_500k")
        assert cell_applicable(arch, "train_4k")
    # 32 applicable model cells total
    from repro.configs.archs import ALL_ARCHS

    n = sum(
        1 for a in ALL_ARCHS for s in SHAPES if cell_applicable(a, s)
    )
    assert n == 32


def test_input_specs_shapes():
    cfg = get_config("whisper-small")
    spec = input_specs(cfg, SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096)
    assert spec["frames"].shape == (256, 1500, 768)
    spec = input_specs(get_config("qwen2-vl-7b"), SHAPES["prefill_32k"])
    assert spec["patches"].shape == (32, 256, 3584)
    spec = input_specs(get_config("qwen3-0.6b"), SHAPES["decode_32k"])
    assert spec["tokens"].shape == (128, 1)


def test_roofline_model_flops():
    import json

    from benchmarks.roofline import _model_flops

    rec = {
        "arch": "qwen3-0.6b",
        "params_active": 596_000_000,
        "n_devices": 256,
    }
    cell = {"kind": "train", "seq_len": 4096, "global_batch": 256}
    mf = _model_flops(rec, cell)
    expect = 6 * 596e6 * 4096 * 256 / 256
    np.testing.assert_allclose(mf, expect, rtol=1e-3)


def test_distributed_stochastic_greedy_quality(rng):
    from repro.core import FacilityLocation, create_kernel, naive_greedy
    from repro.core.optimizers.distributed import (
        distributed_stochastic_fl_greedy,
    )
    from repro.common import mask_from_indices

    cents = rng.normal(scale=4, size=(8, 6))
    x = (cents[rng.integers(0, 8, 256)] + rng.normal(scale=0.5, size=(256, 6))).astype(
        np.float32
    )
    S = np.asarray(create_kernel(x, metric="euclidean"))
    mesh = make_test_mesh((1, 1), ("data", "model"))
    ref = naive_greedy(FacilityLocation.from_kernel(S), 16)
    order, _ = distributed_stochastic_fl_greedy(
        S, 16, mesh, jax.random.PRNGKey(0), sample_per_shard=48
    )
    fn = FacilityLocation.from_kernel(S)
    got = float(fn.evaluate(mask_from_indices(jnp.asarray(np.asarray(order)), 256)))
    assert got >= 0.97 * float(ref.value)


def test_selection_server_serves(rng):
    """launch/serve.py front door: a mixed batch of requests comes back with
    correct per-request selections (deep serving coverage: test_serving.py)."""
    from repro.core import FacilityLocation, create_kernel, maximize
    from repro.launch.serve import SelectionServer

    server = SelectionServer()
    fns = []
    for n in (20, 28):
        x = rng.normal(size=(n, 6)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        fns.append(FacilityLocation.from_kernel(S))
    responses = server.select([(fns[0], 4), (fns[1], 6)])
    for fn, budget, resp in zip(fns, (4, 6), responses):
        assert resp.selection == maximize(fn, budget)
    assert server.stats.requests == 2
