"""AsyncSelectionServer: flush triggers, futures, and the serving contract.

Fast-tier by design (the satellite requirement): the queue-depth and timer
triggers are exercised with tiny instances, and every async response is
pinned bit-identical to sequential ``solve(spec)`` — the same contract the
synchronous server carries.
"""
import asyncio
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import FacilityLocation, SelectionSpec, create_kernel, solve
from repro.launch.async_serve import AsyncSelectionServer
from repro.launch.serve import SelectionServer


def _spec(rng, n=32, budget=4, **kw):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return SelectionSpec(FacilityLocation.from_kernel(S), budget, **kw)


def _same(seq, resp):
    got = resp.result
    assert list(np.asarray(seq.order)) == list(np.asarray(got.order))
    np.testing.assert_array_equal(np.asarray(seq.gains), np.asarray(got.gains))
    assert int(seq.n_evals) == int(got.n_evals)


def test_queue_depth_trigger_flushes_without_timer(rng):
    """max_pending reached -> flush, even though the timer is far away."""
    specs = [_spec(rng) for _ in range(3)]
    with AsyncSelectionServer(max_pending=3, flush_interval=600.0) as server:
        t0 = time.monotonic()
        futures = [server.submit(s) for s in specs]
        responses = [f.result(timeout=300) for f in futures]
        assert time.monotonic() - t0 < 600  # did not wait for the timer
        assert server.flushes >= 1
    for s, r in zip(specs, responses):
        _same(solve(s), r)
    # depth-triggered requests coalesce: same-shape specs rode ONE wave
    assert responses[0].wave_size == 3


def test_timer_trigger_flushes_lone_request(rng):
    """A lone request must not be stranded below max_pending."""
    spec = _spec(rng)
    with AsyncSelectionServer(max_pending=100, flush_interval=0.05) as server:
        fut = server.submit(spec)
        resp = fut.result(timeout=300)  # timer fires, future completes
        assert server.flushes >= 1
    _same(solve(spec), resp)


def test_flush_now_manual_trigger(rng):
    spec = _spec(rng)
    with AsyncSelectionServer(max_pending=100, flush_interval=600.0) as server:
        fut = server.submit(spec)
        assert server.pending == 1
        server.flush_now()
        assert server.pending == 0
        _same(solve(spec), fut.result(timeout=60))


def test_mixed_workload_bit_identical(rng):
    """Heterogeneous specs (sizes, budgets, optimizers) through the async
    front end: the coalescer groups them exactly as sync serving does and
    every response equals sequential solve — ids, gains, and n_evals, even
    for the off-bucket n=24 request.  The three specs land
    in three different groups, so each flushes on its own timer trigger —
    the continuous-batching path."""
    specs = [
        _spec(rng, n=32, budget=4),
        _spec(rng, n=32, budget=6, optimizer="LazyGreedy", screen_k=4),
        _spec(rng, n=24, budget=3),
    ]
    with AsyncSelectionServer(max_pending=len(specs),
                              flush_interval=0.05) as server:
        futures = [server.submit(s) for s in specs]
        responses = [f.result(timeout=300) for f in futures]
    for s, r in zip(specs, responses):
        _same(solve(s), r)


def test_close_flushes_pending(rng):
    spec = _spec(rng)
    server = AsyncSelectionServer(max_pending=100, flush_interval=600.0)
    fut = server.submit(spec)
    server.close()  # default: drain, don't strand
    _same(solve(spec), fut.result(timeout=0))
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(spec)
    server.close()  # idempotent


def test_close_without_flush_cancels(rng):
    server = AsyncSelectionServer(max_pending=100, flush_interval=600.0)
    fut = server.submit(_spec(rng))
    server.close(flush=False)
    assert fut.cancelled()


def test_submit_validation_is_synchronous(rng):
    """Bad requests fail in the caller, immediately — same rejections as the
    sync server — and never consume a future or poison a flush."""
    from repro.core import DisparityMinSum

    d = rng.uniform(0.1, 1.0, size=(8, 8)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    with AsyncSelectionServer(max_pending=100, flush_interval=600.0) as server:
        with pytest.raises(NotImplementedError, match="register_padder"):
            server.submit(SelectionSpec(DisparityMinSum.from_distance(d), 2))
        with pytest.raises(ValueError, match="batched-capable"):
            server.submit(_spec(rng, optimizer="StochasticGreedy"))
        ok = server.submit(_spec(rng))
        server.flush_now()
        assert ok.result(timeout=60).selection


def test_flush_failure_propagates_to_futures(rng):
    """A dispatch error must complete every pending future exceptionally —
    a stranded future is a hung client.  The engine's ORIGINAL exception is
    what surfaces (via FlushError.__cause__), not a serving wrapper."""
    class Boom(RuntimeError):
        pass

    class ExplodingServer(SelectionServer):
        def _dispatch(self, wave):
            raise Boom("engine on fire")

    with AsyncSelectionServer(ExplodingServer(), max_pending=100,
                              flush_interval=600.0) as server:
        fut = server.submit(_spec(rng))
        server.flush_now()
        with pytest.raises(Boom):
            fut.result(timeout=60)


def test_wrapped_server_sync_requests_are_not_dropped(rng):
    """Wrapping an existing SelectionServer that already has a sync request
    pending: the async flush answers it too, and must re-hold its response
    for the sync caller's own flush() instead of discarding it."""
    sync = SelectionServer()
    early = _spec(rng, n=16, budget=3)
    rid_early = sync.submit_spec(early)
    with AsyncSelectionServer(sync, max_pending=100,
                              flush_interval=600.0) as front:
        fut = front.submit(_spec(rng, n=24, budget=4))
        front.flush_now()
        assert fut.result(timeout=60).selection
        held = sync.flush()  # the sync request's answer surfaces here
        assert held[rid_early].selection == solve(early).as_list()


def test_futures_are_awaitable(rng):
    spec = _spec(rng)

    async def roundtrip(server):
        return await asyncio.wrap_future(server.submit(spec))

    with AsyncSelectionServer(max_pending=1, flush_interval=600.0) as server:
        resp = asyncio.run(roundtrip(server))
    _same(solve(spec), resp)


def test_constructor_validation():
    with pytest.raises(ValueError, match="max_pending"):
        AsyncSelectionServer(max_pending=0)
    with pytest.raises(ValueError, match="flush_interval"):
        AsyncSelectionServer(flush_interval=0.0)


def test_async_path_emits_no_deprecation_warnings(rng):
    spec = _spec(rng)
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        with AsyncSelectionServer(max_pending=1) as server:
            server.submit(spec).result(timeout=300)
    assert not [w for w in record if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# Per-group continuous batching, backpressure, deadlines, failure discipline.
# ---------------------------------------------------------------------------


def test_per_group_depth_trigger_flushes_only_that_group(rng):
    """The depth trigger is per (family, n-bucket) group: two same-shape
    requests flush the moment their group fills, while a request in another
    group keeps waiting for ITS co-travellers — continuous batching, not a
    global flush."""
    fl_specs = [_spec(rng, n=32) for _ in range(2)]
    other = _spec(rng, n=24)  # different padded shapes -> different group
    with AsyncSelectionServer(max_pending=2, flush_interval=600.0) as server:
        f_other = server.submit(other)
        futures = [server.submit(s) for s in fl_specs]
        responses = [f.result(timeout=300) for f in futures]
        assert all(r.wave_size == 2 for r in responses)
        assert not f_other.done()  # its group never hit the depth trigger
        server.flush_now()
        r_other = f_other.result(timeout=300)
        assert r_other.wave_size == 1
    for s, r in zip(fl_specs, responses):
        _same(solve(s), r)
    # the n=24 request pads to its 32 bucket, yet ids/gains AND n_evals are
    # bit-identical to sequential solve — engines count logical evaluations
    _same(solve(other), r_other)


def test_submit_does_not_block_behind_executing_wave(rng):
    """The head-of-line-blocking fix: dispatch runs OUTSIDE the condition
    lock, so a submit arriving mid-wave returns immediately instead of
    waiting out the wave's wall time."""
    import threading

    started, release = threading.Event(), threading.Event()

    class SlowServer(SelectionServer):
        def _dispatch(self, wave):
            started.set()
            assert release.wait(timeout=60)
            return super()._dispatch(wave)

    with AsyncSelectionServer(SlowServer(), max_pending=1,
                              flush_interval=600.0) as server:
        f1 = server.submit(_spec(rng))
        assert started.wait(timeout=60)  # wave 1 is now executing
        t0 = time.monotonic()
        f2 = server.submit(_spec(rng))
        submit_s = time.monotonic() - t0
        release.set()
        assert submit_s < 1.0, f"submit blocked {submit_s:.2f}s behind the wave"
        assert f1.result(timeout=300).selection
        assert f2.result(timeout=300).selection


def test_deadline_pulls_flush_ahead_of_interval(rng):
    """A spec-level deadline_s caps how long its group waits for
    co-travellers: the flush fires at the deadline, far ahead of a long
    flush_interval."""
    spec = _spec(rng, deadline_s=0.2)
    with AsyncSelectionServer(max_pending=100, flush_interval=600.0) as server:
        t0 = time.monotonic()
        resp = server.submit(spec).result(timeout=300)
        waited = time.monotonic() - t0
    assert waited < 60, f"deadline did not pull the flush ({waited:.1f}s)"
    assert resp.queue_s < 60
    assert isinstance(resp.deadline_missed, bool)
    _same(solve(spec), resp)


def test_submit_backpressure_rejects_then_recovers(rng):
    from repro.launch.serve import ServerOverloaded

    with AsyncSelectionServer(max_pending=100, flush_interval=600.0,
                              max_queue=2) as server:
        a, b = server.submit(_spec(rng)), server.submit(_spec(rng))
        with pytest.raises(ServerOverloaded):
            server.submit(_spec(rng))
        assert server.stats.rejections == 1
        server.flush_now()  # drains the queue: space again
        c = server.submit(_spec(rng))
        server.flush_now()
        assert all(f.result(timeout=300).selection for f in (a, b, c))


def test_submit_block_waits_for_queue_space(rng):
    """block=True turns a full-queue rejection into a wait: the submit
    parks on the condition until a drain frees space, then enqueues."""
    with AsyncSelectionServer(max_pending=2, flush_interval=600.0,
                              max_queue=2) as server:
        a, b = server.submit(_spec(rng)), server.submit(_spec(rng))
        # the depth trigger (2 pending in one group) is already draining;
        # this submit waits for that drain instead of raising
        c = server.submit(_spec(rng), block=True)
        server.flush_now()
        assert all(f.result(timeout=300).selection for f in (a, b, c))
    assert server.stats.rejections == 0


def test_poisoned_wave_fails_its_futures_and_requeues_the_rest(rng):
    """Failure discipline across a multi-group flush: the completed wave
    delivers, the poisoned wave's future raises the engine's own error, and
    the never-dispatched request is requeued with its future intact — zero
    requests and zero computed responses lost."""
    class Boom(RuntimeError):
        pass

    class PoisonServer(SelectionServer):
        def _dispatch(self, wave):
            if wave.n_bucket == 64:
                raise Boom("poisoned wave")
            return super()._dispatch(wave)

    good, poison, late = _spec(rng, n=32), _spec(rng, n=64), _spec(rng, n=16)
    with AsyncSelectionServer(PoisonServer(), max_pending=100,
                              flush_interval=600.0) as server:
        f_good = server.submit(good)
        f_poison = server.submit(poison)
        f_late = server.submit(late)
        server.flush_now()
        _same(solve(good), f_good.result(timeout=300))  # completed: delivered
        with pytest.raises(Boom):
            f_poison.result(timeout=60)  # poisoned: the engine's own error
        assert not f_late.done()  # undispatched: requeued, future intact
        assert server.pending == 1
        server.flush_now()  # the poison is gone; the survivor now serves
        _same(solve(late), f_late.result(timeout=300))
        m = server.metrics.counters
        assert m["flush_errors"] == 1
        assert m["requeued"] == 1


def test_close_without_flush_cancels_and_clears_server_queues(rng):
    """close(flush=False) under multiple pending submits: every future is
    cancelled AND the requests leave the wrapped server's queues — a later
    sync flush() must not find orphans."""
    sync = SelectionServer()
    server = AsyncSelectionServer(sync, max_pending=100, flush_interval=600.0)
    futures = [server.submit(_spec(rng)) for _ in range(3)]
    server.close(flush=False)
    assert all(f.cancelled() for f in futures)
    assert sync.pending_count == 0
    assert sync.flush() == {}


def test_flush_now_races_timer_without_double_dispatch(rng):
    """flush_now racing the timer trigger: draining is atomic under the
    condition lock, so each request dispatches exactly once no matter who
    wins."""
    specs = [_spec(rng) for _ in range(6)]
    with AsyncSelectionServer(max_pending=100, flush_interval=0.01) as server:
        futures = []
        for s in specs:
            futures.append(server.submit(s))
            server.flush_now()  # races the 10 ms timer
        responses = [f.result(timeout=300) for f in futures]
    assert server.stats.requests == len(specs)  # exactly once each
    for s, r in zip(specs, responses):
        _same(solve(s), r)


def test_close_wakes_blocked_submitter(rng):
    """A submitter parked on block=True backpressure must not hang when the
    server closes underneath it — it raises instead."""
    import threading

    server = AsyncSelectionServer(max_pending=100, flush_interval=600.0,
                                  max_queue=1)
    first = server.submit(_spec(rng))
    errors = []

    def blocked_submit():
        try:
            server.submit(_spec(rng), block=True)
        except RuntimeError as e:
            errors.append(e)

    t = threading.Thread(target=blocked_submit)
    t.start()
    time.sleep(0.1)  # let it park on the condition
    server.close(flush=False)
    t.join(timeout=60)
    assert not t.is_alive()
    assert len(errors) == 1 and "closed" in str(errors[0])
    assert first.cancelled()


# ---------------------------------------------------------------------------
# Session deltas over the async front end (launch/sessions.py edge cases).
# ---------------------------------------------------------------------------


def _session_spec(rng, n0=4, budget=3, **kw):
    from repro.core import FeatureBased

    rows = rng.uniform(0.0, 1.0, size=(n0, 6)).astype(np.float32)
    return rows, SelectionSpec(FeatureBased.from_features(rows), budget, **kw)


def test_session_extend_races_flush_now_without_double_dispatch(rng):
    """extend() racing flush_now and a hot timer: a delta's rebuilt spec
    rides exactly one wave (drain is atomic), and the final update is still
    bit-identical to one solve() over the concatenated stream."""
    from repro.core import FeatureBased

    seed, spec = _session_spec(rng)
    deltas = [rng.uniform(0.0, 1.0, size=(3, 6)).astype(np.float32)
              for _ in range(5)]
    with AsyncSelectionServer(max_pending=100, flush_interval=0.01) as server:
        session = server.open_session(spec)
        updates = []
        for d in deltas:
            fut = session.extend(features=d)
            server.flush_now()  # races the 10 ms timer
            updates.append(fut.result(timeout=300))
        session.close()
    assert server.stats.requests == len(deltas)  # exactly once each
    full = np.concatenate([seed] + deltas, axis=0)
    direct = solve(SelectionSpec(FeatureBased.from_features(full),
                                 spec.budget))
    _same(direct, updates[-1].response)


def test_close_without_flush_cancels_session_delta_futures(rng):
    """close(flush=False) with a session delta in flight: the chained
    SessionUpdate future is cancelled, not stranded — result() raises."""
    from concurrent.futures import CancelledError

    _, spec = _session_spec(rng)
    server = AsyncSelectionServer(max_pending=100, flush_interval=600.0)
    session = server.open_session(spec)
    fut = session.extend(features=np.ones((2, 6), np.float32))
    server.close(flush=False)
    assert fut.cancelled()
    with pytest.raises(CancelledError):
        fut.result(timeout=0)


def test_session_extend_hits_backpressure_and_recovers(rng):
    """ServerOverloaded on a delta submission surfaces synchronously at
    extend() time, the session stream stays uncommitted (no double-append),
    and a retry after a flush replays the SAME stream as a clean session."""
    from repro.core import FeatureBased
    from repro.launch.serve import ServerOverloaded

    seed, spec = _session_spec(rng)
    d1 = rng.uniform(0.0, 1.0, size=(3, 6)).astype(np.float32)
    d2 = rng.uniform(0.0, 1.0, size=(3, 6)).astype(np.float32)
    with AsyncSelectionServer(max_pending=100, flush_interval=600.0,
                              max_queue=1) as server:
        session = server.open_session(spec)
        f1 = session.extend(features=d1)
        with pytest.raises(ServerOverloaded):
            session.extend(features=d2)  # queue full: rejected HERE
        assert server.stats.rejections == 1
        server.flush_now()
        assert f1.result(timeout=300).n_total == seed.shape[0] + 3
        f2 = session.extend(features=d2)  # retry: delta appended ONCE
        server.flush_now()
        upd = f2.result(timeout=300)
        session.close()
    assert upd.n_total == seed.shape[0] + 6
    full = np.concatenate([seed, d1, d2], axis=0)
    direct = solve(SelectionSpec(FeatureBased.from_features(full),
                                 spec.budget))
    _same(direct, upd.response)


def test_close_joins_worker_before_final_drain(rng):
    """Regression: close(flush=True) used to drain while an in-flight
    _execute was still running on the worker thread. If that execute then
    failed its wave, _complete_partial reinstated requests AFTER close's
    final drain had already run — stranding their futures forever. close()
    must join the worker FIRST, then drain, so the final drain sees every
    requeued request."""
    from repro.core import GraphCut

    class Boom(RuntimeError):
        pass

    started = threading.Event()
    release = threading.Event()

    class BlockingPoison(SelectionServer):
        def _dispatch(self, wave):
            if wave.n_bucket == 64:
                started.set()
                assert release.wait(timeout=60)
                raise Boom("poisoned wave")
            return super()._dispatch(wave)

    fl = _spec(rng, n=64)
    x = rng.normal(size=(24, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    gc = SelectionSpec(GraphCut.from_kernel(S, lam=0.3), 4)

    server = AsyncSelectionServer(BlockingPoison(), max_pending=100,
                                  flush_interval=0.01)
    fut_fl = server.submit(fl)
    assert started.wait(timeout=60)  # worker is inside _execute now
    fut_gc = server.submit(gc)  # queued behind the in-flight wave

    closer = threading.Thread(target=server.close)  # flush=True
    closer.start()
    while not server._closed:  # close() has signalled shutdown...
        time.sleep(0.001)
    release.set()  # ...and only now may the in-flight execute fail
    closer.join(timeout=60)
    assert not closer.is_alive()

    with pytest.raises(Boom):
        fut_fl.result(timeout=60)  # poisoned: typed failure, not stranded
    _same(solve(gc), fut_gc.result(timeout=60))  # survivor: served by close
