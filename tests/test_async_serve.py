"""AsyncSelectionServer: flush triggers, futures, and the serving contract.

Fast-tier by design (the satellite requirement): the queue-depth and timer
triggers are exercised with tiny instances, and every async response is
pinned bit-identical to sequential ``solve(spec)`` — the same contract the
synchronous server carries.
"""
import asyncio
import time
import warnings

import numpy as np
import pytest

from repro.core import FacilityLocation, SelectionSpec, create_kernel, solve
from repro.launch.async_serve import AsyncSelectionServer
from repro.launch.serve import SelectionServer


def _spec(rng, n=32, budget=4, **kw):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return SelectionSpec(FacilityLocation.from_kernel(S), budget, **kw)


def _same(seq, resp):
    got = resp.result
    assert list(np.asarray(seq.order)) == list(np.asarray(got.order))
    np.testing.assert_array_equal(np.asarray(seq.gains), np.asarray(got.gains))
    assert int(seq.n_evals) == int(got.n_evals)


def test_queue_depth_trigger_flushes_without_timer(rng):
    """max_pending reached -> flush, even though the timer is far away."""
    specs = [_spec(rng) for _ in range(3)]
    with AsyncSelectionServer(max_pending=3, flush_interval=600.0) as server:
        t0 = time.monotonic()
        futures = [server.submit(s) for s in specs]
        responses = [f.result(timeout=300) for f in futures]
        assert time.monotonic() - t0 < 600  # did not wait for the timer
        assert server.flushes >= 1
    for s, r in zip(specs, responses):
        _same(solve(s), r)
    # depth-triggered requests coalesce: same-shape specs rode ONE wave
    assert responses[0].wave_size == 3


def test_timer_trigger_flushes_lone_request(rng):
    """A lone request must not be stranded below max_pending."""
    spec = _spec(rng)
    with AsyncSelectionServer(max_pending=100, flush_interval=0.05) as server:
        fut = server.submit(spec)
        resp = fut.result(timeout=300)  # timer fires, future completes
        assert server.flushes >= 1
    _same(solve(spec), resp)


def test_flush_now_manual_trigger(rng):
    spec = _spec(rng)
    with AsyncSelectionServer(max_pending=100, flush_interval=600.0) as server:
        fut = server.submit(spec)
        assert server.pending == 1
        server.flush_now()
        assert server.pending == 0
        _same(solve(spec), fut.result(timeout=60))


def test_mixed_workload_bit_identical(rng):
    """Heterogeneous specs (sizes, budgets, optimizers) through the async
    front end: the coalescer groups them exactly as sync serving does and
    every response equals sequential solve (ids/gains; n=32 requests sit at
    their bucket so n_evals compares exactly there)."""
    specs = [
        _spec(rng, n=32, budget=4),
        _spec(rng, n=32, budget=6, optimizer="LazyGreedy", screen_k=4),
        _spec(rng, n=24, budget=3),
    ]
    with AsyncSelectionServer(max_pending=len(specs),
                              flush_interval=600.0) as server:
        futures = [server.submit(s) for s in specs]
        responses = [f.result(timeout=300) for f in futures]
    for s, r in zip(specs, responses):
        seq = solve(s)
        assert r.selection == seq.as_list()
        if s.fn.n == 32:
            assert int(r.result.n_evals) == int(seq.n_evals)


def test_close_flushes_pending(rng):
    spec = _spec(rng)
    server = AsyncSelectionServer(max_pending=100, flush_interval=600.0)
    fut = server.submit(spec)
    server.close()  # default: drain, don't strand
    _same(solve(spec), fut.result(timeout=0))
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(spec)
    server.close()  # idempotent


def test_close_without_flush_cancels(rng):
    server = AsyncSelectionServer(max_pending=100, flush_interval=600.0)
    fut = server.submit(_spec(rng))
    server.close(flush=False)
    assert fut.cancelled()


def test_submit_validation_is_synchronous(rng):
    """Bad requests fail in the caller, immediately — same rejections as the
    sync server — and never consume a future or poison a flush."""
    from repro.core import DisparityMinSum

    d = rng.uniform(0.1, 1.0, size=(8, 8)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    with AsyncSelectionServer(max_pending=100, flush_interval=600.0) as server:
        with pytest.raises(NotImplementedError, match="register_padder"):
            server.submit(SelectionSpec(DisparityMinSum.from_distance(d), 2))
        with pytest.raises(ValueError, match="batched-capable"):
            server.submit(_spec(rng, optimizer="StochasticGreedy"))
        ok = server.submit(_spec(rng))
        server.flush_now()
        assert ok.result(timeout=60).selection


def test_flush_failure_propagates_to_futures(rng):
    """A dispatch error must complete every pending future exceptionally —
    a stranded future is a hung client."""
    class Boom(RuntimeError):
        pass

    class ExplodingServer(SelectionServer):
        def flush(self):
            raise Boom("engine on fire")

    with AsyncSelectionServer(ExplodingServer(), max_pending=100,
                              flush_interval=600.0) as server:
        fut = server.submit(_spec(rng))
        server.flush_now()
        with pytest.raises(Boom):
            fut.result(timeout=60)


def test_wrapped_server_sync_requests_are_not_dropped(rng):
    """Wrapping an existing SelectionServer that already has a sync request
    pending: the async flush answers it too, and must re-hold its response
    for the sync caller's own flush() instead of discarding it."""
    sync = SelectionServer()
    early = _spec(rng, n=16, budget=3)
    rid_early = sync.submit_spec(early)
    with AsyncSelectionServer(sync, max_pending=100,
                              flush_interval=600.0) as front:
        fut = front.submit(_spec(rng, n=24, budget=4))
        front.flush_now()
        assert fut.result(timeout=60).selection
        held = sync.flush()  # the sync request's answer surfaces here
        assert held[rid_early].selection == solve(early).as_list()


def test_futures_are_awaitable(rng):
    spec = _spec(rng)

    async def roundtrip(server):
        return await asyncio.wrap_future(server.submit(spec))

    with AsyncSelectionServer(max_pending=1, flush_interval=600.0) as server:
        resp = asyncio.run(roundtrip(server))
    _same(solve(spec), resp)


def test_constructor_validation():
    with pytest.raises(ValueError, match="max_pending"):
        AsyncSelectionServer(max_pending=0)
    with pytest.raises(ValueError, match="flush_interval"):
        AsyncSelectionServer(flush_interval=0.0)


def test_async_path_emits_no_deprecation_warnings(rng):
    spec = _spec(rng)
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        with AsyncSelectionServer(max_pending=1) as server:
            server.submit(spec).result(timeout=300)
    assert not [w for w in record if issubclass(w.category, DeprecationWarning)]
