"""Information-measure correctness: closed forms (Table 1) vs the generic
MI/CG/CMI combinators on the extended ground set, plus PRISM sanity
properties (eta/nu monotonicity of behaviour)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import mask_from_indices
from repro.core import (
    FLCG,
    FLCMI,
    FLQMI,
    FLVMI,
    GCMI,
    ConcaveOverModular,
    FacilityLocation,
    GraphCut,
    LogDet,
    ProbabilisticSetCover,
    SetCover,
    build_extended_kernel,
    create_kernel,
    gccg,
    generic_cg,
    generic_cmi,
    generic_mi,
    logdet_cg,
    logdet_cmi,
    logdet_mi,
    naive_greedy,
    psc_cg,
    psc_cmi,
    psc_mi,
    sc_cg,
    sc_cmi,
    sc_mi,
)

NV, NQ, NP = 12, 4, 3


@pytest.fixture()
def data(rng):
    V = rng.normal(size=(NV, 5)).astype(np.float32)
    Q = rng.normal(size=(NQ, 5)).astype(np.float32)
    P = rng.normal(size=(NP, 5)).astype(np.float32)
    return V, Q, P


def _masks(rng, n, k=4):
    idx = rng.choice(n, size=k, replace=False)
    return mask_from_indices(jnp.asarray(idx, jnp.int32), n), idx


def test_flvmi_matches_generic_mi(data, rng):
    """FLVMI == I_f(A;Q) for FL with rows over V, ground set V ∪ Q."""
    V, Q, _ = data
    Sx, q_idx, _ = build_extended_kernel(V, Q, metric="cosine")
    base = FacilityLocation.from_kernel(np.asarray(Sx)[:NV, :])  # rows = V only
    gmi = generic_mi(base, q_idx, NV)
    closed = FLVMI.build(
        np.asarray(create_kernel(V, metric="cosine")),
        np.asarray(create_kernel(V, Q, metric="cosine")),
        eta=1.0,
    )
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        np.testing.assert_allclose(
            float(gmi.evaluate(mask)), float(closed.evaluate(mask)), rtol=1e-4,
            atol=1e-5,
        )
    # greedy trajectories agree
    r1 = naive_greedy(gmi, 5)
    r2 = naive_greedy(closed, 5)
    assert [i for i, _ in r1.as_list()] == [i for i, _ in r2.as_list()]


def test_flcg_matches_generic_cg(data, rng):
    V, _, P = data
    Sx, _, p_idx = build_extended_kernel(V, private=P, metric="cosine")
    base = FacilityLocation.from_kernel(np.asarray(Sx)[:NV, :])
    gcg = generic_cg(base, p_idx, NV)
    closed = FLCG.build(
        np.asarray(create_kernel(V, metric="cosine")),
        np.asarray(create_kernel(V, P, metric="cosine")),
        nu=1.0,
    )
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        got, want = float(closed.evaluate(mask)), float(gcg.evaluate(mask))
        # FLCG's max(·,0) clamp makes it an upper bound of the true CG that
        # coincides when each row's best selected sim beats nu*pmax
        assert got >= want - 1e-4


def test_gcmi_matches_generic_mi(data, rng):
    V, Q, _ = data
    lam = 0.5
    Sx, q_idx, _ = build_extended_kernel(V, Q, metric="cosine")
    base = GraphCut.from_kernel(np.asarray(Sx), lam=lam)
    gmi = generic_mi(base, q_idx, NV)
    closed = GCMI.build(np.asarray(create_kernel(V, Q, metric="cosine")), lam=lam)
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        np.testing.assert_allclose(
            float(gmi.evaluate(mask)), float(closed.evaluate(mask)), rtol=1e-3,
            atol=1e-4,
        )


def test_gccg_matches_generic_cg(data, rng):
    V, _, P = data
    lam = 0.4
    Sx, _, p_idx = build_extended_kernel(V, private=P, metric="cosine")
    Sx = np.asarray(Sx)
    # the paper's GCCG keeps the representation (modular) term over V rows
    # only, so the generic base uses represented set = V
    base = GraphCut.from_kernel(Sx, lam=lam, sim_rep=Sx[:NV])
    gcg = generic_cg(base, p_idx, NV)
    closed = gccg(
        np.asarray(create_kernel(V, metric="cosine")),
        np.asarray(create_kernel(V, P, metric="cosine")),
        lam=lam,
        nu=1.0,
    )
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        np.testing.assert_allclose(
            float(gcg.evaluate(mask)), float(closed.evaluate(mask)), rtol=1e-3,
            atol=1e-4,
        )
    s1, s2 = gcg.init_state(), closed.init_state()
    np.testing.assert_allclose(
        np.asarray(gcg.gains(s1))[:NV], np.asarray(closed.gains(s2)), rtol=1e-3,
        atol=1e-4,
    )


def test_logdet_mi_cg_cmi_match_generic(data, rng):
    V, Q, P = data
    eps = 0.75  # diagonal boost keeps kernels well-conditioned
    Sx, q_idx, p_idx = build_extended_kernel(V, Q, P, metric="cosine")
    Sx = np.asarray(Sx) * 0.4
    np.fill_diagonal(Sx, 1.0 + eps)
    base = LogDet.from_kernel(Sx, max_select=NV + NQ + NP)
    S_vv = Sx[:NV, :NV]
    S_vq = Sx[:NV, NV : NV + NQ]
    S_qq = Sx[NV : NV + NQ, NV : NV + NQ]
    S_vp = Sx[:NV, NV + NQ :]
    S_pp = Sx[NV + NQ :, NV + NQ :]
    S_qp = Sx[NV : NV + NQ, NV + NQ :]

    gmi = generic_mi(base, q_idx, NV)
    cmi_closed = logdet_mi(S_vv, S_vq, S_qq, eta=1.0, max_select=NV)
    gcg_f = generic_cg(base, p_idx, NV)
    cg_closed = logdet_cg(S_vv, S_vp, S_pp, nu=1.0, max_select=NV)
    gcmi_f = generic_cmi(base, q_idx, p_idx, NV)
    cmi2_closed = logdet_cmi(
        S_vv, S_vq, S_qq, S_vp, S_pp, S_qp, max_select=NV
    )
    for _ in range(4):
        mask, _ = _masks(rng, NV, k=3)
        np.testing.assert_allclose(
            float(gmi.evaluate(mask)), float(cmi_closed.evaluate(mask)),
            rtol=5e-3, atol=5e-3,
        )
        np.testing.assert_allclose(
            float(gcg_f.evaluate(mask)), float(cg_closed.evaluate(mask)),
            rtol=5e-3, atol=5e-3,
        )
        np.testing.assert_allclose(
            float(gcmi_f.evaluate(mask)), float(cmi2_closed.evaluate(mask)),
            rtol=5e-3, atol=5e-3,
        )


def _sc_instance(rng):
    cover = rng.integers(0, 2, size=(NV + NQ + NP, 9)).astype(np.float32)
    w = rng.uniform(0.5, 2.0, 9).astype(np.float32)
    return cover, w


def test_sc_measures_match_generic(rng):
    cover, w = _sc_instance(rng)
    base = SetCover.from_cover(cover, w)
    q_idx = np.arange(NV, NV + NQ)
    p_idx = np.arange(NV + NQ, NV + NQ + NP)
    gmi = generic_mi(base, q_idx, NV)
    gcg_f = generic_cg(base, p_idx, NV)
    gcmi_f = generic_cmi(base, q_idx, p_idx, NV)
    mi_c = sc_mi(cover[:NV], w, cover[q_idx])
    cg_c = sc_cg(cover[:NV], w, cover[p_idx])
    cmi_c = sc_cmi(cover[:NV], w, cover[q_idx], cover[p_idx])
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        np.testing.assert_allclose(
            float(gmi.evaluate(mask)), float(mi_c.evaluate(mask)), atol=1e-5
        )
        np.testing.assert_allclose(
            float(gcg_f.evaluate(mask)), float(cg_c.evaluate(mask)), atol=1e-5
        )
        np.testing.assert_allclose(
            float(gcmi_f.evaluate(mask)), float(cmi_c.evaluate(mask)), atol=1e-5
        )


def test_psc_measures_match_generic(rng):
    probs = rng.uniform(0, 0.8, size=(NV + NQ + NP, 9)).astype(np.float32)
    w = rng.uniform(0.5, 2.0, 9).astype(np.float32)
    base = ProbabilisticSetCover.from_probs(probs, w)
    q_idx = np.arange(NV, NV + NQ)
    p_idx = np.arange(NV + NQ, NV + NQ + NP)
    gmi = generic_mi(base, q_idx, NV)
    gcg_f = generic_cg(base, p_idx, NV)
    gcmi_f = generic_cmi(base, q_idx, p_idx, NV)
    mi_c = psc_mi(probs[:NV], w, probs[q_idx])
    cg_c = psc_cg(probs[:NV], w, probs[p_idx])
    cmi_c = psc_cmi(probs[:NV], w, probs[q_idx], probs[p_idx])
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        np.testing.assert_allclose(
            float(gmi.evaluate(mask)), float(mi_c.evaluate(mask)), rtol=1e-4,
            atol=1e-5,
        )
        np.testing.assert_allclose(
            float(gcg_f.evaluate(mask)), float(cg_c.evaluate(mask)), rtol=1e-4,
            atol=1e-5,
        )
        np.testing.assert_allclose(
            float(gcmi_f.evaluate(mask)), float(cmi_c.evaluate(mask)), rtol=1e-4,
            atol=1e-5,
        )


def test_flqmi_gain_identity_and_saturation(data, rng):
    """FLQMI at eta=0 saturates per query (paper Fig. 7/10: one relevant
    pick per query, then gains collapse)."""
    V, Q, _ = data
    S_qv = np.asarray(create_kernel(Q, V, metric="cosine"))
    fn = FLQMI.build(S_qv, eta=0.0)
    r = naive_greedy(fn, 8, False, False)
    gains = [g for _, g in r.as_list()]
    # after |Q| picks the remaining representation gains are tiny
    assert gains[NQ] < 0.25 * gains[0] + 1e-6


def test_gcmi_is_pure_retrieval(data, rng):
    """GCMI ranks by query similarity alone (paper Fig. 8) — selection equals
    the top-k of the modular query-similarity scores."""
    V, Q, _ = data
    S_vq = np.asarray(create_kernel(V, Q, metric="cosine"))
    fn = GCMI.build(S_vq, lam=0.5)
    r = naive_greedy(fn, 5, False, False)
    got = [i for i, _ in r.as_list()]
    want = list(np.argsort(-S_vq.sum(axis=1))[:5])
    assert got == [int(i) for i in want]


def test_com_gain_identity(data, rng):
    V, Q, _ = data
    fn = ConcaveOverModular.build(
        np.asarray(create_kernel(V, Q, metric="cosine")), eta=0.5, concave="sqrt"
    )
    state = fn.init_state()
    mask = np.zeros(NV, bool)
    for j in [2, 7, 4]:
        g = float(fn.gains(state)[j])
        oracle = float(fn.marginal_gain(jnp.asarray(mask), j))
        np.testing.assert_allclose(g, oracle, rtol=1e-4, atol=1e-5)
        state = fn.update(state, jnp.asarray(j))
        mask[j] = True


def test_flcmi_collapses_to_flvmi_without_private(data, rng):
    V, Q, _ = data
    S = np.asarray(create_kernel(V, metric="cosine"))
    S_vq = np.asarray(create_kernel(V, Q, metric="cosine"))
    zeros = np.zeros((NV, 1), np.float32)
    cmi = FLCMI.build(S, S_vq, zeros, eta=1.0, nu=1.0)
    vmi = FLVMI.build(S, S_vq, eta=1.0)
    for _ in range(5):
        mask, _ = _masks(rng, NV)
        np.testing.assert_allclose(
            float(cmi.evaluate(mask)), float(vmi.evaluate(mask)), rtol=1e-5
        )
