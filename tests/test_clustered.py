"""Clustered mixtures (paper §8): block-masked kernel == sum of per-cluster
objectives, dense and matrix-free.

``clustered(base_from_kernel, S, labels)`` evaluates the base function on
the block-masked kernel; the §8 claim is that this EQUALS the mixture
f(A) = sum_l f_{C_l}(A ∩ C_l) of independent per-cluster functions.  The
matrix-free form (``clustered_matrix_free``) must agree without ever
materializing the kernel or the mask.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import make_points
from repro.core import (
    FacilityLocation,
    FacilityLocationMF,
    GraphCut,
    GraphCutMF,
    SelectionSpec,
    cluster_mask,
    clustered,
    clustered_matrix_free,
    create_kernel,
    solve,
)
from repro.core.optimizers.backends import full_sweep


def _setup(rng, n=30, n_clusters=3):
    x = make_points(rng, n)
    labels = rng.integers(0, n_clusters, size=n).astype(np.int32)
    S = np.asarray(create_kernel(x, metric="rbf"))
    mask = np.zeros(n, bool)
    mask[rng.choice(n, size=9, replace=False)] = True
    return x, labels, S, mask


def _per_cluster_sum(base_from_kernel, S, labels, mask, **kw):
    """sum_l f_{C_l}(A ∩ C_l), each cluster's function built independently."""
    total = 0.0
    for c in np.unique(labels):
        sel = labels == c
        fn_c = base_from_kernel(jnp.asarray(S[np.ix_(sel, sel)]), **kw)
        total += float(fn_c.evaluate(jnp.asarray(mask[sel])))
    return total


def test_clustered_fl_equals_per_cluster_sum(rng):
    _, labels, S, mask = _setup(rng)
    fn = clustered(FacilityLocation.from_kernel, S, labels)
    want = _per_cluster_sum(FacilityLocation.from_kernel, S, labels, mask)
    np.testing.assert_allclose(float(fn.evaluate(jnp.asarray(mask))), want,
                               rtol=1e-5, atol=1e-5)


def test_clustered_gc_equals_per_cluster_sum(rng):
    _, labels, S, mask = _setup(rng)
    fn = clustered(GraphCut.from_kernel, S, labels, lam=0.4)
    want = _per_cluster_sum(GraphCut.from_kernel, S, labels, mask, lam=0.4)
    np.testing.assert_allclose(float(fn.evaluate(jnp.asarray(mask))), want,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ("dot", "cosine", "rbf"))
def test_clustered_matrix_free_fl_matches_dense(rng, metric):
    x, labels, _, mask = _setup(rng)
    S = np.asarray(create_kernel(x, metric=metric))
    dense = clustered(FacilityLocation.from_kernel, S, labels)
    mf = clustered_matrix_free(
        FacilityLocationMF.from_features, x, labels, metric=metric
    )
    st_d, st_m = dense.init_state(), mf.init_state()
    np.testing.assert_allclose(
        np.asarray(full_sweep(mf, st_m)), np.asarray(full_sweep(dense, st_d)),
        rtol=2e-5, atol=2e-5,
    )
    np.testing.assert_allclose(
        float(mf.evaluate(jnp.asarray(mask))),
        float(dense.evaluate(jnp.asarray(mask))),
        rtol=2e-5, atol=2e-5,
    )
    r_d, r_m = solve(SelectionSpec(dense, 5)), solve(SelectionSpec(mf, 5))
    assert list(np.asarray(r_d.order)) == list(np.asarray(r_m.order))
    np.testing.assert_allclose(np.asarray(r_d.gains), np.asarray(r_m.gains),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("metric", ("dot", "rbf"))
def test_clustered_matrix_free_gc_matches_dense(rng, metric):
    x, labels, _, mask = _setup(rng)
    S = np.asarray(create_kernel(x, metric=metric))
    dense = clustered(GraphCut.from_kernel, S, labels, lam=0.4)
    mf = clustered_matrix_free(
        GraphCutMF.from_features, x, labels, metric=metric, lam=0.4
    )
    np.testing.assert_allclose(
        np.asarray(full_sweep(mf, mf.init_state())),
        np.asarray(full_sweep(dense, dense.init_state())),
        rtol=2e-5, atol=2e-5,
    )
    np.testing.assert_allclose(
        float(mf.evaluate(jnp.asarray(mask))),
        float(dense.evaluate(jnp.asarray(mask))),
        rtol=2e-5, atol=2e-5,
    )
    r_d, r_m = solve(SelectionSpec(dense, 4)), solve(SelectionSpec(mf, 4))
    assert list(np.asarray(r_d.order)) == list(np.asarray(r_m.order))


def test_clustered_matrix_free_solve_modes_bit_identical(rng):
    """Labeled sources ride the same serving contract as unlabeled ones."""
    x, labels, _, _ = _setup(rng, n=37)
    mf = clustered_matrix_free(
        FacilityLocationMF.from_features, x, labels, metric="rbf"
    )
    spec = SelectionSpec(mf, 5)
    seq = solve(spec)
    for got in (
        solve([spec, spec], mode="batched")[0],
        solve([spec], mode="served")[0],
    ):
        assert list(np.asarray(seq.order)) == list(np.asarray(got.order))
        np.testing.assert_array_equal(np.asarray(seq.gains), np.asarray(got.gains))
        assert int(seq.n_evals) == int(got.n_evals)


def test_cluster_mask_is_block_indicator(rng):
    labels = np.asarray([0, 1, 0, 2, 1])
    m = np.asarray(cluster_mask(labels))
    want = (labels[:, None] == labels[None, :]).astype(np.float32)
    np.testing.assert_array_equal(m, want)
