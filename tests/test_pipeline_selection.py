"""Data pipeline + submodular selection integration tests.

The headline behavioural test: FacilityLocation coreset selection over a
multi-modal synthetic stream covers the latent modes far better than a
random/streaming prefix of the same budget — the paper's 'efficient
training' premise made measurable."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticTokens, embed_examples
from repro.data.selection import SelectorConfig, SubmodularSelector
from repro.models.model import init_params


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, seq_len=64, n_modes=8, seed=0)
    pool_idx = list(range(64))
    emb = embed_examples(cfg, params, data.batch(pool_idx))
    return cfg, params, data, pool_idx, emb


def test_embeddings_cluster_by_mode(setup):
    """Mode structure must be visible in embedding space (sanity for the
    selection features)."""
    cfg, params, data, pool_idx, emb = setup
    emb = np.asarray(emb)
    modes = np.asarray([data.mode_of(i) for i in pool_idx])
    # within-mode distance < between-mode distance on average
    d = ((emb[:, None] - emb[None, :]) ** 2).sum(-1) ** 0.5
    same = modes[:, None] == modes[None, :]
    off_diag = ~np.eye(len(pool_idx), dtype=bool)
    within = d[same & off_diag].mean()
    between = d[~same].mean()
    assert within < 0.8 * between, (within, between)


def test_coreset_covers_modes_better_than_prefix(setup):
    cfg, params, data, pool_idx, emb = setup
    sel = SubmodularSelector(
        cfg, SelectorConfig(objective="representative", budget=8,
                            use_pallas_kernel=False)
    )
    chosen = sel.select(emb)
    modes_chosen = {data.mode_of(pool_idx[i]) for i in chosen}
    modes_prefix = {data.mode_of(i) for i in pool_idx[:8]}
    assert len(modes_chosen) >= len(modes_prefix)
    assert len(modes_chosen) >= 7  # 8 picks should cover >= 7 of 8 modes


def test_selector_objectives_run(setup):
    cfg, params, data, pool_idx, emb = setup
    q = emb[:4]
    p = emb[4:8]
    for objective, kwargs in [
        ("representative", {}),
        ("targeted", {"query_emb": q}),
        ("diverse", {}),
        ("privacy", {"private_emb": p}),
    ]:
        sel = SubmodularSelector(
            cfg,
            SelectorConfig(objective=objective, budget=6, use_pallas_kernel=False),
        )
        chosen = sel.select(emb, **kwargs)
        assert len(chosen) == 6 and len(set(chosen.tolist())) == 6


def test_targeted_selection_prefers_query_mode(setup):
    """FLQMI with queries from one mode must pick pool items of that mode
    (the paper's targeted-learning application)."""
    cfg, params, data, pool_idx, emb = setup
    target_mode = 3
    q_idx = [i for i in pool_idx if data.mode_of(i) == target_mode][:4]
    q_emb = np.asarray(emb)[q_idx]
    sel = SubmodularSelector(
        cfg, SelectorConfig(objective="targeted", budget=6, eta=1.0,
                            use_pallas_kernel=False)
    )
    chosen = sel.select(emb, query_emb=jnp.asarray(q_emb))
    hit = sum(1 for i in chosen if data.mode_of(pool_idx[i]) == target_mode)
    assert hit >= 4, f"only {hit}/6 picks in the target mode"


def test_synthetic_stream_deterministic():
    cfg = get_config("qwen3-0.6b").reduced()
    d1 = SyntheticTokens(cfg, 32, seed=5)
    d2 = SyntheticTokens(cfg, 32, seed=5)
    np.testing.assert_array_equal(d1.example(17), d2.example(17))
    b = d1.batch([0, 1, 2])
    assert b["tokens"].shape == (3, 32)


_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core import FacilityLocation, create_kernel, naive_greedy
    from repro.core.optimizers.distributed import distributed_fl_greedy
    from repro.launch.mesh import make_test_mesh

    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    mesh = make_test_mesh((4, 2), ("data", "model"))
    order, gains = distributed_fl_greedy(
        S, 10, mesh, row_axes=("model",), col_axes=("data",)
    )
    ref = naive_greedy(FacilityLocation.from_kernel(S), 10)
    got = [int(i) for i in np.asarray(order)]
    want = [i for i, _ in ref.as_list()]
    assert got == want, (got, want)
    print("MULTIDEV_OK")
    """
)


def test_distributed_greedy_eight_devices():
    """Real 8-device (4x2 mesh) run in a subprocess — proves the shard_map
    greedy's collectives are correct, not just its single-device lowering.

    (Historically @slow: without JAX_PLATFORMS=cpu the clean-env subprocess
    spent minutes probing for non-CPU backends before compiling.)"""
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
