"""launch/metrics.py: bounded counters, reservoir percentiles, the snapshot
schema — the replacement for the server's old unbounded wave_seconds list.
"""
import numpy as np
import pytest

from repro.launch.metrics import Histogram, Reservoir, ServerMetrics


def test_reservoir_is_bounded_and_uniform():
    r = Reservoir(capacity=64, seed=0)
    for v in range(10_000):
        r.add(float(v))
    assert len(r._sample) == 64  # O(capacity) memory, 10k values in
    assert r.seen == 10_000
    # a uniform sample of 0..9999: the median estimate lands mid-range
    assert 2_000 < r.percentile(0.5) < 8_000
    assert r.percentile(0.0) <= r.percentile(0.5) <= r.percentile(1.0)


def test_reservoir_small_stream_is_exact():
    r = Reservoir(capacity=512)
    for v in [5.0, 1.0, 3.0]:
        r.add(v)
    assert r.percentile(0.0) == 1.0
    assert r.percentile(0.5) == 3.0
    assert r.percentile(1.0) == 5.0
    assert np.isnan(Reservoir().percentile(0.5))  # empty -> NaN, not a crash
    with pytest.raises(ValueError, match="capacity"):
        Reservoir(capacity=0)


def test_reservoir_is_deterministic():
    a, b = Reservoir(capacity=8, seed=3), Reservoir(capacity=8, seed=3)
    for v in range(1000):
        a.add(float(v))
        b.add(float(v))
    assert a._sample == b._sample  # seeded: reproducible accounting


def test_histogram_exact_aggregates_bounded_percentiles():
    h = Histogram(reservoir_size=16)
    for v in range(100):
        h.record(float(v))
    assert h.count == 100
    assert h.total == float(sum(range(100)))  # count/sum/min/max are EXACT
    assert h.min == 0.0 and h.max == 99.0
    assert h.mean == pytest.approx(49.5)
    snap = h.snapshot()
    assert set(snap) == {"count", "sum", "max", "p50", "p99"}
    assert snap["count"] == 100 and snap["max"] == 99.0
    empty = Histogram().snapshot()
    assert empty == {"count": 0, "sum": 0.0, "max": 0.0, "p50": 0.0, "p99": 0.0}


def test_server_metrics_snapshot_schema():
    m = ServerMetrics()
    m.observe_enqueue("FacilityLocation/n32/NaiveGreedy", depth=1)
    m.observe_enqueue("FacilityLocation/n32/NaiveGreedy", depth=2)
    m.observe_wave("FacilityLocation/n32/NaiveGreedy", 0.5,
                   requests=2, slots=4, padded_slots=2)
    m.observe_served("FacilityLocation/n32/NaiveGreedy", 0.01)
    m.observe_served("FacilityLocation/n32/NaiveGreedy", 0.02,
                     deadline_missed=True)
    m.inc("rejections")
    m.observe_delta(0.25, churn=3)
    m.set_breaker("FacilityLocation/kernel", "open")
    snap = m.snapshot()
    assert set(snap) == {
        "counters", "queue_s", "wave_s", "queue_depth", "delta_s",
        "breakers", "groups",
    }
    assert snap["breakers"] == {"FacilityLocation/kernel": "open"}
    c = snap["counters"]
    assert c["retries_total"] == 0
    assert c["fallbacks_total"] == 0
    assert c["quarantined_total"] == 0
    assert c["requests"] == 2 and c["waves"] == 1
    assert c["slots"] == 4 and c["padded_slots"] == 2
    assert c["rejections"] == 1 and c["deadline_misses"] == 1
    assert c["session_deltas"] == 1 and c["session_churn"] == 3
    assert snap["queue_s"]["count"] == 2
    assert snap["wave_s"]["max"] == 0.5
    assert snap["queue_depth"]["max"] == 2
    assert snap["delta_s"]["count"] == 1 and snap["delta_s"]["max"] == 0.25
    g = snap["groups"]["FacilityLocation/n32/NaiveGreedy"]
    assert g["requests"] == 2 and g["waves"] == 1
    assert g["queue_s"]["count"] == 2 and g["wave_s"]["count"] == 1
    # snapshots are detached: mutating the server doesn't alter them
    m.inc("rejections")
    assert snap["counters"]["rejections"] == 1


def test_server_metrics_thread_safe_under_contention():
    import threading

    m = ServerMetrics()

    def hammer():
        for _ in range(500):
            m.inc("requests")
            m.observe_served("G/n8/NaiveGreedy", 0.001)

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.counters["requests"] == 2000
    assert m.queue_s.count == 2000
