"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
executed in interpret mode on CPU (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    from _propcheck import given, settings, st

from repro.kernels import ref
from repro.kernels.fl_gains import fl_gains_pallas
from repro.kernels.similarity_kernel import similarity_pallas

SHAPES = [
    (8, 8, 8),  # far below one tile
    (50, 70, 33),  # ragged, sub-tile
    (128, 128, 512),  # exactly one tile
    (130, 257, 600),  # ragged, multi-tile
    (256, 384, 1024),  # multiple tiles each dim
]
METRICS = ["dot", "cosine", "euclidean", "rbf"]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("metric", METRICS)
def test_similarity_matches_ref_fp32(shape, metric, rng):
    n, m, d = shape
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(m, d)).astype(np.float32)
    got = np.asarray(similarity_pallas(x, y, metric=metric, interpret=True))
    want = np.asarray(ref.similarity_ref(jnp.asarray(x), jnp.asarray(y), metric))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("metric", ["dot", "rbf"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_similarity_dtypes(metric, dtype, rng):
    x = jnp.asarray(rng.normal(size=(96, 200)).astype(np.float32), dtype)
    y = jnp.asarray(rng.normal(size=(64, 200)).astype(np.float32), dtype)
    got = np.asarray(similarity_pallas(x, y, metric=metric, interpret=True))
    want = np.asarray(ref.similarity_ref(x, y, metric))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("block", [(64, 128), (256, 512), (128, 256)])
def test_similarity_block_shapes(block, rng):
    bn, bk = block
    x = rng.normal(size=(100, 300)).astype(np.float32)
    y = rng.normal(size=(90, 300)).astype(np.float32)
    got = np.asarray(
        similarity_pallas(x, y, metric="dot", interpret=True, bn=bn, bm=bn, bk=bk)
    )
    want = np.asarray(ref.similarity_ref(jnp.asarray(x), jnp.asarray(y), "dot"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


FL_SHAPES = [(8, 8), (40, 60), (256, 512), (300, 700), (513, 1025)]


@pytest.mark.parametrize("shape", FL_SHAPES)
def test_fl_gains_matches_ref(shape, rng):
    u, n = shape
    s = rng.uniform(0, 1, size=(u, n)).astype(np.float32)
    cm = rng.uniform(0, 1, size=(u,)).astype(np.float32)
    got = np.asarray(fl_gains_pallas(s, cm, interpret=True))
    want = np.asarray(ref.fl_gains_ref(jnp.asarray(s), jnp.asarray(cm)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_fl_gains_dtypes(dtype, rng):
    s = jnp.asarray(rng.uniform(0, 1, size=(300, 400)).astype(np.float32), dtype)
    cm = jnp.asarray(rng.uniform(0, 1, size=(300,)).astype(np.float32), jnp.float32)
    got = np.asarray(fl_gains_pallas(s, cm, interpret=True))
    want = np.asarray(ref.fl_gains_ref(s, cm))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(
    u=st.integers(3, 200),
    n=st.integers(3, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_fl_gains_property(u, n, seed):
    rng = np.random.default_rng(seed)
    s = rng.uniform(0, 1, size=(u, n)).astype(np.float32)
    cm = rng.uniform(0, 1, size=(u,)).astype(np.float32)
    got = np.asarray(fl_gains_pallas(s, cm, interpret=True, bu=64, bn=128))
    want = np.asarray(ref.fl_gains_ref(jnp.asarray(s), jnp.asarray(cm)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    assert (got >= -1e-6).all()  # gains of a monotone function


def test_fl_function_kernel_path_matches_plain(rng):
    """FacilityLocation(use_kernel=True) routes gains through the Pallas op
    and must select the identical greedy set."""
    from repro.core import FacilityLocation, create_kernel, naive_greedy

    x = rng.normal(size=(80, 16)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    plain = FacilityLocation.from_kernel(S, use_kernel=False)
    fused = FacilityLocation.from_kernel(S, use_kernel=True)
    r1 = naive_greedy(plain, 10)
    r2 = naive_greedy(fused, 10)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-5, atol=1e-5
    )


FUSED_SHAPES = [(40, 60, 16), (300, 700, 128), (256, 512, 300), (513, 1025, 80)]


@pytest.mark.parametrize("shape", FUSED_SHAPES)
def test_fused_fl_sweep_matches_ref(shape, rng):
    """Beyond-paper fused similarity+gain kernel (EXPERIMENTS §Perf-3/C3):
    the O(n^2) kernel matrix never exists; gains come straight from the
    embeddings through a VMEM tile accumulator."""
    from repro.kernels.fused_fl_sweep import (
        fused_fl_sweep_pallas,
        fused_fl_sweep_ref,
    )

    u, n, d = shape
    x = rng.normal(size=(u, d)).astype(np.float32)
    y = rng.normal(size=(n, d)).astype(np.float32)
    cm = rng.uniform(0, 3, size=(u,)).astype(np.float32)
    got = np.asarray(
        fused_fl_sweep_pallas(x, y, cm, interpret=True, bu=128, bn=128, bk=64)
    )
    want = np.asarray(
        fused_fl_sweep_ref(jnp.asarray(x), jnp.asarray(y), jnp.asarray(cm))
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# -- graph-cut gain sweep (backend-layer kernel) -----------------------------

GC_SHAPES = [
    (8,),  # far below one tile
    (100,),  # ragged, sub-tile
    (128,),  # exactly one tile (bj=bk=64 -> multi-tile, aligned)
    (257,),  # ragged, multi-tile
]


@pytest.mark.parametrize("shape", GC_SHAPES)
def test_gc_gains_matches_ref(shape, rng):
    from repro.kernels.gc_gains import gc_gains_pallas

    (n,) = shape
    s = rng.uniform(0, 1, size=(n, n)).astype(np.float32)
    s = (s + s.T) / 2
    m = (rng.uniform(size=n) < 0.3).astype(np.float32)
    tot = s.sum(axis=0).astype(np.float32)
    got = np.asarray(gc_gains_pallas(s, m, tot, 0.4, interpret=True, bj=64, bk=64))
    want = np.asarray(
        ref.gc_gains_ref(jnp.asarray(s), jnp.asarray(m), jnp.asarray(tot), 0.4)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_gc_gains_dtypes(dtype, rng):
    from repro.kernels.gc_gains import gc_gains_pallas

    n = 150
    s = jnp.asarray(rng.uniform(0, 1, size=(n, n)).astype(np.float32), dtype)
    m = jnp.asarray((rng.uniform(size=n) < 0.5).astype(np.float32))
    tot = jnp.asarray(rng.uniform(0, n, size=n).astype(np.float32))
    got = np.asarray(gc_gains_pallas(s, m, tot, 0.25, interpret=True, bj=64, bk=64))
    want = np.asarray(ref.gc_gains_ref(s, m, tot, 0.25))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=5e-2)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(3, 200), seed=st.integers(0, 2**31 - 1))
def test_gc_gains_property(n, seed):
    from repro.kernels.gc_gains import gc_gains_pallas

    rng = np.random.default_rng(seed)
    s = rng.uniform(0, 1, size=(n, n)).astype(np.float32)
    m = (rng.uniform(size=n) < 0.4).astype(np.float32)
    tot = s.sum(axis=0).astype(np.float32)
    lam = float(rng.uniform(0.0, 1.0))
    got = np.asarray(gc_gains_pallas(s, m, tot, lam, interpret=True, bj=64, bk=64))
    want = np.asarray(
        ref.gc_gains_ref(jnp.asarray(s), jnp.asarray(m), jnp.asarray(tot), lam)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gc_function_kernel_path_matches_plain(rng):
    """GraphCut(use_kernel=True) routes full sweeps through the Pallas gain
    backend and must select the identical greedy set."""
    from repro.core import GraphCut, create_kernel, naive_greedy

    x = rng.normal(size=(70, 12)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="cosine"))
    plain = GraphCut.from_kernel(S, lam=0.3)
    fused = GraphCut.from_kernel(S, lam=0.3, use_kernel=True)
    r1 = naive_greedy(plain, 10, False, False)
    r2 = naive_greedy(fused, 10, False, False)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-4, atol=1e-4
    )


# -- feature-based concave-over-modular sweep ---------------------------------

FB_SHAPES = [(8, 5), (128, 128), (130, 70), (300, 33)]


@pytest.mark.parametrize("shape", FB_SHAPES)
@pytest.mark.parametrize("concave", ["sqrt", "log", "inverse"])
def test_fb_gains_matches_ref(shape, concave, rng):
    from repro.kernels.fb_gains import fb_gains_pallas

    n, F = shape
    feats = rng.uniform(0, 1, size=(n, F)).astype(np.float32)
    acc = rng.uniform(0, 2, size=(F,)).astype(np.float32)
    w = rng.uniform(0.5, 1.5, size=(F,)).astype(np.float32)
    got = np.asarray(
        fb_gains_pallas(feats, acc, w, concave=concave, interpret=True, bn=64, bf=64)
    )
    want = np.asarray(
        ref.fb_gains_ref(jnp.asarray(feats), jnp.asarray(acc), jnp.asarray(w), concave)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_fb_gains_dtypes(dtype, rng):
    from repro.kernels.fb_gains import fb_gains_pallas

    feats = jnp.asarray(rng.uniform(0, 1, size=(90, 40)).astype(np.float32), dtype)
    acc = jnp.asarray(rng.uniform(0, 2, size=(40,)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(40,)).astype(np.float32))
    got = np.asarray(fb_gains_pallas(feats, acc, w, interpret=True))
    want = np.asarray(ref.fb_gains_ref(feats, acc, w))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=5e-2)


def test_fb_function_kernel_path_matches_plain(rng):
    from repro.core import FeatureBased, naive_greedy

    feats = rng.uniform(0, 1, size=(60, 20)).astype(np.float32)
    plain = FeatureBased.from_features(feats, concave="log")
    fused = FeatureBased.from_features(feats, concave="log", use_kernel=True)
    r1 = naive_greedy(plain, 10, False, False)
    r2 = naive_greedy(fused, 10, False, False)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-4, atol=1e-4
    )


# -- set-cover family sweeps (backend-layer kernels) --------------------------

SC_SHAPES = [(8, 5), (100, 33), (128, 128), (257, 70), (300, 130)]


@pytest.mark.parametrize("shape", SC_SHAPES)
def test_sc_gains_matches_ref(shape, rng):
    from repro.kernels.sc_gains import sc_gains_pallas

    n, m = shape
    cover = rng.integers(0, 2, size=(n, m)).astype(np.float32)
    covered = (rng.uniform(size=m) < 0.4).astype(np.float32)
    w = rng.uniform(0.5, 2.0, size=m).astype(np.float32)
    got = np.asarray(
        sc_gains_pallas(cover, covered, w, interpret=True, bn=64, bm=64)
    )
    want = np.asarray(
        ref.sc_gains_ref(jnp.asarray(cover), jnp.asarray(covered), jnp.asarray(w))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (got >= -1e-6).all()  # gains of a monotone function


@pytest.mark.parametrize("shape", SC_SHAPES)
def test_psc_gains_matches_ref(shape, rng):
    from repro.kernels.sc_gains import psc_gains_pallas

    n, m = shape
    probs = rng.uniform(0, 0.9, size=(n, m)).astype(np.float32)
    miss = rng.uniform(0, 1, size=m).astype(np.float32)
    w = rng.uniform(0.5, 2.0, size=m).astype(np.float32)
    got = np.asarray(
        psc_gains_pallas(probs, miss, w, interpret=True, bn=64, bm=64)
    )
    want = np.asarray(
        ref.psc_gains_ref(jnp.asarray(probs), jnp.asarray(miss), jnp.asarray(w))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(3, 200), m=st.integers(1, 80), seed=st.integers(0, 2**31 - 1))
def test_sc_gains_property(n, m, seed):
    from repro.kernels.sc_gains import sc_gains_pallas

    rng = np.random.default_rng(seed)
    cover = rng.integers(0, 2, size=(n, m)).astype(np.float32)
    covered = (rng.uniform(size=m) < 0.5).astype(np.float32)
    w = rng.uniform(0.0, 2.0, size=m).astype(np.float32)
    got = np.asarray(sc_gains_pallas(cover, covered, w, interpret=True, bn=64, bm=64))
    want = np.asarray(
        ref.sc_gains_ref(jnp.asarray(cover), jnp.asarray(covered), jnp.asarray(w))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sc_function_kernel_path_matches_plain(rng):
    """SetCover(use_kernel=True) routes full sweeps through the Pallas gain
    backend and must select the identical greedy set."""
    from repro.core import SetCover, naive_greedy

    cover = rng.integers(0, 2, size=(70, 25)).astype(np.float32)
    w = rng.uniform(0.5, 2.0, size=25).astype(np.float32)
    plain = SetCover.from_cover(cover, w)
    fused = SetCover.from_cover(cover, w, use_kernel=True)
    r1 = naive_greedy(plain, 10)
    r2 = naive_greedy(fused, 10)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-5, atol=1e-5
    )


def test_psc_function_kernel_path_matches_plain(rng):
    from repro.core import ProbabilisticSetCover, naive_greedy

    probs = rng.uniform(0, 0.9, size=(60, 20)).astype(np.float32)
    plain = ProbabilisticSetCover.from_probs(probs)
    fused = ProbabilisticSetCover.from_probs(probs, use_kernel=True)
    r1 = naive_greedy(plain, 10)
    r2 = naive_greedy(fused, 10)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-5, atol=1e-5
    )


# -- disparity sweeps (stateless, from the selection mask) --------------------

DISP_SHAPES = [(8,), (100,), (128,), (257,)]


@pytest.mark.parametrize("shape", DISP_SHAPES)
def test_dsum_gains_matches_ref(shape, rng):
    from repro.kernels.disp_gains import dsum_gains_pallas

    (n,) = shape
    d = rng.uniform(0, 2, size=(n, n)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    m = (rng.uniform(size=n) < 0.3).astype(np.float32)
    got = np.asarray(dsum_gains_pallas(d, m, interpret=True, bj=64, bk=64))
    want = np.asarray(ref.dsum_gains_ref(jnp.asarray(d), jnp.asarray(m)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", DISP_SHAPES)
def test_dmin_gains_matches_ref(shape, rng):
    from repro.kernels.disp_gains import dmin_gains_pallas

    (n,) = shape
    d = rng.uniform(0.1, 2, size=(n, n)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    m = (rng.uniform(size=n) < 0.3).astype(np.float32)
    count = int(m.sum())
    curmin = float(rng.uniform(0, 1)) if count else 0.0
    got = np.asarray(
        dmin_gains_pallas(d, m, count, curmin, interpret=True, bj=64, bk=64)
    )
    want = np.asarray(
        ref.dmin_gains_ref(jnp.asarray(d), jnp.asarray(m), count, curmin)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dmin_gains_empty_selection_is_zero(rng):
    """|A| = 0: the surrogate collapses to 0 - f({}) = 0 for every candidate
    (the kernel's SMEM count conditional, not the masked min, must win)."""
    from repro.kernels.disp_gains import dmin_gains_pallas

    d = rng.uniform(0, 2, size=(40, 40)).astype(np.float32)
    got = np.asarray(
        dmin_gains_pallas(
            d, np.zeros(40, np.float32), 0, 0.0, interpret=True, bj=64, bk=64
        )
    )
    np.testing.assert_array_equal(got, np.zeros(40, np.float32))


def test_dsum_function_kernel_path_matches_plain(rng):
    from repro.core import DisparitySum, naive_greedy

    d = rng.uniform(0, 2, size=(60, 60)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    plain = DisparitySum.from_distance(d)
    fused = DisparitySum.from_distance(d, use_kernel=True)
    r1 = naive_greedy(plain, 8, False, False)
    r2 = naive_greedy(fused, 8, False, False)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-5, atol=1e-5
    )


def test_dmin_function_kernel_path_matches_plain(rng):
    """DisparityMin's masked min is order-independent, so the stateless
    Pallas sweep reproduces the memoized path bit-for-bit."""
    from repro.core import DisparityMin, naive_greedy

    d = rng.uniform(0.1, 2, size=(60, 60)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    plain = DisparityMin.from_distance(d)
    fused = DisparityMin.from_distance(d, use_kernel=True)
    r1 = naive_greedy(plain, 8, False, False)
    r2 = naive_greedy(fused, 8, False, False)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
    np.testing.assert_array_equal(np.asarray(r1.gains), np.asarray(r2.gains))


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_fused_fl_sweep_dtypes(dtype, rng):
    from repro.kernels.fused_fl_sweep import (
        fused_fl_sweep_pallas,
        fused_fl_sweep_ref,
    )

    x = jnp.asarray(rng.normal(size=(100, 96)).astype(np.float32), dtype)
    y = jnp.asarray(rng.normal(size=(90, 96)).astype(np.float32), dtype)
    cm = jnp.asarray(rng.uniform(0, 2, size=(100,)).astype(np.float32))
    got = np.asarray(fused_fl_sweep_pallas(x, y, cm, interpret=True))
    want = np.asarray(fused_fl_sweep_ref(x, y, cm))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=5e-2)


# -- masked-subset (gather-sweep) entry points --------------------------------
#
# The partial_sweep contract behind the bucketed lazy engines: gains for a
# gathered candidate subset only, idx < 0 slots padded to NEG_INF.  Validated
# two ways per family: allclose against the jnp subset oracle, and EXACT
# equality against the full-sweep kernel gathered at the same indices (the
# per-candidate accumulation order is identical by construction, which is
# what lets lazy screens mix stale full-sweep bounds with subset refreshes).

from repro.common import NEG_INF
from repro.kernels.fb_gains import fb_gains_at_pallas, fb_gains_pallas
from repro.kernels.fl_gains import fl_gains_at_pallas
from repro.kernels.gc_gains import gc_gains_at_pallas, gc_gains_pallas

SUBSET_IDX = [
    np.array([0], np.int32),
    np.array([5, 3, 3, 17], np.int32),  # duplicates allowed
    np.array([2, -1, 40, -1, 7, 0], np.int32),  # padded slots
    np.arange(48, dtype=np.int32)[::-1].copy(),  # everything, reversed
]


@pytest.mark.parametrize("idx", SUBSET_IDX)
def test_fl_gains_at_matches_ref_and_full(idx):
    rng = np.random.default_rng(11)
    u, n = 70, 48
    sim = rng.uniform(0, 1, size=(u, n)).astype(np.float32)
    cm = rng.uniform(0, 0.8, size=(u,)).astype(np.float32)
    got = np.asarray(fl_gains_at_pallas(sim, cm, idx, interpret=True))
    want = np.asarray(ref.fl_gains_at_ref(sim, cm, jnp.asarray(idx)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    full = np.asarray(fl_gains_pallas(sim, cm, interpret=True))
    mask = idx >= 0
    np.testing.assert_array_equal(got[mask], full[idx[mask]])
    assert (got[~mask] == NEG_INF).all()


@pytest.mark.parametrize("idx", SUBSET_IDX)
def test_gc_gains_at_matches_ref_and_full(idx):
    rng = np.random.default_rng(11)
    n = 48
    sim = rng.uniform(0, 1, size=(n, n)).astype(np.float32)
    sim = (sim + sim.T) / 2
    total = sim.sum(axis=0).astype(np.float32)
    selmask = (rng.uniform(size=n) < 0.3).astype(np.float32)
    lam = jnp.float32(0.4)
    got = np.asarray(
        gc_gains_at_pallas(sim, selmask, total, lam, idx, interpret=True)
    )
    want = np.asarray(
        ref.gc_gains_at_ref(sim, selmask, total, lam, jnp.asarray(idx))
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    full = np.asarray(gc_gains_pallas(sim, selmask, total, lam, interpret=True))
    mask = idx >= 0
    np.testing.assert_array_equal(got[mask], full[idx[mask]])
    assert (got[~mask] == NEG_INF).all()


@pytest.mark.parametrize("idx", SUBSET_IDX)
@pytest.mark.parametrize("concave", ["sqrt", "log"])
def test_fb_gains_at_matches_ref_and_full(idx, concave):
    rng = np.random.default_rng(11)
    n, F = 48, 33
    feats = rng.uniform(0, 1, size=(n, F)).astype(np.float32)
    acc = rng.uniform(0, 3, size=(F,)).astype(np.float32)
    w = rng.uniform(0.2, 1.5, size=(F,)).astype(np.float32)
    got = np.asarray(
        fb_gains_at_pallas(feats, acc, w, idx, concave=concave, interpret=True)
    )
    want = np.asarray(
        ref.fb_gains_at_ref(feats, acc, w, jnp.asarray(idx), concave=concave)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    full = np.asarray(
        fb_gains_pallas(feats, acc, w, concave=concave, interpret=True)
    )
    mask = idx >= 0
    np.testing.assert_array_equal(got[mask], full[idx[mask]])
    assert (got[~mask] == NEG_INF).all()


def test_partial_sweep_routes_through_kernel_backends():
    """backends.partial_sweep uses the family's Pallas subset kernel when
    use_kernel=True and the jnp gains_at reference otherwise — and the lazy
    screens agree between the two, which the batched lazy engine relies on."""
    from repro.core import FacilityLocation, create_kernel, lazy_greedy
    from repro.core.optimizers.backends import partial_sweep

    rng = np.random.default_rng(11)
    x = rng.normal(size=(40, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    plain = FacilityLocation.from_kernel(S)
    fused = FacilityLocation.from_kernel(S, use_kernel=True)
    st = plain.init_state()
    idx = jnp.asarray([3, 11, 0, 25], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(partial_sweep(plain, st, idx)),
        np.asarray(partial_sweep(fused, st, idx)),
        rtol=1e-5,
        atol=1e-5,
    )
    r1 = lazy_greedy(plain, 6)
    r2 = lazy_greedy(fused, 6)
    assert list(np.asarray(r1.order)) == list(np.asarray(r2.order))
