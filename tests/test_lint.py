"""repro-lint: per-rule positive / negative / pragma fixtures, the
framework contract (pragmas, baseline, unknown rules), the jaxpr-audit
library, and the real-tree gates.

Fixture tests run the rules in-process against temp trees (``run_lint``
accepts any root; rooted rules skip themselves there).  The mutation
check additionally drives the real CLI in a subprocess — seed one
violation of each rule into a temp tree and assert ``python -m
tools.lint`` fails with that RULE-ID — so the exit-code contract the
Makefile relies on is itself pinned.
"""
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.lint import run_lint  # noqa: E402
from tools.lint.framework import (  # noqa: E402
    RULES,
    SourceFile,
    Violation,
    load_baseline,
    write_baseline,
)


def _write(root: pathlib.Path, rel: str, body: str) -> None:
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(body))


def _run(root, rules):
    report = run_lint(root=root, rule_ids=rules, baseline_path=None)
    return report.fresh


def _ids(violations):
    return sorted({v.rule for v in violations})


# -- seeded-violation / clean / pragma fixtures, one set per rule -------------

# (rule, violating file, clean file) — the two bodies are as close as the
# rule allows, so each fixture isolates exactly the banned construct
FIXTURES = {
    "BITSTAB": (
        "src/repro/core/functions/fx.py",
        """
        def gains(self, state):
            return self.sim @ state.mask
        """,
        """
        def gains(self, state):
            return (self.sim * state.mask[None, :]).sum(axis=-1)

        def evaluate(self, state):
            return self.sim @ state.mask  # objective f(): exempt by design
        """,
    ),
    "NEGMASK": (
        "src/repro/core/functions/fx.py",
        """
        class Rogue:
            def gains_at(self, state, idx):
                return state.gains[idx]
        """,
        """
        class SetFunction:
            pass

        class Fine(SetFunction):
            def gains_at(self, state, idx):
                return state.gains[idx]
        """,
    ),
    "LOCKDISC": (
        "src/repro/launch/fx.py",
        """
        import threading

        class Server:
            _GUARDED_BY = {"_queue": "_lock"}

            def __init__(self):
                self._lock = threading.Lock()
                self._queue = []

            def push(self, item):
                self._queue.append(item)
        """,
        """
        import threading

        class Server:
            _GUARDED_BY = {"_queue": "_lock"}

            def __init__(self):
                self._lock = threading.Lock()
                self._queue = []

            def push(self, item):
                with self._lock:
                    self._queue.append(item)

            def _drain_locked(self):
                return list(self._queue)
        """,
    ),
    "TRACEPURE": (
        "src/repro/core/fx.py",
        """
        import time

        def gains(state):
            time.sleep(0.1)
            return state
        """,
        """
        import jax

        def gains(key):
            return jax.random.uniform(key, (4,))
        """,
    ),
    "WALLCLOCK": (
        "src/repro/launch/fx.py",
        """
        import time

        def step():
            t0 = time.time()
            return time.time() - t0
        """,
        """
        import time

        def step():
            t0 = time.monotonic()
            return time.monotonic() - t0
        """,
    ),
    "SHIMS": (
        "src/repro/launch/fx.py",
        """
        def run(engine, fn):
            return engine.maximize(fn, 5)
        """,
        """
        def run(engine, spec):
            return engine.submit(spec)
        """,
    ),
}


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_fires_on_violation(tmp_path, rule):
    rel, bad, _ = FIXTURES[rule]
    _write(tmp_path, rel, bad)
    found = _run(tmp_path, [rule])
    assert _ids(found) == [rule]
    assert all(v.path == rel for v in found)


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_passes_clean_tree(tmp_path, rule):
    rel, _, good = FIXTURES[rule]
    _write(tmp_path, rel, good)
    assert _run(tmp_path, [rule]) == []


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_trailing_pragma_suppresses_line(tmp_path, rule):
    rel, bad, _ = FIXTURES[rule]
    lines = textwrap.dedent(bad).splitlines()
    # find the line the violation fires on, then pragma exactly that line
    _write(tmp_path, rel, bad)
    found = _run(tmp_path, [rule])
    for v in found:
        lines[v.line - 1] += f"  # lint: ok({rule}): fixture justification"
    (tmp_path / rel).write_text("\n".join(lines) + "\n")
    assert _run(tmp_path, [rule]) == []


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_file_pragma_suppresses_whole_file(tmp_path, rule):
    rel, bad, _ = FIXTURES[rule]
    body = f"# lint: ok({rule}): fixture-wide justification\n" + textwrap.dedent(bad)
    _write(tmp_path, rel, body)
    assert _run(tmp_path, [rule]) == []


def test_pragma_without_reason_does_not_suppress(tmp_path):
    rel, bad, _ = FIXTURES["WALLCLOCK"]
    body = "# lint: ok(WALLCLOCK):\n" + textwrap.dedent(bad)
    _write(tmp_path, rel, body)
    assert _ids(_run(tmp_path, ["WALLCLOCK"])) == ["WALLCLOCK"]


def test_pragma_for_other_rule_does_not_suppress(tmp_path):
    rel, bad, _ = FIXTURES["WALLCLOCK"]
    body = "# lint: ok(BITSTAB): wrong rule\n" + textwrap.dedent(bad)
    _write(tmp_path, rel, body)
    assert _ids(_run(tmp_path, ["WALLCLOCK"])) == ["WALLCLOCK"]


# -- rule-specific edges ------------------------------------------------------


def test_bitstab_flags_named_contractions(tmp_path):
    _write(
        tmp_path,
        "src/repro/core/functions/fx.py",
        """
        import jax.numpy as jnp

        def gains_at(self, state, idx):
            return jnp.einsum("ij,j->i", self.sim, state.mask)[idx]

        def update(self, state, j):
            return jnp.dot(self.sim, state.mask)
        """,
    )
    found = _run(tmp_path, ["BITSTAB"])
    assert len(found) == 2
    assert {"einsum" in v.message or "dot" in v.message for v in found} == {True}


def test_negmask_flags_posthoc_assignment(tmp_path):
    _write(
        tmp_path,
        "src/repro/core/functions/fx.py",
        """
        class SetFunction:
            pass

        class Fine(SetFunction):
            pass

        def raw(self, state, idx):
            return state.gains[idx]

        Fine.gains_at = raw
        """,
    )
    found = _run(tmp_path, ["NEGMASK"])
    assert len(found) == 1 and "post-hoc" in found[0].message


def test_negmask_allows_masked_assignment(tmp_path):
    _write(
        tmp_path,
        "src/repro/core/functions/fx.py",
        """
        class SetFunction:
            pass

        def _mask_negative_idxs(fn):
            return fn

        class Fine(SetFunction):
            pass

        def raw(self, state, idx):
            return state.gains[idx]

        Fine.gains_at = _mask_negative_idxs(raw)
        """,
    )
    assert _run(tmp_path, ["NEGMASK"]) == []


def test_lockdisc_flags_undeclared_lock(tmp_path):
    _write(
        tmp_path,
        "src/repro/launch/fx.py",
        """
        import threading

        class Bare:
            def __init__(self):
                self._cv = threading.Condition()
        """,
    )
    found = _run(tmp_path, ["LOCKDISC"])
    assert len(found) == 1 and "_GUARDED_BY" in found[0].message


def test_lockdisc_two_lock_protocol(tmp_path):
    """The async_serve shape: holding the WRONG lock is still a violation."""
    _write(
        tmp_path,
        "src/repro/launch/fx.py",
        """
        import threading

        class Server:
            _GUARDED_BY = {"_futures": "_cv"}

            def __init__(self):
                self._cv = threading.Condition()
                self._dispatch = threading.Lock()
                self._futures = {}

            def bad(self, rid):
                with self._dispatch:
                    return self._futures.pop(rid)
        """,
    )
    found = _run(tmp_path, ["LOCKDISC"])
    assert len(found) == 1 and "_futures" in found[0].message


def test_tracepure_allows_jax_random_aliases(tmp_path):
    _write(
        tmp_path,
        "src/repro/core/fx.py",
        """
        import jax
        from jax import random

        def gains(key):
            return random.uniform(key, (4,)) + jax.random.normal(key, (4,))
        """,
    )
    assert _run(tmp_path, ["TRACEPURE"]) == []


def test_tracepure_flags_np_random(tmp_path):
    _write(
        tmp_path,
        "src/repro/core/fx.py",
        """
        import numpy as np

        def gains(state):
            return state + np.random.uniform()
        """,
    )
    assert _ids(_run(tmp_path, ["TRACEPURE"])) == ["TRACEPURE"]


def test_wallclock_flags_from_import(tmp_path):
    _write(
        tmp_path,
        "src/repro/launch/fx.py",
        """
        from time import time

        def step():
            return time()
        """,
    )
    assert _ids(_run(tmp_path, ["WALLCLOCK"])) == ["WALLCLOCK"]


def test_wallclock_dryrun_regression_fixture(tmp_path):
    """The satellite catch, fossilized: dryrun's old compile/lower timing
    pattern must keep firing (and its monotonic rewrite must not)."""
    _write(
        tmp_path,
        "src/repro/launch/dryrun_fx.py",
        """
        import time

        def _compile_once(jitted, args):
            t0 = time.time()
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            return compiled, t_lower, t_compile
        """,
    )
    found = _run(tmp_path, ["WALLCLOCK"])
    assert len(found) == 3


def test_shims_legacy_submit_kwargs(tmp_path):
    _write(
        tmp_path,
        "src/repro/launch/fx.py",
        """
        def run(server, fn):
            return server.submit(fn, budget=5, optimizer="NaiveGreedy")
        """,
    )
    assert _ids(_run(tmp_path, ["SHIMS"])) == ["SHIMS"]


# -- framework contract -------------------------------------------------------


def test_unknown_rule_rejected(tmp_path):
    with pytest.raises(SystemExit):
        run_lint(root=tmp_path, rule_ids=["NOPE"], baseline_path=None)


def test_rooted_rules_skip_under_custom_root(tmp_path):
    report = run_lint(root=tmp_path, baseline_path=None)
    assert set(report.skipped_rules) == {"MATRIX", "JAXPR"}
    assert not any(RULES[r].rooted for r in report.ran_rules)


def test_baseline_partitions_known_violations(tmp_path):
    rel, bad, _ = FIXTURES["WALLCLOCK"]
    _write(tmp_path, rel, bad)
    fresh = _run(tmp_path, ["WALLCLOCK"])
    assert fresh
    baseline = tmp_path / "baseline.json"
    write_baseline(baseline, fresh)
    report = run_lint(
        root=tmp_path, rule_ids=["WALLCLOCK"], baseline_path=baseline
    )
    assert report.fresh == [] and len(report.baselined) == len(fresh)
    assert not report.failed
    assert load_baseline(baseline) == {v.key() for v in fresh}


def test_baseline_key_is_line_insensitive():
    a = Violation("R", "p.py", 10, "msg")
    b = Violation("R", "p.py", 99, "msg")
    assert a.key() == b.key()


def test_committed_baseline_is_empty():
    """ISSUE contract: the baseline exists for transitions, and ships
    empty — launch/ and kernels/ violations were fixed, not parked."""
    committed = load_baseline(ROOT / "tools" / "lint" / "baseline.json")
    assert committed == set()


def test_sourcefile_pragma_scopes(tmp_path):
    p = tmp_path / "f.py"
    p.write_text(
        "# lint: ok(FILEWIDE): whole file\n"
        "x = 1  # lint: ok(LINEONLY): just this line\n"
        "y = 2\n"
    )
    sf = SourceFile(p, tmp_path)
    assert sf.suppressed("FILEWIDE", 3)
    assert sf.suppressed("LINEONLY", 2)
    assert not sf.suppressed("LINEONLY", 3)


# -- mutation check: the CLI contract, one seeded violation per rule ----------


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_cli_fails_on_seeded_violation(tmp_path, rule):
    rel, bad, _ = FIXTURES[rule]
    _write(tmp_path, rel, bad)
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "tools.lint",
            "--root",
            str(tmp_path),
            "--rules",
            rule,
            "--baseline",
            "none",
        ],
        cwd=ROOT,
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 1, proc.stderr
    assert rule in proc.stderr and "FAIL" in proc.stderr


def test_cli_exit_zero_on_clean_tree(tmp_path):
    rel, _, good = FIXTURES["WALLCLOCK"]
    _write(tmp_path, rel, good)
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "tools.lint",
            "--root",
            str(tmp_path),
            "--rules",
            "WALLCLOCK",
            "--baseline",
            "none",
        ],
        cwd=ROOT,
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stderr


# -- jaxpr audit library ------------------------------------------------------


def test_jaxpr_audit_flags_square_intermediate():
    import jax
    import jax.numpy as jnp

    from tools.lint.jaxpr_audit import square_intermediates

    n = 64
    closed = jax.make_jaxpr(lambda x: (x[:, None] * x[None, :]).sum())(
        jnp.ones(n)
    )
    problems = square_intermediates(closed.jaxpr, n, tile=1)
    assert problems and "(n, n)" in problems[0]


def test_jaxpr_audit_flags_dot_general():
    import jax
    import jax.numpy as jnp

    from tools.lint.jaxpr_audit import dot_generals

    closed = jax.make_jaxpr(lambda a, b: a @ b)(
        jnp.ones((4, 4)), jnp.ones((4, 4))
    )
    assert dot_generals(closed.jaxpr)


def test_jaxpr_audit_flags_host_callback():
    import jax
    import jax.numpy as jnp

    from tools.lint.jaxpr_audit import host_callbacks

    closed = jax.make_jaxpr(
        lambda x: jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x
        )
    )(jnp.float32(1.0))
    assert host_callbacks(closed.jaxpr)


def test_jaxpr_audit_walks_nested_scan_bodies():
    import jax
    import jax.numpy as jnp

    from tools.lint.jaxpr_audit import dot_generals

    def scanned(a, b):
        def body(c, _):
            return c @ b, ()

        out, _ = jax.lax.scan(body, a, None, length=3)
        return out

    closed = jax.make_jaxpr(scanned)(jnp.ones((4, 4)), jnp.ones((4, 4)))
    assert dot_generals(closed.jaxpr)  # the @ lives inside the scan body


def test_jaxpr_audit_manifest_case_clean_small():
    """One manifest cell traced end-to-end at a small n: the audit itself
    (not just the helpers) reports clean."""
    from tools.lint.jaxpr_audit import audit_case, default_manifest

    cases = {c.name: c for c in default_manifest(n=2048)}
    assert audit_case(cases["flmf-dot-full_sweep"]) == []
    assert audit_case(cases["gcmf-knn-full_sweep"]) == []


def test_jaxpr_audit_full_manifest_at_issue_scale():
    """The acceptance re-proof: every matrix-free source x metric x
    optimizer cell in the manifest holds the no-(n,n) ceiling, no-callback
    and no-dot_general invariants at n = 50_000."""
    from tools.lint.jaxpr_audit import (
        N_AUDIT,
        audit_case,
        default_manifest,
    )

    assert N_AUDIT == 50_000
    cases = default_manifest()
    assert len(cases) >= 11
    for case in cases:
        assert audit_case(case) == [], case.name


# -- the real tree ------------------------------------------------------------


def test_real_tree_is_lint_clean():
    """Every AST rule, against the actual repo, with the committed
    (empty) baseline: zero fresh violations.  This is the same gate
    ``make lint`` runs pre-merge — a red here means a real regression."""
    report = run_lint(
        rule_ids=["BITSTAB", "NEGMASK", "LOCKDISC", "TRACEPURE", "WALLCLOCK", "SHIMS"]
    )
    assert report.fresh == [], [v.render() for v in report.fresh]
