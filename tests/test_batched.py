"""Optimizer-equivalence + batched-engine correctness.

- lazy_greedy must match naive_greedy element-for-element on every function
  class (the lazy bound screen is exact under submodularity)
- batched_maximize must match a Python loop of single maximize calls per
  instance — orders, gains, AND the n_evals accounting, exactly
- padding masks: a zero-padded instance with a valid mask selects the same
  set as the unpadded instance
- _should_stop edge cases: the stopIfZeroGain / stopIfNegativeGain semantics
  are pinned (zero-gain stops iff stopIfZeroGain; stopIfZeroGain subsumes
  negative gains; with both off the budget is always exhausted)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BatchedEngine,
    FacilityLocation,
    FeatureBased,
    GraphCut,
    LogDet,
    ProbabilisticSetCover,
    SetCover,
    batched_maximize,
    create_kernel,
    lazy_greedy,
    naive_greedy,
)
from repro.core.optimizers.greedy import _should_stop

N = 32


def _build(name, rng):
    x = rng.normal(size=(N, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="cosine"))
    if name == "fl":
        return FacilityLocation.from_kernel(S)
    if name == "fl_kernel":
        return FacilityLocation.from_kernel(S, use_kernel=True)
    if name == "gc":
        return GraphCut.from_kernel(S, lam=0.3)
    if name == "gc_kernel":
        return GraphCut.from_kernel(S, lam=0.3, use_kernel=True)
    if name == "logdet":
        return LogDet.from_kernel(S + 0.5 * np.eye(N, dtype=np.float32))
    if name == "sc":
        return SetCover.from_cover(
            rng.integers(0, 2, size=(N, 12)).astype(np.float32)
        )
    if name == "psc":
        return ProbabilisticSetCover.from_probs(
            rng.uniform(0, 0.9, size=(N, 10)).astype(np.float32)
        )
    if name == "fb":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(N, 9)).astype(np.float32), concave="sqrt"
        )
    if name == "fb_kernel":
        return FeatureBased.from_features(
            rng.uniform(0, 1, size=(N, 9)).astype(np.float32),
            concave="sqrt",
            use_kernel=True,
        )
    raise KeyError(name)


# every submodular function class (disparity functions are excluded: they are
# not submodular, so the lazy bound screen carries no guarantee there)
ALL_CLASSES = [
    "fl",
    "fl_kernel",
    "gc",
    "gc_kernel",
    "logdet",
    "sc",
    "psc",
    "fb",
    "fb_kernel",
]


@pytest.mark.parametrize("name", ALL_CLASSES)
def test_lazy_equals_naive_every_class(name, rng):
    fn = _build(name, rng)
    r_naive = naive_greedy(fn, 8, False, False)
    r_lazy = lazy_greedy(fn, 8, 8, False, False)
    assert list(np.asarray(r_naive.order)) == list(np.asarray(r_lazy.order))
    np.testing.assert_allclose(
        np.asarray(r_naive.gains), np.asarray(r_lazy.gains), rtol=1e-5, atol=1e-5
    )
    # NOTE: no n_evals <= naive assertion here — on flat gain distributions
    # (e.g. probabilistic set cover) the bound screen's fallback sweeps can
    # cost slightly more than naive; identical OUTPUT is the guarantee.
    assert int(r_lazy.n_evals) >= fn.n  # at least the initial bound sweep


def _fl_instances(rng, B, n=24):
    fns = []
    for _ in range(B):
        x = rng.normal(size=(n, 5)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        fns.append(FacilityLocation.from_kernel(S))
    return fns


@pytest.mark.parametrize("optimizer", ["NaiveGreedy", "LazyGreedy"])
def test_batched_matches_sequential_loop(optimizer, rng):
    """B=8 instances, mixed budgets: per-instance results must be identical
    to a Python loop of single maximize calls — including n_evals."""
    B = 8
    fns = _fl_instances(rng, B)
    budgets = [5, 3, 7, 5, 2, 6, 4, 5]
    single = {"NaiveGreedy": naive_greedy, "LazyGreedy": lazy_greedy}[optimizer]
    batched = batched_maximize(fns, budgets, optimizer=optimizer, return_result=True)
    assert len(batched) == B
    for i, (fn, b) in enumerate(zip(fns, budgets)):
        seq = single(fn, b)
        assert list(np.asarray(seq.order)) == list(np.asarray(batched[i].order)), i
        np.testing.assert_allclose(
            np.asarray(seq.gains), np.asarray(batched[i].gains), rtol=1e-6
        )
        assert int(seq.n_evals) == int(batched[i].n_evals), i
        np.testing.assert_allclose(
            float(seq.value), float(batched[i].value), rtol=1e-5
        )


def test_batched_naive_eval_accounting_exact(rng):
    """n_evals must be exactly (steps taken) * n for the naive engine."""
    B = 4
    n = 24
    fns = _fl_instances(rng, B, n=n)
    budgets = [3, 5, 1, 4]
    res = batched_maximize(fns, budgets, return_result=True)
    for r, b in zip(res, budgets):
        steps = int((np.asarray(r.order) >= 0).sum())
        assert steps == b  # monotone fn, budget < n: never stops early
        assert int(r.n_evals) == steps * n


def test_batched_valid_mask_padding(rng):
    """Zero-padded instances + valid mask == the unpadded instance."""
    n_small, n_pad = 20, 30
    x = rng.normal(size=(n_small, 6)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    Sp = np.zeros((n_pad, n_pad), np.float32)
    Sp[:n_small, :n_small] = S
    fn_small = FacilityLocation.from_kernel(S)
    fn_pad = FacilityLocation.from_kernel(Sp)
    valid = np.zeros((4, n_pad), bool)
    valid[:, :n_small] = True
    res = batched_maximize(
        [fn_pad] * 4, 5, valid=jnp.asarray(valid), return_result=True
    )
    seq = naive_greedy(fn_small, 5)
    for r in res:
        assert list(np.asarray(seq.order)) == list(np.asarray(r.order))
        np.testing.assert_allclose(
            np.asarray(seq.gains), np.asarray(r.gains), rtol=1e-6
        )


def test_batched_lazy_never_selects_padding(rng):
    """Exhaustion edge case: with fewer valid candidates than screen_k and
    stopping disabled, the lazy screen's top-k spills into padded indices —
    they must be masked out, never selected."""
    n_valid, n_pad = 4, 16
    x = rng.normal(size=(n_valid, 4)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    Sp = np.zeros((n_pad, n_pad), np.float32)
    Sp[:n_valid, :n_valid] = S
    valid = np.zeros((2, n_pad), bool)
    valid[:, :n_valid] = True
    res = batched_maximize(
        [FacilityLocation.from_kernel(Sp)] * 2,
        10,  # budget far beyond the valid count
        optimizer="LazyGreedy",
        valid=jnp.asarray(valid),
        return_result=True,
        stopIfZeroGain=False,
        stopIfNegativeGain=False,
    )
    for r in res:
        order = np.asarray(r.order)
        chosen = order[order >= 0]
        # padded candidates must never appear (pre-fix, top_k spill let
        # their unmasked 0-gains win over the NEG_INF-masked valid set)
        assert (chosen < n_valid).all(), order
        # the real selection (first n_valid picks) is unique; past
        # exhaustion with stopping disabled the argmax degenerately repeats
        # — same as the sequential optimizers, so not asserted against
        assert len(set(chosen[:n_valid].tolist())) == n_valid, order


def test_batched_engine_reuse(rng):
    """A resident BatchedEngine answers repeated queries consistently and
    supports per-call budgets."""
    fns = _fl_instances(rng, 3)
    engine = BatchedEngine(fns)
    first = engine.maximize(4, return_result=True)
    again = engine.maximize(4, return_result=True)
    for a, b in zip(first, again):
        assert list(np.asarray(a.order)) == list(np.asarray(b.order))
    shorter = engine.maximize(2, return_result=True)
    for a, s in zip(first, shorter):
        assert list(np.asarray(a.order))[:2] == list(np.asarray(s.order))


def test_batched_rejects_mixed_families(rng):
    fl = _fl_instances(rng, 1, n=N)[0]
    gc = _build("gc", rng)
    with pytest.raises(ValueError):
        batched_maximize([fl, gc], 3)
    with pytest.raises(ValueError):
        batched_maximize(_fl_instances(rng, 2), [3, 4, 5])  # budget len mismatch


# -- eval-sparsity property: batched lazy across the servable matrix ----------


def _servable(kind, rng, n=64):
    """One instance per servable family, shaped so the gain distribution has
    a clear head (the regime lazy greedy targets): wide concept axes keep
    SetCover from exhausting inside the budget, and PSC rows get decaying
    scales — a uniformly-flat PSC is the known worst case where bound
    screens always miss (see test_lazy_equals_naive_every_class NOTE)."""
    from repro.core import FLVMI
    from repro.launch.serve import _random_function

    if kind == "fl_kernel":
        fn = _random_function("fl", n, rng)
        return FacilityLocation.from_kernel(np.asarray(fn.sim), use_kernel=True)
    if kind == "sc":
        cover = rng.integers(0, 2, size=(n, 96)).astype(np.float32)
        w = rng.uniform(0.5, 2.0, 96).astype(np.float32)
        scale = (0.8 ** np.arange(n))[rng.permutation(n)].astype(np.float32)
        return SetCover.from_cover(cover * scale[:, None], w)
    if kind == "psc":
        probs = rng.uniform(0, 0.9, size=(n, 24)).astype(np.float32)
        scale = (0.75 ** np.arange(n))[rng.permutation(n)].astype(np.float32)
        return ProbabilisticSetCover.from_probs(probs * scale[:, None])
    if kind == "flvmi":
        from repro.core import create_kernel as ck

        x = rng.normal(size=(n, 8)).astype(np.float32)
        q = rng.normal(size=(5, 8)).astype(np.float32)
        S = np.asarray(ck(x, metric="euclidean"))
        return FLVMI.build(S, np.asarray(ck(x, q, metric="euclidean")))
    return _random_function(kind, n, rng)


SERVABLE_FAMILIES = [
    "fl", "fl_kernel", "gc", "fb", "sc", "psc", "dsum", "dmin",
    "flqmi", "flvmi", "gcmi", "logdet",
]


@pytest.mark.parametrize("kind", SERVABLE_FAMILIES)
def test_batched_lazy_property_every_servable_family(kind):
    """The tentpole contract, per servable family: (a) batched LazyGreedy is
    bit-identical to sequential lazy_greedy — ids, gains AND n_evals; (b) on
    head-heavy gain distributions its eval count never exceeds batched
    NaiveGreedy's (the Minoux '78 savings, recovered in the batched path)."""
    from repro.core import maximize

    # local generator: the session `rng` fixture's draw sequence feeds the
    # data-sensitive equivalence tests in later files
    rng = np.random.default_rng(7)
    stop = kind not in ("dsum", "dmin")  # dispersion: empty-set gain is 0
    fns = [_servable(kind, rng) for _ in range(3)]
    budgets = [12, 8, 10]
    kw = dict(stopIfZeroGain=stop, stopIfNegativeGain=stop)
    lazy = batched_maximize(
        fns, budgets, optimizer="LazyGreedy", return_result=True, **kw
    )
    naive = batched_maximize(
        fns, budgets, optimizer="NaiveGreedy", return_result=True, **kw
    )
    for fn, b, rl, rn in zip(fns, budgets, lazy, naive):
        for optimizer, got in (("LazyGreedy", rl), ("NaiveGreedy", rn)):
            ref = maximize(fn, b, optimizer=optimizer, return_result=True, **kw)
            assert list(np.asarray(ref.order)) == list(np.asarray(got.order)), kind
            np.testing.assert_array_equal(
                np.asarray(ref.gains), np.asarray(got.gains)
            )
            assert int(ref.n_evals) == int(got.n_evals), (kind, optimizer)
        assert int(rl.n_evals) <= int(rn.n_evals), kind


# -- _should_stop semantics ---------------------------------------------------


def test_should_stop_truth_table():
    """Pin the stopping rule: stop_if_zero uses gj <= 0 (so it subsumes
    negatives), stop_if_negative uses gj < 0, both off never stops."""
    cases = [
        # (gain, stop_if_zero, stop_if_negative, expected)
        (1.0, True, True, False),
        (0.0, True, True, True),
        (-1.0, True, True, True),
        (0.0, False, True, False),  # zero gain allowed when only negatives stop
        (-1e-6, False, True, True),
        (0.0, True, False, True),
        (-1.0, True, False, True),  # stop_if_zero alone still stops negatives
        (0.0, False, False, False),
        (-5.0, False, False, False),
    ]
    for g, sz, sn, want in cases:
        got = bool(_should_stop(jnp.asarray(g, jnp.float32), sz, sn))
        assert got == want, (g, sz, sn)


def test_stop_flag_behaviour_on_modular_function(rng):
    """Behavioural pin: modular SetCover with positive / zero / negative
    element weights under each flag combination."""
    n = 9
    w = np.asarray([2.0, 1.5, 1.0, 0.0, 0.0, -0.5, -1.0, 3.0, 0.5], np.float32)
    fn = SetCover.from_cover(np.eye(n, dtype=np.float32), w)

    both = naive_greedy(fn, n, True, True)
    assert sorted(i for i, _ in both.as_list()) == sorted(
        int(i) for i in np.flatnonzero(w > 0)
    )

    neg_only = naive_greedy(fn, n, False, True)
    chosen = [i for i, _ in neg_only.as_list()]
    assert sorted(chosen) == sorted(int(i) for i in np.flatnonzero(w >= 0))

    never = naive_greedy(fn, n, False, False)
    assert len(never.as_list()) == n  # budget exhausted, negatives included
    np.testing.assert_allclose(float(never.value), w.sum(), rtol=1e-6)
