"""The typed front door: OptimizerSpec / SelectionSpec / solve().

Pins the api_redesign contract:

- construction-time validation: unknown optimizer names, misspelled or
  ill-typed hyperparameters, non-function objects, and impossible backend
  overrides all fail BEFORE anything traces or flushes, with errors naming
  the valid set;
- spec round-tripping: to_dict()/from_dict() and jit/pytree flattening;
- ONE spec routed through solve() in sequential, batched, sharded, served
  and async-served modes returns bit-identical (ids, gains, n_evals) — in
  process on a (1,1) mesh and in a subprocess on a real 2x2 device mesh;
- per-family stop-rule defaults resolve in one place (Disparity* parity
  across entry points);
- the legacy entry points are DeprecationWarning shims that delegate with
  identical results (and reject misspelled options instead of swallowing
  them — the old api.maximize kw.get bug).
"""
import json
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.core import (
    DisparityMin,
    DisparitySum,
    FacilityLocation,
    LogDet,
    OptimizerSpec,
    SelectionSpec,
    batched_maximize,
    create_kernel,
    family_defaults,
    lazy_greedy,
    maximize,
    naive_greedy,
    optimizer_names,
    resolve_optimizer,
    solve,
    stochastic_greedy,
)
from repro.core.optimizers.batched import BatchedEngine
from repro.launch.serve import SelectionServer


def _fl(rng, n=32):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    return FacilityLocation.from_kernel(S)


def _dsum(rng, n=24):
    x = rng.normal(size=(n, 8)).astype(np.float32)
    return DisparitySum.from_distance(
        1.0 - np.asarray(create_kernel(x, metric="euclidean"))
    )


def _same(a, b, n_evals=True):
    assert list(np.asarray(a.order)) == list(np.asarray(b.order))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    if n_evals:
        assert int(a.n_evals) == int(b.n_evals)


# -- OptimizerSpec validation -------------------------------------------------


def test_optimizer_registry_names():
    names = optimizer_names()
    assert {"NaiveGreedy", "LazyGreedy", "StochasticGreedy",
            "LazierThanLazyGreedy"} <= set(names)
    for n in names:
        assert resolve_optimizer(n).name == n


def test_optimizer_spec_unknown_name():
    with pytest.raises(ValueError, match="unknown optimizer.*NaiveGreedy"):
        OptimizerSpec("QuantumGreedy")


def test_optimizer_spec_unknown_param_names_valid_set():
    with pytest.raises(TypeError, match=r"screen_kk.*screen_k"):
        OptimizerSpec("LazyGreedy", screen_kk=4)


def test_optimizer_spec_bad_values():
    with pytest.raises(TypeError, match="screen_k"):
        OptimizerSpec("LazyGreedy", screen_k=0)
    with pytest.raises(TypeError, match="epsilon"):
        OptimizerSpec("StochasticGreedy", epsilon=2.0)
    with pytest.raises(TypeError, match="sample_size"):
        OptimizerSpec("StochasticGreedy", sample_size=0)


def test_optimizer_spec_defaults_and_roundtrip():
    opt = OptimizerSpec("LazierThanLazyGreedy", epsilon=0.1)
    assert opt.params == {
        "seed": 0, "epsilon": 0.1, "sample_size": None, "screen_k": 8,
    }
    # to_dict is JSON-able and round-trips exactly
    d = json.loads(json.dumps(opt.to_dict()))
    assert OptimizerSpec.from_dict(d) == opt
    # copy-construction is idempotent; adding params to a spec is rejected
    assert OptimizerSpec(opt) == opt
    with pytest.raises(TypeError, match="alongside"):
        OptimizerSpec(opt, screen_k=4)


def test_optimizer_spec_is_hashable_zero_leaf_pytree():
    a = OptimizerSpec("LazyGreedy", screen_k=4)
    b = OptimizerSpec("LazyGreedy", screen_k=4)
    assert a == b and hash(a) == hash(b)
    leaves, treedef = jax.tree.flatten(a)
    assert leaves == []
    assert jax.tree.unflatten(treedef, []) == a


# -- SelectionSpec validation -------------------------------------------------


def test_selection_spec_rejects_non_function(rng):
    with pytest.raises(TypeError, match="SetFunction"):
        SelectionSpec(np.eye(4, dtype=np.float32), 2)


def test_selection_spec_rejects_bad_budget(rng):
    fn = _fl(rng, 16)
    with pytest.raises(ValueError, match="budget"):
        SelectionSpec(fn, 0)


def test_selection_spec_unknown_option_names_valid_set(rng):
    fn = _fl(rng, 16)
    with pytest.raises(TypeError, match=r"stopIfZeroGian.*stopIfZeroGain"):
        SelectionSpec(fn, 3, stopIfZeroGian=False)


def test_selection_spec_use_kernel_rejected_for_flagless_family(rng):
    x = rng.normal(size=(16, 8)).astype(np.float32)
    S = np.asarray(create_kernel(x, metric="euclidean"))
    ld = LogDet.from_kernel(S + 0.5 * np.eye(16, dtype=np.float32))
    with pytest.raises(TypeError, match="use_kernel"):
        SelectionSpec(ld, 3, use_kernel=True)


def test_selection_spec_optimizer_spec_plus_params_rejected(rng):
    fn = _fl(rng, 16)
    with pytest.raises(TypeError, match="OptimizerSpec"):
        SelectionSpec(fn, 3, OptimizerSpec("LazyGreedy"), screen_k=4)


def test_selection_spec_use_kernel_override_resolves(rng):
    fn = _fl(rng, 16)
    spec = SelectionSpec(fn, 3, use_kernel=True)
    assert spec.resolved_fn().use_kernel is True
    assert SelectionSpec(fn, 3).resolved_fn() is fn  # None = untouched


# -- per-family stop defaults -------------------------------------------------


def test_family_default_table(rng):
    from repro.core import DisparityMinSum

    assert family_defaults(FacilityLocation)["stopIfZeroGain"] is True
    for cls in (DisparitySum, DisparityMin, DisparityMinSum):
        d = family_defaults(cls)
        assert d["stopIfZeroGain"] is False, cls
        assert d["stopIfNegativeGain"] is True, cls
    fn = _dsum(rng)
    assert SelectionSpec(fn, 3).stop_if_zero is False
    # explicit flag always beats the family default
    assert SelectionSpec(fn, 3, stopIfZeroGain=True).stop_if_zero is True


def test_disparity_parity_across_entry_points(rng):
    """The satellite contract: the dispersion default lives in ONE table, so
    sequential solve, the maximize shim, sync serving and legacy submit all
    return the same non-empty selection without any explicit flag."""
    fn = _dsum(rng)
    spec = SelectionSpec(fn, 5)
    seq = solve(spec)
    assert seq.as_list(), "family default must prevent the empty selection"
    served = solve([spec], mode="served")[0]
    _same(seq, served, n_evals=False)  # n=24 pads to 32: ids/gains only
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert maximize(fn, 5) == seq.as_list()
        server = SelectionServer()
        rid = server.submit(fn, 5)  # legacy form, no flags
        assert server.flush()[rid].selection == seq.as_list()


# -- round-tripping -----------------------------------------------------------


def test_selection_spec_dict_roundtrip(rng):
    fn = _fl(rng)
    spec = SelectionSpec(fn, 4, "LazyGreedy", screen_k=6,
                         stopIfNegativeGain=False)
    d = spec.to_dict()
    back = SelectionSpec.from_dict(d)
    assert back == spec
    _same(solve(spec), solve(back))


def test_selection_spec_deadline_validation_and_roundtrip(rng):
    """deadline_s: a serving-scheduler hint that rides the spec — validated
    at construction, carried through dict and pytree round trips, and NEVER
    part of the selection semantics (same result with or without one)."""
    fn = _fl(rng, 32)
    spec = SelectionSpec(fn, 4, deadline_s=0.5)
    assert spec.deadline_s == 0.5
    assert "deadline_s=0.5" in repr(spec)
    assert "deadline_s" not in repr(SelectionSpec(fn, 4))  # quiet when unset
    back = SelectionSpec.from_dict(spec.to_dict())
    assert back == spec and back.deadline_s == 0.5
    leaves, treedef = jax.tree.flatten(spec)
    assert jax.tree.unflatten(treedef, leaves) == spec
    # scheduling hint only: the selection is identical without the deadline
    _same(solve(spec), solve(SelectionSpec(fn, 4)))
    for bad in (0.0, -1.0, float("inf"), float("nan")):
        with pytest.raises(ValueError, match="deadline_s"):
            SelectionSpec(fn, 4, deadline_s=bad)


def test_selection_spec_pytree_roundtrip(rng):
    fn = _fl(rng)
    spec = SelectionSpec(fn, 4, "LazyGreedy", screen_k=6)
    leaves, treedef = jax.tree.flatten(spec)
    assert len(leaves) == len(jax.tree.leaves(fn))  # fn is the only child
    back = jax.tree.unflatten(treedef, leaves)
    assert back == spec
    _same(solve(spec), solve(back))


def test_selection_spec_crosses_jit_without_retrace(rng):
    traces = []

    @jax.jit
    def peak_gain(spec: SelectionSpec):
        traces.append(1)
        return spec.fn.gains(spec.fn.init_state()).max()

    a = SelectionSpec(_fl(rng), 4, "LazyGreedy")
    b = SelectionSpec(_fl(rng), 4, "LazyGreedy")  # same statics, new data
    ga, gb = float(peak_gain(a)), float(peak_gain(b))
    assert len(traces) == 1  # static half rides the cache key; no retrace
    assert ga > 0 and gb > 0
    # a different static half IS a different program
    float(peak_gain(SelectionSpec(_fl(rng), 5, "LazyGreedy")))
    assert len(traces) == 2


# -- solve(): one spec, every route -------------------------------------------


def test_solve_single_vs_all_modes_bit_identical(rng):
    """n=32 sits at its pow-2 bucket and 4 at its budget bucket, so even
    n_evals must agree across sequential / batched / sharded(1,1) / served /
    async routes."""
    spec = SelectionSpec(_fl(rng, 32), 4, "LazyGreedy", screen_k=6)
    seq = solve(spec)
    _same(seq, lazy_greedy(spec.fn, 4, 6))  # sequential == the raw optimizer

    batched = solve([spec, spec], mode="batched")
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    sharded = solve([spec, spec], mesh=mesh)
    served = solve([spec], mode="served")
    awaited = solve([spec], mode="async")
    for r in (*batched, *sharded, served[0], awaited[0]):
        _same(seq, r)


def test_solve_sequential_list_and_empty(rng):
    specs = [SelectionSpec(_fl(rng, 16), b) for b in (2, 3)]
    out = solve(specs, mode="sequential")
    for s, r in zip(specs, out):
        _same(r, naive_greedy(s.fn, s.budget))
    assert solve([], mode="batched") == []


def test_solve_stochastic_seed_matches_raw_optimizer(rng):
    fn = _fl(rng, 48)
    spec = SelectionSpec(fn, 5, "StochasticGreedy", seed=3)
    ref = stochastic_greedy(fn, 5, jax.random.PRNGKey(3), 0.01)
    _same(solve(spec), ref)


def test_solve_mode_validation(rng):
    spec = SelectionSpec(_fl(rng, 16), 3)
    with pytest.raises(ValueError, match="unknown mode"):
        solve(spec, mode="warp")
    with pytest.raises(ValueError, match="mesh"):
        solve([spec], mode="sharded")
    with pytest.raises(TypeError, match="SelectionSpec"):
        solve([spec, "nope"])


def test_solve_batched_rejects_mixed_static_specs(rng):
    fn = _fl(rng, 16)
    a = SelectionSpec(fn, 3, "NaiveGreedy")
    b = SelectionSpec(fn, 3, "LazyGreedy")
    with pytest.raises(ValueError, match="served"):
        solve([a, b], mode="batched")


def test_solve_batched_rejects_unbatchable_optimizer(rng):
    spec = SelectionSpec(_fl(rng, 16), 3, "StochasticGreedy")
    with pytest.raises(ValueError, match="batched-capable"):
        solve([spec], mode="batched")


def test_server_rejects_unbatchable_optimizer_at_submit(rng):
    """A non-wave optimizer must be rejected at submit, never mid-flush."""
    server = SelectionServer()
    ok = server.submit(SelectionSpec(_fl(rng, 16), 3))
    with pytest.raises(ValueError, match="batched-capable"):
        server.submit(SelectionSpec(_fl(rng, 16), 3, "StochasticGreedy"))
    out = server.flush()  # the valid request is unaffected
    assert out[ok].selection


def test_solve_served_heterogeneous_matches_sequential(rng):
    """Served mode takes what batched mode rejects: mixed families, sizes,
    optimizers — every response equals its sequential solve."""
    specs = [
        SelectionSpec(_fl(rng, 24), 4),
        SelectionSpec(_fl(rng, 40), 6, "LazyGreedy", screen_k=4),
        SelectionSpec(_dsum(rng, 24), 3),
    ]
    out = solve(specs, mode="served")
    for s, r in zip(specs, out):
        # engines count logical evaluations, so even off-bucket requests
        # report n_evals exactly as sequential solve does
        _same(solve(s), r)


# -- the deprecated shims -----------------------------------------------------


def _one_deprecation(record):
    msgs = [w for w in record if issubclass(w.category, DeprecationWarning)]
    assert len(msgs) == 1, [str(w.message) for w in record]
    return str(msgs[0].message)


def test_maximize_shim_warns_once_and_delegates(rng):
    fn = _fl(rng)
    spec = SelectionSpec(fn, 4, "LazyGreedy", screen_k=6)
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        out = maximize(fn, 4, optimizer="LazyGreedy", screen_k=6)
    assert "solve" in _one_deprecation(record)
    assert out == solve(spec).as_list()
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        res = maximize(fn, 4, optimizer="LazyGreedy", screen_k=6,
                       return_result=True)
    _one_deprecation(record)
    _same(res, solve(spec))


def test_maximize_shim_rejects_misspelled_option(rng):
    """Regression for the silent kw.get swallowing: the old entry point ran
    under the wrong stopping semantics; now it must raise, naming the set."""
    fn = _fl(rng, 16)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(TypeError, match=r"stopIfZeroGian.*stopIfZeroGain"):
            maximize(fn, 3, stopIfZeroGian=False)
        with pytest.raises(ValueError, match="unknown optimizer"):
            maximize(fn, 3, optimizer="Nope")


def test_batched_maximize_shim_warns_once_and_delegates(rng):
    fns = [_fl(rng, 16) for _ in range(3)]
    specs = [SelectionSpec(f, 3) for f in fns]
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        out = batched_maximize(fns, 3, return_result=True)
    _one_deprecation(record)  # exactly one: no cascade through inner shims
    for a, b in zip(out, solve(specs, mode="batched")):
        _same(a, b)


def test_engine_maximize_shim_warns_once_and_delegates(rng):
    fns = [_fl(rng, 16) for _ in range(2)]
    engine = BatchedEngine(fns)
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        out = engine.maximize([2, 3], return_result=True)
    _one_deprecation(record)
    for a, b in zip(out, engine.run([2, 3])):
        _same(a, b)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(TypeError, match="screen_kk"):
            engine.maximize(2, optimizer="LazyGreedy", screen_kk=4)


def test_server_submit_shim_warns_once_and_delegates(rng):
    fn = _fl(rng, 16)
    server = SelectionServer()
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        rid = server.submit(fn, 3)
    _one_deprecation(record)
    # the spec path is warning-free
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        rid_spec = server.submit(SelectionSpec(fn, 3))
    assert not [w for w in record if issubclass(w.category, DeprecationWarning)]
    out = server.flush()
    assert out[rid].selection == out[rid_spec].selection
    with pytest.raises(TypeError, match="no extra options"):
        server.submit(SelectionSpec(fn, 3), 4)
    # an optimizer alongside a spec must raise, not be silently dropped
    with pytest.raises(TypeError, match="no extra options"):
        server.submit(SelectionSpec(fn, 3), optimizer="LazyGreedy")


def test_solve_served_on_shared_server_drops_nothing(rng):
    """solve(mode="served", server=...) drains the caller's flush on behalf
    of its own specs only: a request the caller enqueued earlier must
    surface on the caller's next flush(), never be dropped."""
    server = SelectionServer()
    early = SelectionSpec(_fl(rng, 16), 3)
    rid_early = server.submit_spec(early)
    out = solve([SelectionSpec(_fl(rng, 24), 4)], mode="served", server=server)
    assert out[0].as_list()
    held = server.flush()  # nothing pending, but early's answer is held here
    assert held[rid_early].selection == solve(early).as_list()


def test_internal_paths_emit_no_deprecation_warnings(rng):
    """solve() on every route must never touch a shim."""
    spec = SelectionSpec(_fl(rng, 32), 3)
    mesh = jax.make_mesh((1, 1), ("batch", "data"))
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        solve(spec)
        solve([spec], mode="batched")
        solve([spec], mesh=mesh)
        solve([spec], mode="served")
        solve([spec], mode="async")
    assert not [w for w in record if issubclass(w.category, DeprecationWarning)]


# -- acceptance: one spec, four routes, real 2x2 mesh -------------------------

_MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.core import (FacilityLocation, SelectionSpec, create_kernel,
                            solve)
    from repro.launch.async_serve import AsyncSelectionServer

    rng = np.random.default_rng(0)

    def spec(budget):
        x = rng.normal(size=(32, 8)).astype(np.float32)
        S = np.asarray(create_kernel(x, metric="euclidean"))
        return SelectionSpec(FacilityLocation.from_kernel(S), budget,
                             "LazyGreedy", screen_k=6)

    mesh = jax.make_mesh((2, 2), ("batch", "data"))
    assert len(jax.devices()) == 4
    specs = [spec(b) for b in (4, 8, 2, 4)]

    seq = solve(specs, mode="sequential")
    batched = solve(specs, mode="batched")
    sharded = solve(specs, mesh=mesh)
    served = solve(specs, mode="served", mesh=mesh)
    with AsyncSelectionServer(mesh=mesh, max_pending=len(specs),
                              flush_interval=30.0) as server:
        futures = [server.submit(s) for s in specs]  # depth-triggered flush
        async_res = [f.result(timeout=300).result for f in futures]

    for route, results in [("batched", batched), ("sharded", sharded),
                           ("served", served), ("async", async_res)]:
        for a, b in zip(seq, results):
            assert list(np.asarray(a.order)) == list(np.asarray(b.order)), route
            assert np.array_equal(np.asarray(a.gains), np.asarray(b.gains)), route
            assert int(a.n_evals) == int(b.n_evals), route
    print("SPEC_ROUTES_OK")
    """
)


def test_one_spec_every_route_2x2_mesh_subprocess():
    """The acceptance criterion: one SelectionSpec routed through solve() in
    sequential, batched, sharded (real 2x2 mesh, live collectives) and
    async-served modes returns bit-identical (ids, gains, n_evals)."""
    r = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SPEC_ROUTES_OK" in r.stdout, r.stdout + r.stderr
