"""Training substrate: AdamW vs numpy reference, schedules, clipping,
gradient compression with error feedback, train-step loss descent,
checkpoint save/restore round-trip + elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import get_config
from repro.train.grad_compress import (
    apply_error_feedback,
    compress_decompress,
    ef_init,
)
from repro.train.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.train.train_step import init_train_state, make_train_step


def _numpy_adamw(p, g, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1**step)
    vhat = v / (1 - b2**step)
    p = p - lr * (mhat / (np.sqrt(vhat) + eps) + wd * p)
    return p, m, v


def test_adamw_matches_numpy(rng):
    p0 = rng.normal(size=(64,)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adamw_init(params)
    pn, mn, vn = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for step in range(1, 6):
        g = rng.normal(size=(64,)).astype(np.float32)
        params, state = adamw_update(
            {"w": jnp.asarray(g)}, state, params, lr=1e-2
        )
        pn, mn, vn = _numpy_adamw(pn, g, mn, vn, step, 1e-2)
        np.testing.assert_allclose(np.asarray(params["w"]), pn, rtol=1e-5, atol=1e-6)


def test_clip_by_global_norm(rng):
    g = {"a": jnp.asarray(rng.normal(size=(32,)).astype(np.float32)) * 100}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_cosine_schedule():
    sched = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.asarray(100))) < float(sched(jnp.asarray(50)))
    assert float(sched(jnp.asarray(100))) >= 1e-4 - 1e-9  # min_ratio floor


def test_compression_error_feedback(rng):
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    g_hat, err = compress_decompress(g)
    # int8 block quantization: small relative error, exact error residual
    np.testing.assert_allclose(
        np.asarray(g_hat + err), np.asarray(g), rtol=1e-6, atol=1e-6
    )
    assert float(jnp.abs(err).max()) < float(jnp.abs(g).max()) / 64
    # error feedback: accumulated compressed updates converge to the truth
    grads = {"w": g}
    ef = ef_init(grads)
    total = np.zeros(1000, np.float32)
    for _ in range(20):
        out, ef = apply_error_feedback(grads, ef)
        total += np.asarray(out["w"])
    np.testing.assert_allclose(total / 20, np.asarray(g), rtol=0.02, atol=1e-3)


def test_train_step_descends_loss(rng):
    cfg = get_config("qwen3-0.6b").reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, cosine_schedule(3e-3, 2, 1000)))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
    }
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses  # memorizes the fixed batch


def test_train_step_grad_accum_matches(rng):
    cfg = get_config("qwen3-0.6b").reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
    }
    s1, m1 = make_train_step(cfg, grad_accum=1)(state, batch)
    s2, m2 = make_train_step(cfg, grad_accum=2)(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2,
            atol=2e-4,
        )


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = get_config("qwen3-0.6b").reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state, {"arch": "qwen3-0.6b"})
    assert ckpt.latest_step(d) == 7
    restored, meta = ckpt.restore(d, state)
    assert meta["step"] == 7 and meta["arch"] == "qwen3-0.6b"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path, rng):
    cfg = get_config("mamba2-370m").reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, state, keep_last=2)
    steps = sorted(os.listdir(d))
    assert steps == ["step_0000000004", "step_0000000005"]
    assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_checkpoint_elastic_restore_to_mesh(tmp_path, rng):
    """Restore onto a (different) mesh with explicit shardings — the elastic
    restart path after node loss."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_test_mesh

    cfg = get_config("qwen3-0.6b").reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state)
    mesh = make_test_mesh((1, 1))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, meta = ckpt.restore(d, state, shardings=shardings)
    assert meta["step"] == 3
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf.sharding, NamedSharding)
