"""Gradient compression with error feedback (DESIGN §6).

int8 block-quantization applied to gradients *before* the data-parallel
all-reduce, with the quantization residual carried to the next step
(error feedback keeps convergence unbiased; Seide et al. '14, Karimireddy
et al. '19).  Cuts the DP collective payload 4x when the roofline says a
cell is gradient-all-reduce-bound.

Under pjit the all-reduce is implicit (GSPMD inserts it for the sharded
gradient sum); quantize->dequantize around the psum boundary shrinks the
transferred representation, which shows up in the dry-run collective bytes.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_BLOCK = 256


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree like grads


def ef_init(grads_shape) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
    )


def _quantize_leaf(g: jax.Array):
    """Symmetric int8 per-block quantization: returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127).astype(
        jnp.int8
    )
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def compress_decompress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Round-trip a gradient leaf through int8; returns (g_hat, error)."""
    q, scale = _quantize_leaf(g)
    g_hat = _dequantize_leaf(q, scale, g.shape)
    return g_hat, g.astype(jnp.float32) - g_hat


def apply_error_feedback(grads, ef: ErrorFeedbackState):
    """grads + residual -> int8 round trip -> (compressed grads, new state)."""

    def leaf(g, r):
        g_hat, err = compress_decompress(g.astype(jnp.float32) + r)
        return g_hat.astype(g.dtype), err

    out = jax.tree.map(leaf, grads, ef.residual)
    g_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return g_new, ErrorFeedbackState(residual=res)
