"""Training step factory: fwd/bwd, optional microbatch gradient
accumulation, gradient clipping, optional int8 error-feedback compression,
AdamW.  Pure function of (params, opt_state, batch) — jit/pjit-ready."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import train_forward
from repro.train.grad_compress import ErrorFeedbackState, apply_error_feedback, ef_init
from repro.train.optim import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any  # ErrorFeedbackState | None


def init_train_state(
    cfg: ArchConfig,
    key=None,
    abstract: bool = False,
    moment_dtype=None,
    compress: bool = False,
) -> TrainState:
    from repro.models.model import init_params

    params = init_params(cfg, key, abstract=abstract)
    if abstract:
        opt = jax.eval_shape(functools.partial(adamw_init, moment_dtype=moment_dtype), params)
        ef = jax.eval_shape(ef_init, params) if compress else None
    else:
        opt = adamw_init(params, moment_dtype=moment_dtype)
        ef = ef_init(params) if compress else None
    return TrainState(params=params, opt=opt, ef=ef)


def make_train_step(
    cfg: ArchConfig,
    lr_schedule: Callable | None = None,
    grad_accum: int = 1,
    max_grad_norm: float = 1.0,
    compress_grads: bool = False,
):
    lr_schedule = lr_schedule or cosine_schedule(3e-4, 100, 10000)

    def loss_fn(params, batch):
        loss, _ = train_forward(cfg, params, batch)
        return loss

    def compute_grads(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        # microbatch accumulation: batch (B, ...) -> (A, B/A, ...)
        def reshape(leaf):
            return leaf.reshape((grad_accum, leaf.shape[0] // grad_accum) + leaf.shape[1:])

        micro = jax.tree.map(reshape, batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (
                loss_acc + loss,
                jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g),
            ), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), g0), micro)
        scale = 1.0 / grad_accum
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = compute_grads(state.params, batch)
        ef = state.ef
        if compress_grads:
            grads, ef = apply_error_feedback(grads, ef)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(state.opt.step)
        params, opt = adamw_update(grads, state.opt, state.params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=params, opt=opt, ef=ef), metrics

    return train_step
