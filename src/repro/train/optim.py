"""AdamW from scratch (no optax in this environment).

State layout mirrors the param pytree, so whatever sharding the params carry
(2-D FSDP x TP, DESIGN §5) applies to m/v too — ZeRO-style optimizer-state
sharding falls out for free.  ``dtype`` allows bf16 moments for the
trillion-parameter dry-runs.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params, moment_dtype=None) -> AdamWState:
    def zeros_like(p):
        dt = moment_dtype or p.dtype
        return jnp.zeros(p.shape, dt)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros_like, params),
        v=jax.tree.map(zeros_like, params),
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """Returns (new_params, new_state). ``lr`` may be a traced scalar."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def cosine_schedule(
    base_lr: float, warmup: int, total: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr
