"""Activation-sharding hints.

Model code calls ``constrain(x, role_spec)`` at layer boundaries; when a mesh
context is active (set by launch/dryrun/train), roles resolve to mesh axes
and become with_sharding_constraint; otherwise they are no-ops (CPU unit
tests never see a mesh).

Roles: "dp" -> the data axes ("pod","data"), "tp" -> "model", None -> leave.
"""
from __future__ import annotations

import contextlib
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

_CTX: dict | None = None


@contextlib.contextmanager
def activation_sharding(
    mesh: jax.sharding.Mesh, enable: bool = True, policy: str = "fsdp"
):
    global _CTX
    from repro.distributed.sharding import data_axes

    prev = _CTX
    dp = data_axes(mesh)
    all_axes = tuple(dp) + ("model",)
    _CTX = (
        {
            # pure-DP policy: the batch carries every axis; no TP roles
            "dp": all_axes
            if policy == "dp"
            else (dp if len(dp) > 1 else (dp[0] if dp else None)),
            "tp": None if policy == "dp" else "model",
            "dptp": all_axes,
            "mesh": mesh,
        }
        if enable
        else None
    )
    try:
        yield
    finally:
        _CTX = prev


def tp_size() -> int:
    """Size of the model axis in the active context (1 when no mesh or when
    the pure-DP policy disabled TP roles)."""
    if _CTX is None or _CTX["tp"] is None:
        return 1
    return _CTX["mesh"].shape["model"]


def constrain(x: jax.Array, roles: Sequence[str | None]) -> jax.Array:
    """roles: one entry per dim of x, each "dp" | "tp" | None. Axes that do
    not divide the dim are dropped (same padding rule as param shardings)."""
    if _CTX is None:
        return x
    from repro.distributed.sharding import axis_size

    mesh = _CTX["mesh"]
    entries = []
    for r, dim in zip(roles, x.shape):
        entry = _CTX.get(r) if r else None
        if entry is not None and dim % axis_size(mesh, entry) != 0:
            entry = None
        entries.append(entry)
    return jax.lax.with_sharding_constraint(x, P(*entries))
