"""Per-architecture sharding rules (DESIGN §5).

2-D sharding: weight input-dims shard over the data axes (FSDP / ZeRO-3
storage) and output-dims over "model" (Megatron TP); experts shard over
"model" (EP).  Rules are keyed on the leaf name in the param pytree; stacked
layer dims (scan) get a leading None automatically.  GSPMD pads uneven
dims (e.g. vocab 51865, kv-heads 8 on a 16-way axis) transparently — noted
as a baseline inefficiency in EXPERIMENTS §Perf.

``dp`` below is ("data",) on the single-pod mesh and ("pod", "data") on the
multi-pod mesh: the pod axis simply widens FSDP/batch sharding, which keeps
all cross-pod traffic in the gradient/weight all-reduce class.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

TP = "model"


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _param_rules(dp) -> dict[str, P]:
    return {
        # embeddings / heads
        "embed": P(TP, dp),
        "lm_head": P(dp, TP),
        "patch_proj": P(dp, TP),
        # attention (GQA)
        "wq": P(dp, TP),
        "wk": P(dp, TP),
        "wv": P(dp, TP),
        "wo": P(TP, dp),
        "bq": P(TP),
        "bk": P(TP),
        "bv": P(TP),
        "bo": P(None),
        # attention (MLA)
        "w_dkv": P(dp, None),
        "w_krope": P(dp, None),
        "w_dq": P(dp, None),
        "w_uq": P(None, TP, None),
        "w_uk": P(None, TP, None),
        "w_uv": P(None, TP, None),
        # ffn
        "w_gate": P(dp, TP),
        "w_up": P(dp, TP),
        "w_down": P(TP, dp),
        "w_in": P(dp, TP),
        "w_out": P(TP, dp),
        "b_in": P(TP),
        "b_out": P(None),
        # moe
        "router": P(dp, None),
        "shared_gate": P(dp, TP),
        "shared_up": P(dp, TP),
        "shared_down": P(TP, dp),
        # mamba
        "in_proj": P(dp, TP),
        "conv_w": P(None, TP),
        "out_proj": P(TP, dp),
        "dt_bias": P(TP),
        "A_log": P(TP),
        "D": P(TP),
        "norm": P(TP),
    }


_MOE_EXPERT_RULES = {
    # experts: EP over model, FSDP over data on the d_model dim
    "w_gate": lambda dp: P(TP, dp, None),
    "w_up": lambda dp: P(TP, dp, None),
    "w_down": lambda dp: P(TP, None, dp),
}


def axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def filter_divisible(spec: P, shape, mesh: Mesh) -> P:
    """Explicit in_shardings require exact divisibility — drop axes that
    don't divide the dim (recorded as a padding/replication inefficiency in
    EXPERIMENTS §Perf)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is not None and shape[i] % axis_size(mesh, entry) != 0:
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


def _leaf_spec(path, leaf, dp, mesh) -> P:
    names = [getattr(k, "key", str(k)) for k in path]
    name = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    rules = _param_rules(dp)
    if parent == "moe" and name in _MOE_EXPERT_RULES:
        spec = _MOE_EXPERT_RULES[name](dp)
    elif name == "wo_mla":
        spec = P(TP, None, dp)  # (H, hd, D)
    elif name in rules:
        spec = rules[name]
    else:
        spec = P()  # norms, biases, scalars -> replicate
    # stacked layer/period dims: prepend None for the extra leading dims
    extra = leaf.ndim - len(spec)
    if extra > 0:
        spec = P(*([None] * extra), *spec)
    elif extra < 0:
        spec = P(*spec[-leaf.ndim:]) if leaf.ndim else P()
    return filter_divisible(spec, leaf.shape, mesh)


# --- parallelization policy ------------------------------------------------
# "fsdp": 2-D FSDP x TP weight sharding (default; required >= ~10B params)
# "dp"  : pure data parallelism for small archs — weights REPLICATED for
#         compute (no per-layer weight gathers), optimizer moments kept
#         sharded (ZeRO-1), batch sharded over every mesh axis.
#         §Perf-2 hillclimb: on a 242M-param arch this removed ~99.7% of the
#         per-step collective bytes.

DP_POLICY_MAX_BYTES = 2.5e9  # replicated bf16 weights must fit comfortably


def auto_policy(params_total: int) -> str:
    return "dp" if params_total * 2 <= DP_POLICY_MAX_BYTES else "fsdp"


def _under_opt_state(path) -> bool:
    names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
    return any(n in ("m", "v", "residual") for n in names)


def param_specs(params: Any, mesh: Mesh, policy: str = "fsdp"):
    """PartitionSpec pytree for a param (or optimizer-state) pytree."""
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def leaf_spec(path, leaf):
        if policy == "dp" and not _under_opt_state(path):
            return P(*([None] * leaf.ndim))  # replicated compute weights
        return _leaf_spec(path, leaf, dp, mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params: Any, mesh: Mesh, policy: str = "fsdp"):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params, mesh, policy)
    )


def batch_specs(batch: Any, mesh: Mesh, shard_batch: bool = True,
                policy: str = "fsdp"):
    """Inputs: batch dim over the data axes (pure-DP policy: over every axis
    that divides), everything else replicated."""
    dp = data_axes(mesh)
    dp_s = dp if len(dp) > 1 else (dp[0] if dp else None)
    all_axes = tuple(dp) + (TP,)

    def spec(leaf):
        if not shard_batch or leaf.ndim == 0:
            return P()
        tail = [None] * (leaf.ndim - 1)
        if policy == "dp" and leaf.shape[0] % axis_size(mesh, all_axes) == 0:
            return P(all_axes, *tail)
        return filter_divisible(P(dp_s, *tail), leaf.shape, mesh)

    return jax.tree.map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh, batch_size: int, seq_len: int):
    """KV/SSM cache sharding for serving.

    batch > 1 : batch over data axes, cache length over "model" (TP decode)
    batch == 1: (long-context) cache length over ALL axes — context-parallel
                decode; SSM states shard heads over "model".
    """
    dp = data_axes(mesh)
    dp_s = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        name = names[-1]
        nd = leaf.ndim
        if name in ("k", "v", "c_kv", "k_rope"):  # (layers?, B, L, ...)
            lead = nd - (4 if name in ("k", "v") else 3)
            if batch_size == 1:
                core = (None, tuple(dp) + (TP,)) if dp else (None, TP)
            else:
                core = (dp_s, TP)
            tail = nd - len(core) - lead
            return P(*([None] * lead), *core, *([None] * tail))
        if name == "ssm":  # (layers?, B, H, P, N)
            lead = nd - 4
            return P(*([None] * lead), dp_s if batch_size > 1 else None, TP, None, None)
        if name == "conv":  # (layers?, B, W-1, C)
            lead = nd - 3
            return P(*([None] * lead), dp_s if batch_size > 1 else None, None, TP)
        if name == "enc_out":  # (B, T, D)
            return P(dp_s if batch_size > 1 else None, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: filter_divisible(spec(path, leaf), leaf.shape, mesh),
        cache,
    )
