"""Shared utilities: pytree dataclasses, tie-breaking argmax, concave fns."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def pytree_dataclass(cls=None, *, meta_fields: tuple[str, ...] = ()):
    """Register a (frozen) dataclass as a JAX pytree.

    ``meta_fields`` are static (hashed into the treedef); everything else is a
    leaf/data field.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)


def first_argmax(x: jax.Array) -> jax.Array:
    """Index of the first occurrence of the maximum (paper's tie rule)."""
    return jnp.argmax(x)


def masked_first_argmax(x: jax.Array, valid: jax.Array) -> jax.Array:
    """First argmax over entries where ``valid`` is True."""
    return jnp.argmax(jnp.where(valid, x, NEG_INF))


CONCAVE_FNS: dict[str, Callable[[jax.Array], jax.Array]] = {
    # g(0) = 0 and concave increasing on x >= 0 — paper supports log / sqrt / inverse.
    "sqrt": lambda x: jnp.sqrt(jnp.maximum(x, 0.0)),
    "log": lambda x: jnp.log1p(jnp.maximum(x, 0.0)),
    "inverse": lambda x: x / (1.0 + jnp.maximum(x, 0.0)),
}


def get_concave(name: str) -> Callable[[jax.Array], jax.Array]:
    if name not in CONCAVE_FNS:
        raise ValueError(f"unknown concave fn {name!r}; choose from {sorted(CONCAVE_FNS)}")
    return CONCAVE_FNS[name]


def mask_from_indices(idxs: Any, n: int) -> jax.Array:
    """(k,) int indices (possibly with -1 padding) -> (n,) bool mask."""
    idxs = jnp.asarray(idxs, jnp.int32)
    valid = idxs >= 0
    return jnp.zeros((n,), bool).at[jnp.where(valid, idxs, 0)].set(valid, mode="drop")
