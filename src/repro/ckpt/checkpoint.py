"""Fault-tolerant checkpointing (DESIGN §6).

- Atomic: writes to <dir>.tmp then os.replace — a crash mid-save never
  corrupts the latest checkpoint.
- Sharded: each host writes one npz of its addressable shard data plus a
  msgpack manifest (step, config name, mesh shape, tree structure).
- Elastic restore: restore() re-shards onto whatever mesh the restarted job
  brings up (device_put with the new NamedSharding), so a job can come back
  on fewer/more pods after node loss.
- retention: keep_last prunes old steps.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, meta: dict | None = None,
         keep_last: int = 3) -> str:
    """Save a pytree checkpoint atomically. Returns the final path."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    arrays = {}
    manifest = {"step": step, "leaves": [], "meta": meta or {}}
    for name, leaf in _flatten_with_names(tree):
        arr = np.asarray(jax.device_get(leaf))
        key = name.replace("/", "__")
        arrays[key] = arr
        manifest["leaves"].append(
            {"name": name, "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    np.savez(os.path.join(tmp_dir, f"shard_{jax.process_index():05d}.npz"), **arrays)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)  # atomic publish
    _prune(ckpt_dir, keep_last)
    return step_dir


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    like: Any,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``like``; if ``shardings`` (a pytree of
    NamedSharding matching ``like``) is given, leaves are placed sharded —
    this is the elastic-restart path (the saving mesh may have differed)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, f"shard_{jax.process_index():05d}.npz"))

    by_name = {l["name"]: l for l in manifest["leaves"]}
    flat_like = _flatten_with_names(like)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    leaves = []
    for i, (name, leaf) in enumerate(flat_like):
        entry = by_name[name]
        arr = data[entry["key"]]
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"] | {
        "step": manifest["step"]
    }
