"""Shared layer primitives: norms, rotary embeddings (RoPE / M-RoPE),
sinusoidal positions, FFNs."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


def sinusoidal_positions(positions: jax.Array, dim: int, dtype=jnp.float32):
    """(...,) int positions -> (..., dim) sinusoidal embeddings (whisper)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (..., L) -> cos/sin of shape (..., L, head_dim//2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x (B, L, H, hd), positions (B, L) -> rotated (interleaved-half layout)."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)  # (B, L, hd/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: tuple[int, ...],
    theta: float = 1e6,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions (3, B, L) — temporal / height / width position streams; the
    rotary half-dim is split into ``sections`` (sums to hd/2), each section
    taking its angles from the corresponding stream.  For pure-text tokens
    all three streams are equal, recovering standard RoPE.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    cos_parts, sin_parts = [], []
    offset = 0
    half = hd // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    for s, sec in zip(positions, sections):
        ang = s.astype(jnp.float32)[..., None] * inv[offset : offset + sec]
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        offset += sec
    cos = jnp.concatenate(cos_parts, -1)[:, :, None, :]  # (B, L, 1, hd/2)
    sin = jnp.concatenate(sin_parts, -1)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN used by every modern assigned arch."""
    g = jax.nn.silu(jnp.einsum("bld,df->blf", x, w_gate.astype(x.dtype)))
    u = jnp.einsum("bld,df->blf", x, w_up.astype(x.dtype))
    return jnp.einsum("blf,fd->bld", g * u, w_down.astype(x.dtype))


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    """GELU MLP (whisper)."""
    h = jax.nn.gelu(
        jnp.einsum("bld,df->blf", x, w_in.astype(x.dtype)) + b_in.astype(x.dtype)
    )
    return jnp.einsum("blf,fd->bld", h, w_out.astype(x.dtype)) + b_out.astype(x.dtype)
