"""Attention: GQA (+qk_norm, bias, RoPE/M-RoPE), MLA (DeepSeek latent
attention with compressed-cache decode absorption), blockwise (flash-style)
attention in pure JAX for long sequences, cross-attention for enc-dec.

Conventions: hidden x is (B, L, D); caches are dicts of arrays; ``pos`` is
the number of tokens already in the cache (static python int or traced
scalar) for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_sharding import constrain, tp_size
from repro.models.layers import apply_mrope, apply_rope, rms_norm

_NEG = -1e30
FLASH_THRESHOLD = 8192  # switch to blockwise attention above this seq len
Q_BLOCK = 2048
KV_BLOCK = 2048


def _rope_q_k(cfg: ArchConfig, q, k, positions):
    if cfg.rope == "rope":
        return apply_rope(q, positions, cfg.rope_theta), apply_rope(
            k, positions, cfg.rope_theta
        )
    if cfg.rope == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return (
            apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta),
            apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta),
        )
    return q, k


def _gqa_scores_einsum(q, k):
    """q (B, Lq, KV, G, hd), k (B, Lk, KV, hd) -> (B, KV, G, Lq, Lk).

    KV heads are never materialized at full head count (GQA-native einsum)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def _gqa_out_einsum(p, v):
    """p (B, KV, G, Lq, Lk), v (B, Lk, KV, hd) -> (B, Lq, KV, G, hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v)


def dense_attention(q, k, v, causal: bool, q_offset=0):
    """Materializes the score matrix — used for short sequences / decode."""
    B, Lq, KV, G, hd = q.shape
    Lk = k.shape[1]
    scores = _gqa_scores_einsum(q, k) * (hd**-0.5)
    if causal:
        qpos = jnp.arange(Lq) + q_offset
        kpos = jnp.arange(Lk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, _NEG)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return _gqa_out_einsum(p, v)


def blockwise_attention(q, k, v, causal: bool):
    """Flash-style attention in pure JAX: outer scan over query blocks, inner
    scan over KV blocks with online softmax. Never materializes more than a
    (B, KV, G, Q_BLOCK, KV_BLOCK) score tile — this is what keeps the 32k
    prefill inside HBM (DESIGN §3)."""
    B, L, KV, G, hd = q.shape
    Lk = k.shape[1]
    qb = min(Q_BLOCK, L)
    kb = min(KV_BLOCK, Lk)
    n_q = L // qb
    n_k = Lk // kb
    assert L % qb == 0 and Lk % kb == 0, (L, Lk, qb, kb)
    scale = hd**-0.5

    q_r = q.reshape(B, n_q, qb, KV, G, hd)

    def q_step(_, qi):
        q_blk = q_r[:, qi]  # (B, qb, KV, G, hd)
        q_start = qi * qb

        def kv_step(carry, ki):
            acc, m, denom = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kb, kb, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kb, kb, axis=1)
            s = _gqa_scores_einsum(q_blk, k_blk).astype(jnp.float32) * scale
            if causal:
                qpos = q_start + jnp.arange(qb)
                kpos = ki * kb + jnp.arange(kb)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            denom = denom * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + _gqa_out_einsum(
                p.astype(q.dtype), v_blk
            ).astype(jnp.float32).transpose(0, 2, 3, 1, 4)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, qb), _NEG, jnp.float32)
        d0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        # NOTE: the baseline scans ALL kv blocks even for causal attention
        # (2x flops above the triangle); causal block-skipping is a §Perf
        # hillclimb item (needs a static q-block loop).
        (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0), jnp.arange(n_k))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(n_q))
    # outs (n_q, B, qb, KV, G, hd) -> (B, L, KV, G, hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, L, KV, G, hd)


def _maybe_qk_norm(cfg: ArchConfig, params, q, k):
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k


def gqa_attention(
    cfg: ArchConfig,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None = None,
    cache_len=None,
    causal: bool = True,
):
    """Returns (out (B, L, D), new_cache or None)."""
    B, L, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // KV
    dt = x.dtype

    def proj(w, b, heads):
        y = jnp.einsum("bld,do->blo", x, w.astype(dt))
        if b is not None:
            y = y + b.astype(dt)
        return y.reshape(B, L, heads, hd)

    q = proj(params["wq"], params.get("bq"), H)
    k = proj(params["wk"], params.get("bk"), KV)
    v = proj(params["wv"], params.get("bv"), KV)
    q, k = _maybe_qk_norm(cfg, params, q, k)
    q, k = _rope_q_k(cfg, q, k, positions)
    q = q.reshape(B, L, KV, G, hd)
    # TP placement for the attention activations, in preference order:
    #   1. KV-head dim (classic head-TP; KV caches shard too)
    #   2. query-group dim (GQA: Q heads shard, K/V replicate over TP)
    #   3. sequence dim (SP fallback when head counts don't divide the axis:
    #      scores shard over Lq, K/V replicate — bounds the score memory)
    ts = tp_size()
    from repro.distributed.act_sharding import constrain as _c

    if KV % ts == 0:
        q = _c(q, ("dp", None, "tp", None, None))
        k = _c(k, ("dp", None, "tp", None))
        v = _c(v, ("dp", None, "tp", None))
    elif G % ts == 0:
        q = _c(q, ("dp", None, None, "tp", None))
        k = _c(k, ("dp", None, None, None))
        v = _c(v, ("dp", None, None, None))
    elif L % ts == 0 and L > 1:
        q = _c(q, ("dp", "tp", None, None, None))
        k = _c(k, ("dp", None, None, None))
        v = _c(v, ("dp", None, None, None))

    if cache is not None:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_len, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_len, 1)
        new_cache = {"k": k_cache, "v": v_cache}
        if L > 1:
            # prefill-with-cache: attention over the freshly written prefix
            # (requires cache_len == 0, which is how prefill() calls us)
            if L > FLASH_THRESHOLD:
                out = blockwise_attention(q, k, v, causal=True)
            else:
                out = dense_attention(q, k, v, causal=True)
        else:
            # decode: one query attends over the whole (masked) cache
            Lk = k_cache.shape[1]
            kpos = jnp.arange(Lk)
            valid = kpos < (cache_len + L)
            scores = _gqa_scores_einsum(q, k_cache) * (hd**-0.5)
            scores = jnp.where(valid[None, None, None, None, :], scores, _NEG)
            p = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(dt)
            out = _gqa_out_einsum(p, v_cache)
    else:
        if L > FLASH_THRESHOLD:
            out = blockwise_attention(q, k, v, causal)
        else:
            out = dense_attention(q, k, v, causal)
        new_cache = None

    out = out.reshape(B, L, H * hd)
    y = jnp.einsum("blo,od->bld", out, params["wo"].astype(dt))
    if params.get("bo") is not None:
        y = y + params["bo"].astype(dt)
    return y, new_cache


def cross_attention(cfg: ArchConfig, params: dict, x, enc_kv: dict):
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    B, L, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim_
    dt = x.dtype
    q = (
        jnp.einsum("bld,do->blo", x, params["wq"].astype(dt))
        + params.get("bq", jnp.zeros((), dt)).astype(dt)
    ).reshape(B, L, H, hd)
    k, v = enc_kv["k"], enc_kv["v"]  # (B, Lk, H, hd)
    scores = jnp.einsum("blhd,bshd->bhls", q, k) * (hd**-0.5)
    p = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(dt)
    out = jnp.einsum("bhls,bshd->blhd", p, v).reshape(B, L, H * hd)
    y = jnp.einsum("blo,od->bld", out, params["wo"].astype(dt))
    if params.get("bo") is not None:
        y = y + params["bo"].astype(dt)
    return y


def mla_attention(
    cfg: ArchConfig,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None = None,
    cache_len=None,
):
    """DeepSeek-V2 Multi-head Latent Attention.

    Prefill: uncompressed compute; the cache stores only the compressed
    latent c_kv (kv_lora_rank) + the shared rope key (rope_head_dim) — the
    536-dim-per-token cache that makes 32k serving cheap.
    Decode: *absorbed* form — q_nope is folded through w_uk so scores are
    taken directly against the latent cache; the attention output stays in
    latent space and is expanded through w_uv only once.
    """
    B, L, D = x.shape
    H, hd, r = cfg.n_heads, cfg.head_dim_, cfg.rope_head_dim
    dt = x.dtype

    # --- projections ---
    c_kv = jnp.einsum("bld,dr->blr", x, params["w_dkv"].astype(dt))
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bld,dr->blr", x, params["w_krope"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cfg.q_lora_rank:
        c_q = jnp.einsum("bld,dr->blr", x, params["w_dq"].astype(dt))
        c_q = rms_norm(c_q, params["q_norm_lora"], cfg.norm_eps)
    else:
        c_q = x
    q_full = jnp.einsum("blr,rho->blho", c_q, params["w_uq"].astype(dt))
    q_full = constrain(q_full, ("dp", None, "tp", None))  # H carries TP
    q_nope, q_rope = q_full[..., :hd], q_full[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    scale = (hd + r) ** -0.5

    new_cache = None
    if cache is not None:
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv, cache_len, 1
        )
        krope_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, cache_len, 1
        )
        new_cache = {"c_kv": ckv_cache, "k_rope": krope_cache}

    if cache is None or L > 1:
        # uncompressed prefill path (cache, if present, is written above)
        k_nope = jnp.einsum("blr,rho->blho", c_kv, params["w_uk"].astype(dt))
        v = jnp.einsum("blr,rho->blho", c_kv, params["w_uv"].astype(dt))
        if L > FLASH_THRESHOLD:
            # pack the shared rope key alongside the per-head nope key so the
            # blockwise kernel sees one (hd + r) head dim; q/k layouts match.
            q_pack = jnp.concatenate(
                [q_nope, q_rope], axis=-1
            ).reshape(B, L, H, 1, hd + r)
            k_pack = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, L, H, r))],
                axis=-1,
            )
            v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, r)))
            out = blockwise_attention(q_pack, k_pack, v_pad, causal=True)
            out = out.reshape(B, L, H, hd + r)[..., :hd]
        else:
            s = (
                jnp.einsum("blho,bsho->bhls", q_nope, k_nope)
                + jnp.einsum("blhr,bsr->bhls", q_rope, k_rope)
            ) * scale
            qpos = jnp.arange(L)
            mask = qpos[:, None] >= qpos[None, :]
            s = jnp.where(mask[None, None], s, _NEG)
            p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(dt)
            out = jnp.einsum("bhls,bsho->blho", p, v)
    else:
        # absorbed decode: q_nope -> latent space through w_uk; attention and
        # its output stay in the compressed 512-d latent space
        Lk = new_cache["c_kv"].shape[1]
        q_lat = jnp.einsum("blho,rho->blhr", q_nope, params["w_uk"].astype(dt))
        s = (
            jnp.einsum("blhr,bsr->bhls", q_lat, new_cache["c_kv"])
            + jnp.einsum("blhr,bsr->bhls", q_rope, new_cache["k_rope"])
        ) * scale
        valid = jnp.arange(Lk) < (cache_len + L)
        s = jnp.where(valid[None, None, None], s, _NEG)
        p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(dt)
        out_lat = jnp.einsum("bhls,bsr->blhr", p, new_cache["c_kv"])
        out = jnp.einsum("blhr,rho->blho", out_lat, params["w_uv"].astype(dt))

    y = jnp.einsum("blho,hod->bld", out, params["wo_mla"].astype(dt))
    return y, new_cache
