"""Model assembly for the data-selection-for-training testbed.

These models are the *workload* side of the library: launch/train.py trains
them with per-round submodular coreset selection over their gradient/loss
embeddings, and launch/dryrun.py uses them to cost out the production
meshes.  Families: dense / moe / vlm (decoder-only transformer), hybrid
(jamba period-scan), ssm (mamba2), audio (whisper enc-dec).

All layer stacks are scanned (jax.lax.scan over stacked params) with
jax.checkpoint around the layer body — this keeps HLO size O(1) in depth
(fast compiles at 61-72 layers) and bounds activation memory.

Public entry points:
  init_params(cfg, key | abstract=True)
  train_forward(cfg, params, batch) -> (loss, metrics)
  prefill(cfg, params, batch)       -> (logits_last, cache)
  decode_step(cfg, params, cache, tokens, cache_len) -> (logits, cache)
  init_cache(cfg, batch_size, max_len, abstract=True)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_sharding import constrain
from repro.models.attention import (
    cross_attention,
    gqa_attention,
    mla_attention,
)
from repro.models.layers import (
    gelu_mlp,
    layer_norm,
    rms_norm,
    sinusoidal_positions,
    swiglu,
)
from repro.models.mamba import mamba_block
from repro.models.moe import moe_ffn

# ---------------------------------------------------------------------------
# scan-vs-unroll control
#
# XLA's cost_analysis does NOT account for while-loop (lax.scan) bodies, so
# the dry-run sets unroll mode to get truthful FLOP/byte/collective counts
# from the compiled artifact (launch/dryrun.py). Normal training/tests keep
# scan for O(1) HLO size.
# ---------------------------------------------------------------------------

_UNROLL = False


def set_unroll(value: bool):
    global _UNROLL
    _UNROLL = bool(value)


def maybe_scan(body, carry, xs, length: int | None = None):
    """lax.scan, or a python loop in dry-run unroll mode."""
    if not _UNROLL:
        return jax.lax.scan(body, carry, xs)
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


class _Init:
    """Tiny helper tracking a PRNG key chain."""

    def __init__(self, key, dtype):
        self.key = key
        self.dtype = dtype

    def mat(self, *shape, scale=0.02):
        self.key, sub = jax.random.split(self.key)
        return (jax.random.normal(sub, shape, jnp.float32) * scale).astype(self.dtype)

    def zeros(self, *shape):
        return jnp.zeros(shape, self.dtype)

    def ones(self, *shape):
        return jnp.ones(shape, self.dtype)


def _attn_params(cfg: ArchConfig, ini: _Init) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if cfg.mla:
        r_kv, r_q, r_r = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.rope_head_dim
        p = {
            "w_dkv": ini.mat(D, r_kv),
            "kv_norm": ini.ones(r_kv),
            "w_krope": ini.mat(D, r_r),
            "w_uk": ini.mat(r_kv, H, hd),
            "w_uv": ini.mat(r_kv, H, hd),
            "wo_mla": ini.mat(H, hd, D),
        }
        if r_q:
            p["w_dq"] = ini.mat(D, r_q)
            p["q_norm_lora"] = ini.ones(r_q)
            p["w_uq"] = ini.mat(r_q, H, hd + r_r)
        else:
            p["w_uq"] = ini.mat(D, H, hd + r_r)
        return p
    p = {
        "wq": ini.mat(D, H * hd),
        "wk": ini.mat(D, KV * hd),
        "wv": ini.mat(D, KV * hd),
        "wo": ini.mat(H * hd, D),
    }
    if cfg.use_bias:
        p.update(bq=ini.zeros(H * hd), bk=ini.zeros(KV * hd), bv=ini.zeros(KV * hd))
    if cfg.qk_norm:
        p.update(q_norm=ini.ones(hd), k_norm=ini.ones(hd))
    return p


def _ffn_params(cfg: ArchConfig, ini: _Init, gelu: bool = False) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if gelu:
        return {
            "w_in": ini.mat(D, F),
            "b_in": ini.zeros(F),
            "w_out": ini.mat(F, D),
            "b_out": ini.zeros(D),
        }
    return {"w_gate": ini.mat(D, F), "w_up": ini.mat(D, F), "w_down": ini.mat(F, D)}


def _moe_params(cfg: ArchConfig, ini: _Init) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert_
    p = {
        "router": ini.mat(D, E),
        "w_gate": ini.mat(E, D, F),
        "w_up": ini.mat(E, D, F),
        "w_down": ini.mat(E, F, D),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        p.update(
            shared_gate=ini.mat(D, Fs),
            shared_up=ini.mat(D, Fs),
            shared_down=ini.mat(Fs, D),
        )
    return p


def _mamba_params(cfg: ArchConfig, ini: _Init) -> dict:
    D, di, N, H, W = (
        cfg.d_model,
        cfg.d_inner,
        cfg.ssm_state,
        cfg.n_ssm_heads,
        cfg.ssm_conv_width,
    )
    return {
        "in_proj": ini.mat(D, 2 * di + 2 * N + H),
        "conv_w": ini.mat(W, di + 2 * N, scale=0.1),
        "dt_bias": ini.zeros(H),
        "A_log": ini.zeros(H),
        "D": ini.ones(H),
        "norm": ini.ones(di),
        "out_proj": ini.mat(di, D),
    }


def _decoder_layer_params(cfg: ArchConfig, ini: _Init, moe: bool, mamba: bool) -> dict:
    D = cfg.d_model
    p: dict[str, Any] = {"ln1": ini.ones(D)}
    if mamba:
        p["mixer"] = _mamba_params(cfg, ini)
    else:
        p["attn"] = _attn_params(cfg, ini)
    p["ln2"] = ini.ones(D)
    if moe:
        p["moe"] = _moe_params(cfg, ini)
    else:
        p["ffn"] = _ffn_params(cfg, ini, gelu=cfg.family == "audio")
    return p


def _whisper_dec_layer_params(cfg: ArchConfig, ini: _Init) -> dict:
    D = cfg.d_model
    return {
        "ln1": ini.ones(D),
        "b1": ini.zeros(D),
        "attn": _attn_params(cfg, ini),
        "ln_x": ini.ones(D),
        "bx": ini.zeros(D),
        "xattn": _attn_params(cfg, ini),
        "ln2": ini.ones(D),
        "b2": ini.zeros(D),
        "ffn": _ffn_params(cfg, ini, gelu=True),
    }


def _stack(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _make_params(cfg: ArchConfig, key) -> dict:
    ini = _Init(key, _dtype(cfg))
    D, V = cfg.d_model, cfg.vocab
    params: dict[str, Any] = {"embed": ini.mat(V, D)}
    if not cfg.tie_embeddings:
        params["lm_head"] = ini.mat(D, V)
    params["final_norm"] = ini.ones(D)

    if cfg.family == "audio":
        # whisper: encoder self-attn stack + decoder (self + cross) stack
        params["enc_layers"] = _stack(
            [
                {
                    "ln1": ini.ones(D),
                    "b1": ini.zeros(D),
                    "attn": _attn_params(cfg, ini),
                    "ln2": ini.ones(D),
                    "b2": ini.zeros(D),
                    "ffn": _ffn_params(cfg, ini, gelu=True),
                }
                for _ in range(cfg.enc_layers)
            ]
        )
        params["enc_norm"] = ini.ones(D)
        params["enc_norm_b"] = ini.zeros(D)
        params["dec_layers"] = _stack(
            [_whisper_dec_layer_params(cfg, ini) for _ in range(cfg.n_layers)]
        )
        params["final_norm_b"] = ini.zeros(D)
        return params

    if cfg.family == "hybrid":
        period = cfg.attn_every
        n_periods = cfg.n_layers // period
        for pos in range(period):
            layers = [
                _decoder_layer_params(
                    cfg,
                    ini,
                    moe=cfg.is_moe_layer(per * period + pos),
                    mamba=not cfg.is_attn_layer(per * period + pos),
                )
                for per in range(n_periods)
            ]
            params[f"pos{pos}"] = _stack(layers)
        return params

    if cfg.family == "ssm":
        params["layers"] = _stack(
            [
                _decoder_layer_params(cfg, ini, moe=False, mamba=True)
                for _ in range(cfg.n_layers)
            ]
        )
        return params

    if cfg.family == "vlm":
        params["patch_proj"] = ini.mat(D, D)

    # dense / moe / vlm decoder-only stacks
    n_pre = cfg.first_dense_layers if cfg.n_experts else 0
    if n_pre:
        params["layers_pre"] = _stack(
            [
                _decoder_layer_params(cfg, ini, moe=False, mamba=False)
                for _ in range(n_pre)
            ]
        )
    params["layers"] = _stack(
        [
            _decoder_layer_params(
                cfg, ini, moe=cfg.is_moe_layer(l), mamba=False
            )
            for l in range(n_pre, cfg.n_layers)
        ]
    )
    return params


def init_params(cfg: ArchConfig, key=None, abstract: bool = False):
    """Materialized (key given) or abstract ShapeDtypeStruct params."""
    if abstract:
        return jax.eval_shape(
            functools.partial(_make_params, cfg), jax.random.PRNGKey(0)
        )
    assert key is not None
    return _make_params(cfg, key)


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _apply_ffn(cfg: ArchConfig, lp: dict, h: jax.Array):
    if "moe" in lp:
        return moe_ffn(cfg, lp["moe"], h)
    if cfg.family == "audio":
        return gelu_mlp(h, lp["ffn"]["w_in"], lp["ffn"]["b_in"], lp["ffn"]["w_out"],
                        lp["ffn"]["b_out"])
    return swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])


def _norm(cfg: ArchConfig, x, scale, bias=None):
    if cfg.family == "audio":
        return layer_norm(x, scale, bias, cfg.norm_eps)
    return rms_norm(x, scale, cfg.norm_eps)


def _decoder_layer(
    cfg: ArchConfig,
    lp: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None,
    cache_len,
):
    """Pre-norm block: mixer (attn | mamba | mla) + FFN/MoE. Returns
    (x, new_cache)."""
    # layer-boundary residual: batch over data axes AND sequence over the
    # model axis (Megatron-SP): norms/FFN are token-pointwise so the L-shard
    # flows through; attention gathers only the small GQA K/V heads
    x = constrain(x, ("dp", "tp", None))
    h = _norm(cfg, x, lp["ln1"], lp.get("b1"))
    if "mixer" in lp:
        out, new_cache = mamba_block(cfg, lp["mixer"], h, cache)
    elif cfg.mla:
        out, new_cache = mla_attention(cfg, lp["attn"], h, positions, cache, cache_len)
    else:
        out, new_cache = gqa_attention(
            cfg, lp["attn"], h, positions, cache, cache_len
        )
    x = x + out
    x = constrain(x, ("dp", "tp", None))
    h = _norm(cfg, x, lp["ln2"], lp.get("b2"))
    x = x + _apply_ffn(cfg, lp, h)
    return x, new_cache


def _scan_stack(cfg, stacked, x, positions, caches, cache_len, remat=True):
    """Scan a uniform stacked layer group. caches: stacked pytree or None."""

    layer = functools.partial(_decoder_layer, cfg)
    if remat:
        layer = jax.checkpoint(layer)

    if caches is None:

        def body(h, lp):
            h, _ = layer(lp, h, positions, None, cache_len)
            return h, None

        x, _ = maybe_scan(body, x, stacked)
        return x, None

    def body(h, inp):
        lp, c = inp
        h, new_c = layer(lp, h, positions, c, cache_len)
        return h, new_c

    x, new_caches = maybe_scan(body, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# embeddings and heads
# ---------------------------------------------------------------------------


def _embed(cfg: ArchConfig, params, tokens):
    return params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))


def _head_matrix(cfg: ArchConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T  # (D, V)
    return params["lm_head"]


def chunked_xent(
    cfg: ArchConfig, hidden: jax.Array, head: jax.Array, targets: jax.Array,
    chunk: int | None = None,
):
    """Cross-entropy. Default: ONE remat'd computation over the (SP-sharded)
    sequence — per-device logits are (B_loc, L/tp, V) transients only.

    §Perf-1 iteration A2 finding: slicing the loss into dynamic chunks
    defeated GSPMD's sequence sharding (the traced slice offset forced an
    all-gather of the f32 residual/cotangent — 30 GB/device on kimi-k2);
    the optional ``chunk`` path is kept for unsharded long-L edge cases."""
    B, L, D = hidden.shape

    @jax.checkpoint  # recompute logits in bwd — never store (B, L, V)
    def piece(h, t):
        logits = jnp.einsum("bld,dv->blv", h, head.astype(h.dtype)).astype(
            jnp.float32
        )
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        # label logit via a one-hot contraction: vocab stays sharded (a
        # take_along_axis here would all-gather the full logits — §Perf)
        onehot = jax.nn.one_hot(t, logits.shape[-1], dtype=logits.dtype)
        ll = jnp.einsum("blv,blv->bl", logits, onehot)
        return (lse - ll).sum()

    if chunk is None or chunk >= L:
        return piece(hidden, targets) / (B * L)

    c = chunk
    assert L % c == 0
    n = L // c

    def body(acc, i):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * c, c, axis=1)
        t = jax.lax.dynamic_slice_in_dim(targets, i * c, c, axis=1)
        return acc + piece(h, t), None

    total, _ = maybe_scan(body, jnp.zeros((), jnp.float32), jnp.arange(n), length=n)
    return total / (B * L)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _backbone(cfg: ArchConfig, params, x, positions):
    """Token-embedded input -> final hidden states (no cache)."""
    if cfg.family == "hybrid":
        period = cfg.attn_every
        stacked = tuple(params[f"pos{p}"] for p in range(period))

        def period_body(h, per_params):
            for p in range(period):
                layer = jax.checkpoint(functools.partial(_decoder_layer, cfg))
                h, _ = layer(per_params[p], h, positions, None, None)
            return h, None

        x, _ = maybe_scan(period_body, x, stacked)
        return x
    if "layers_pre" in params:
        x, _ = _scan_stack(cfg, params["layers_pre"], x, positions, None, None)
    x, _ = _scan_stack(cfg, params["layers"], x, positions, None, None)
    return x


def _whisper_encode(cfg: ArchConfig, params, frames):
    """frames (B, T, D) stub embeddings -> encoder output."""
    B, T, D = frames.shape
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + sinusoidal_positions(jnp.arange(T), D, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(h, lp):
        a = layer_norm(h, lp["ln1"], lp["b1"], cfg.norm_eps)
        out, _ = gqa_attention(cfg, lp["attn"], a, positions, causal=False)
        h = h + out
        f = layer_norm(h, lp["ln2"], lp["b2"], cfg.norm_eps)
        h = h + gelu_mlp(f, lp["ffn"]["w_in"], lp["ffn"]["b_in"],
                         lp["ffn"]["w_out"], lp["ffn"]["b_out"])
        return h, None

    x, _ = maybe_scan(jax.checkpoint(body), x, params["enc_layers"])
    return layer_norm(x, params["enc_norm"], params["enc_norm_b"], cfg.norm_eps)


def _whisper_decoder(cfg, params, x, positions, enc_out, caches, cache_len):
    """Decoder stack; cross-attn K/V recomputed from enc_out per layer."""
    H, hd = cfg.n_heads, cfg.head_dim_
    B = x.shape[0]

    def body(h, inp):
        lp, c = inp
        a = layer_norm(h, lp["ln1"], lp["b1"], cfg.norm_eps)
        out, new_c = gqa_attention(cfg, lp["attn"], a, positions, c, cache_len)
        h = h + out
        xa = layer_norm(h, lp["ln_x"], lp["bx"], cfg.norm_eps)
        ek = jnp.einsum(
            "btd,do->bto", enc_out, lp["xattn"]["wk"].astype(h.dtype)
        ).reshape(B, -1, H, hd)
        ev = (
            jnp.einsum("btd,do->bto", enc_out, lp["xattn"]["wv"].astype(h.dtype))
            + lp["xattn"]["bv"].astype(h.dtype)
        ).reshape(B, -1, H, hd)
        h = h + cross_attention(cfg, lp["xattn"], xa, {"k": ek, "v": ev})
        f = layer_norm(h, lp["ln2"], lp["b2"], cfg.norm_eps)
        h = h + gelu_mlp(f, lp["ffn"]["w_in"], lp["ffn"]["b_in"],
                         lp["ffn"]["w_out"], lp["ffn"]["b_out"])
        return h, new_c

    if caches is None:
        x, _ = maybe_scan(
            jax.checkpoint(lambda h, lp: (body(h, (lp, None))[0], None)),
            x,
            params["dec_layers"],
        )
        return x, None
    x, new_caches = maybe_scan(jax.checkpoint(body), x, (params["dec_layers"], caches))
    return x, new_caches


def train_forward(cfg: ArchConfig, params, batch) -> tuple[jax.Array, dict]:
    """batch: tokens (B, L) [+ frames (B, T, D) for audio, patches
    (B, Np, D) for vlm]. Returns (mean xent loss, metrics)."""
    tokens = batch["tokens"]
    B, L = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1
    )

    if cfg.family == "audio":
        enc_out = _whisper_encode(cfg, params, batch["frames"])
        x = _embed(cfg, params, tokens)
        x = x + sinusoidal_positions(positions, cfg.d_model, x.dtype)
        x, _ = _whisper_decoder(cfg, params, x, positions, enc_out, None, None)
        x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
    else:
        x = _embed(cfg, params, tokens)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype) @ params["patch_proj"].astype(
                x.dtype
            )
            n_p = patches.shape[1]
            x = jnp.concatenate([patches, x[:, n_p:]], axis=1)
        x = _backbone(cfg, params, x, positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    loss = chunked_xent(cfg, x, _head_matrix(cfg, params), targets)
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _layer_cache_shape(cfg: ArchConfig, layer: int, B: int, max_len: int):
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family in ("ssm", "hybrid") and not cfg.is_attn_layer(layer):
        di, N, H, P, W = (
            cfg.d_inner,
            cfg.ssm_state,
            cfg.n_ssm_heads,
            cfg.ssm_head_dim,
            cfg.ssm_conv_width,
        )
        return {
            "conv": jax.ShapeDtypeStruct((B, W - 1, di + 2 * N), dt),
            "ssm": jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        }
    if cfg.mla:
        return {
            "c_kv": jax.ShapeDtypeStruct((B, max_len, cfg.kv_lora_rank), dt),
            "k_rope": jax.ShapeDtypeStruct((B, max_len, cfg.rope_head_dim), dt),
        }
    hd = cfg.head_dim_
    return {
        "k": jax.ShapeDtypeStruct((B, max_len, cfg.n_kv_heads, hd), dt),
        "v": jax.ShapeDtypeStruct((B, max_len, cfg.n_kv_heads, hd), dt),
    }


def init_cache(cfg: ArchConfig, B: int, max_len: int, abstract: bool = False):
    """Stacked caches matching the layer-stack structure."""

    def mk(shapes):
        return jax.tree.map(
            lambda s: s if abstract else jnp.zeros(s.shape, s.dtype), shapes,
            is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct),
        )

    def stack_abstract(shapes_list):
        def s(*leaves):
            l0 = leaves[0]
            return jax.ShapeDtypeStruct((len(leaves),) + l0.shape, l0.dtype)

        out = jax.tree.map(
            s, *shapes_list, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
        )
        return mk(out)

    if cfg.family == "audio":
        dec = stack_abstract(
            [_layer_cache_shape(cfg, l, B, max_len) for l in range(cfg.n_layers)]
        )
        enc_dt = jnp.dtype(cfg.compute_dtype)
        enc_shape = jax.ShapeDtypeStruct((B, cfg.enc_positions, cfg.d_model), enc_dt)
        return {"dec": dec, "enc_out": mk(enc_shape)}
    if cfg.family == "hybrid":
        period = cfg.attn_every
        n_per = cfg.n_layers // period
        return {
            f"pos{p}": stack_abstract(
                [
                    _layer_cache_shape(cfg, per * period + p, B, max_len)
                    for per in range(n_per)
                ]
            )
            for p in range(period)
        }
    caches = {}
    n_pre = cfg.first_dense_layers if cfg.n_experts else 0
    if n_pre:
        caches["pre"] = stack_abstract(
            [_layer_cache_shape(cfg, l, B, max_len) for l in range(n_pre)]
        )
    caches["layers"] = stack_abstract(
        [_layer_cache_shape(cfg, l, B, max_len) for l in range(n_pre, cfg.n_layers)]
    )
    return caches


def decode_step(cfg: ArchConfig, params, caches, tokens, cache_len):
    """One decode step: tokens (B, 1) at position cache_len. Returns
    (logits (B, 1, V), new_caches)."""
    B = tokens.shape[0]
    positions = jnp.full((B, 1), cache_len, jnp.int32)

    if cfg.family == "audio":
        x = _embed(cfg, params, tokens)
        x = x + sinusoidal_positions(positions, cfg.d_model, x.dtype)
        x, dec_caches = _whisper_decoder(
            cfg, params, x, positions, caches["enc_out"], caches["dec"], cache_len
        )
        x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
        new_caches = {"dec": dec_caches, "enc_out": caches["enc_out"]}
    elif cfg.family == "hybrid":
        x = _embed(cfg, params, tokens)
        period = cfg.attn_every
        stacked = tuple(params[f"pos{p}"] for p in range(period))
        cache_tup = tuple(caches[f"pos{p}"] for p in range(period))

        def period_body(h, inp):
            per_params, per_caches = inp
            new_cs = []
            for p in range(period):
                h, c = _decoder_layer(
                    cfg, per_params[p], h, positions, per_caches[p], cache_len
                )
                new_cs.append(c)
            return h, tuple(new_cs)

        x, new_tup = maybe_scan(period_body, x, (stacked, cache_tup))
        new_caches = {f"pos{p}": new_tup[p] for p in range(period)}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    else:
        x = _embed(cfg, params, tokens)
        new_caches = {}
        if "pre" in caches:
            x, new_caches["pre"] = _scan_stack(
                cfg, params["layers_pre"], x, positions, caches["pre"], cache_len
            )
        x, new_caches["layers"] = _scan_stack(
            cfg, params["layers"], x, positions, caches["layers"], cache_len
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    logits = jnp.einsum(
        "bld,dv->blv", x, _head_matrix(cfg, params).astype(x.dtype)
    ).astype(jnp.float32)
    return logits, new_caches


def prefill(cfg: ArchConfig, params, batch, max_len: int | None = None):
    """Processes batch['tokens'] (B, L), returns (last-token logits, caches
    filled up to L)."""
    tokens = batch["tokens"]
    B, L = tokens.shape
    max_len = max_len or L
    caches = init_cache(cfg, B, max_len)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    if cfg.family == "audio":
        enc_out = _whisper_encode(cfg, params, batch["frames"])
        x = _embed(cfg, params, tokens)
        x = x + sinusoidal_positions(positions, cfg.d_model, x.dtype)
        x, dec_caches = _whisper_decoder(
            cfg, params, x, positions, enc_out, caches["dec"], 0
        )
        x = layer_norm(x, params["final_norm"], params["final_norm_b"], cfg.norm_eps)
        new_caches = {"dec": dec_caches, "enc_out": enc_out}
    elif cfg.family == "hybrid":
        x = _embed(cfg, params, tokens)
        period = cfg.attn_every
        stacked = tuple(params[f"pos{p}"] for p in range(period))
        cache_tup = tuple(caches[f"pos{p}"] for p in range(period))

        def period_body(h, inp):
            per_params, per_caches = inp
            new_cs = []
            for p in range(period):
                layer = jax.checkpoint(functools.partial(_decoder_layer, cfg))
                h, c = layer(per_params[p], h, positions, per_caches[p], 0)
                new_cs.append(c)
            return h, tuple(new_cs)

        x, new_tup = maybe_scan(period_body, x, (stacked, cache_tup))
        new_caches = {f"pos{p}": new_tup[p] for p in range(period)}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    else:
        x = _embed(cfg, params, tokens)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype) @ params["patch_proj"].astype(
                x.dtype
            )
            n_p = patches.shape[1]
            x = jnp.concatenate([patches, x[:, n_p:]], axis=1)
        new_caches = {}
        if "pre" in caches:
            x, new_caches["pre"] = _scan_stack(
                cfg, params["layers_pre"], x, positions, caches["pre"], 0
            )
        x, new_caches["layers"] = _scan_stack(
            cfg, params["layers"], x, positions, caches["layers"], 0
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)

    last = x[:, -1:]
    logits = jnp.einsum(
        "bld,dv->blv", last, _head_matrix(cfg, params).astype(x.dtype)
    ).astype(jnp.float32)
    return logits, new_caches
