"""Mixture-of-Experts: token-choice top-k routing with GShard-style grouped
one-hot dispatch (capacity-dropped), plus dense shared experts.

Design notes (DESIGN §3, §5):
- Tokens are reshaped into groups of ``moe_group_size`` so the dispatch
  einsum cost is g/(3*d_ff) of the expert FFN cost (~8% at g=512, f=2048)
  instead of quadratic in the full per-shard token count.
- Dispatch/combine are einsums, so sharding the expert axis over "model"
  (EP) and the group axis over "data"/"pod" makes the token->expert
  all-to-all emerge from GSPMD rather than hand-written collectives.
- Capacity factor 1.0 with token dropping (overflow tokens pass through the
  residual only) — the standard TPU-training configuration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_sharding import constrain
from repro.models.layers import swiglu


def moe_ffn(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    """x (B, L, D) -> (B, L, D).

    Group layout is (B, nL, g, D): the batch dim keeps its "dp" sharding and
    the sequence-block dim its "tp" (SP) sharding through every einsum — a
    flat (B*L) reshape would interleave the two axes and trigger GSPMD's
    involuntary-full-remat fallback (replicating the whole tensor; observed
    as a 28x collective blow-up on kimi-k2, EXPERIMENTS §Perf-1)."""
    from repro.distributed.act_sharding import tp_size

    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = x.dtype
    # pick the group count nL as a multiple of the TP degree so the
    # sequence-block dim can carry the "tp" sharding cleanly
    ts = max(tp_size(), 1)
    g_target = min(cfg.moe_group_size, L)
    nL = -(-L // g_target)  # ceil
    nL = -(-nL // ts) * ts  # round up to a multiple of ts
    g = -(-L // nL)
    pad = nL * g - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    cap = max(1, int(g * K * cfg.capacity_factor / E))

    xt = x.reshape(B, nL, g, D)
    xt = constrain(xt, ("dp", "tp", None, None))
    router_logits = jnp.einsum(
        "bngd,de->bnge", xt.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    # keep routing tensors on the token sharding: without this, GSPMD
    # gathered the (B, nL, g, E) probs over the batch axis for top_k (§Perf-1)
    router_logits = constrain(router_logits, ("dp", "tp", None, None))
    probs = jax.nn.softmax(router_logits, axis=-1)  # (B, nL, g, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (B, nL, g, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B, nL, g, K, E)
    pos = jnp.cumsum(onehot.reshape(B, nL, g * K, E), axis=2).reshape(
        B, nL, g, K, E
    ) * onehot - 1.0
    kept = (pos >= 0) & (pos < cap)
    pos = jnp.where(kept, pos, 0.0).astype(jnp.int32)
    cap_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * kept[..., None]
    # dispatch / combine weights, (B, nL, g, E, cap)
    dispatch = (onehot[..., None] * cap_oh).sum(axis=3)
    combine = (gate_vals[..., None, None] * onehot[..., None] * cap_oh).sum(axis=3)

    # group(seq-block over tp) -> expert(E over tp) re-layout: THE MoE
    # all-to-all, emitted by GSPMD between these two constraints
    expert_in = jnp.einsum("bngec,bngd->bnecd", dispatch.astype(dt), xt)
    expert_in = constrain(expert_in, ("dp", None, "tp", None, None))
    h = jax.nn.silu(
        jnp.einsum("bnecd,edf->bnecf", expert_in, params["w_gate"].astype(dt))
    ) * jnp.einsum("bnecd,edf->bnecf", expert_in, params["w_up"].astype(dt))
    expert_out = jnp.einsum("bnecf,efd->bnecd", h, params["w_down"].astype(dt))
    expert_out = constrain(expert_out, ("dp", None, "tp", None, None))
    y = jnp.einsum("bngec,bnecd->bngd", combine.astype(dt), expert_out)
    y = constrain(y, ("dp", "tp", None, None))

    y = y.reshape(B, L + pad, D)
    if cfg.n_shared_experts:
        y = y + swiglu(
            x, params["shared_gate"], params["shared_up"], params["shared_down"]
        )
    if pad:
        y = y[:, :L]
    return y


def moe_aux_loss(router_probs: jax.Array, gate_idx: jax.Array, n_experts: int):
    """Switch-style load-balancing auxiliary loss (for the training loop)."""
    me = router_probs.mean(axis=tuple(range(router_probs.ndim - 1)))
    ce = jax.nn.one_hot(gate_idx[..., 0], n_experts).mean(
        axis=tuple(range(gate_idx.ndim - 1))
    )
    return n_experts * jnp.sum(me * ce)
