"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060], TPU-adapted.

The chunked SSD algorithm maps the selective scan onto matmuls (MXU-friendly)
instead of a length-L sequential scan:
- intra-chunk: a (Q, Q) causal "attention-like" matmul per chunk
- inter-chunk: a lax.scan over n_chunks carrying the (H, P, N) state

Decode is the O(1) recurrent update  S <- dA * S + dt * (B ⊗ x),
y = C · S + D*x — constant memory at any context length, which is why the
SSM/hybrid archs are the only ones that run the long_500k cell (DESIGN §4).

Single B/C group (n_groups=1), heads H = d_inner / ssm_head_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_sharding import constrain
from repro.models.layers import rms_norm


def _split_proj(cfg: ArchConfig, z_x_b_c_dt: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z, x, B, C, dt = jnp.split(z_x_b_c_dt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    return z, x, B, C, dt  # dt: (B, L, H)


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv, x (B, L, C), w (W, C). Returns (y, new_state)
    where state is the last W-1 inputs for streaming decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+W-1, C)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    new_state = xp[:, -(W - 1) :] if W > 1 else pad
    return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x (B, L, H, P)   dt (B, L, H)  [post-softplus]
    A (H,) negative  Bm, Cm (B, L, N)
    Returns y (B, L, H, P) and the final state (B, H, P, N).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xr = x.reshape(Bsz, nc, Q, H, P)
    dtr = dt.reshape(Bsz, nc, Q, H)
    Br = Bm.reshape(Bsz, nc, Q, N)
    Cr = Cm.reshape(Bsz, nc, Q, N)

    dA = dtr * A  # (B, nc, Q, H), negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay
    total = cum[:, :, -1]  # (B, nc, H)

    # intra-chunk (causal quadratic form): M[t,s] = C_t·B_s * exp(cum_t - cum_s) * dt_s
    CB = jnp.einsum("bnqm,bnsm->bnqs", Cr, Br)  # (B, nc, Q, Q)
    decay = jnp.exp(
        cum[:, :, :, None, :] - cum[:, :, None, :, :]
    )  # (B, nc, Q, Q, H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    M = CB[..., None] * jnp.where(causal[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bnqsh,bnsh,bnshp->bnqhp", M, dtr, xr)

    # chunk summaries: S_n = sum_s exp(total - cum_s) dt_s B_s ⊗ x_s
    w_state = jnp.exp(total[:, :, None, :] - cum) * dtr  # (B, nc, Q, H)
    S_chunk = jnp.einsum("bnqh,bnqm,bnqhp->bnhpm", w_state, Br, xr)

    # inter-chunk recurrence over chunk states
    def step(S, inp):
        S_c, tot = inp  # (B, H, P, N), (B, H)
        S_new = S * jnp.exp(tot)[:, :, None, None] + S_c
        return S_new, S

    S0 = jnp.zeros((Bsz, H, P, N), x.dtype)
    S_final, S_prevs = jax.lax.scan(
        step,
        S0,
        (S_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # inter-chunk contribution: y_t += C_t · (exp(cum_t) * S_prev)
    y_inter = jnp.einsum(
        "bnqm,bnqh,bnhpm->bnqhp", Cr, jnp.exp(cum), S_prevs
    )
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y, S_final


def mamba_block(
    cfg: ArchConfig,
    params: dict,
    x: jax.Array,
    state: dict | None = None,
):
    """Full Mamba2 mixer. x (B, L, D). ``state`` enables streaming decode:
    {"conv": (B, W-1, conv_ch), "ssm": (B, H, P, N)}."""
    B, L, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype

    zxbcdt = jnp.einsum("bld,do->blo", x, params["in_proj"].astype(dt_))
    z, xs, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, params["conv_w"], None if state is None else state["conv"]
    )
    xs, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)
    xh = xs.reshape(B, L, H, P)
    xh = constrain(xh, ("dp", None, "tp", None))  # SSM heads carry TP

    if state is None:
        y, S_final = ssd_chunked(
            xh.astype(jnp.float32),
            dt,
            A,
            Bm.astype(jnp.float32),
            Cm.astype(jnp.float32),
            cfg.ssm_chunk,
        )
        new_state = {"conv": conv_state, "ssm": S_final}
    else:
        # recurrent decode (L == 1)
        S = state["ssm"].astype(jnp.float32)  # (B, H, P, N)
        dA = jnp.exp(dt[:, 0] * A)  # (B, H)
        inc = jnp.einsum(
            "bh,bm,bhp->bhpm", dt[:, 0], Bm[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        S = S * dA[:, :, None, None] + inc
        y = jnp.einsum("bm,bhpm->bhp", Cm[:, 0].astype(jnp.float32), S)[:, None]
        new_state = {"conv": conv_state, "ssm": S}

    y = y.astype(dt_) + xh * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, L, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)  # gated norm
    out = jnp.einsum("blo,od->bld", y, params["out_proj"].astype(dt_))
    return out, new_state
