"""Set-function protocol.

Every submodular (or near-submodular) function in the library is a pytree
object exposing a *functional, memoized* interface, mirroring the paper's
memoization design (Tables 3-4) but vectorized over the whole candidate set:

  state  = fn.init_state()          # pre-computed statistics for A = {}
  gains  = fn.gains(state)          # (n,) marginal gains f(j | A) for ALL j
  state  = fn.update(state, j)      # A <- A + {j}, O(stat) incremental
  value  = fn.evaluate(mask)        # f(A) from scratch (oracle, for tests)
  value  = fn.evaluate_state(state) # f(A) from the memoized statistics

Instances are pytrees so they pass through jit/shard_map; ``n`` and other
shape-determining attributes are static meta fields.

Functions either hold their statistics dense (a materialized kernel matrix)
or matrix-free behind a :class:`~repro.core.sources.SimilaritySource`
(features + metric, sparse k-NN, or a dense matrix on the same contract) —
the protocol is identical either way, so optimizers, batched engines, and
the serving coalescer never distinguish the two.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _mask_negative_idxs(method):
    """Make ``gains_at`` NEG_INF on negative indices instead of wrapping.

    Every dense implementation is a plain gather, so idx = -1 silently reads
    the LAST row — an engine passing an unfiltered ``order`` buffer (-1
    padded) would treat a ghost of the last candidate as selectable.  The
    wrapper clamps negatives before the implementation runs and masks them
    to NEG_INF after, leaving idx >= 0 results bit-identical.
    """
    if getattr(method, "_neg_masked", False):
        return method

    @functools.wraps(method)
    def wrapped(self, state, idxs):
        from repro.common import NEG_INF

        idxs = jnp.asarray(idxs)
        g = method(self, state, jnp.maximum(idxs, 0))
        return jnp.where(idxs < 0, jnp.asarray(NEG_INF, g.dtype), g)

    wrapped._neg_masked = True
    return wrapped


class SetFunction:
    """Duck-typed base; concrete functions are frozen pytree dataclasses."""

    n: int  # ground-set size

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        # families override gains_at with gather-shaped implementations; wrap
        # each override (and, below, the base default) exactly once so the
        # negative-index contract holds for every family, dense or
        # matrix-free, without per-family bookkeeping
        impl = cls.__dict__.get("gains_at")
        if impl is not None:
            cls.gains_at = _mask_negative_idxs(impl)

    # -- interface -----------------------------------------------------------
    def init_state(self):
        raise NotImplementedError

    def gains(self, state) -> jax.Array:
        """Marginal gains f(j|A) for every ground element j, shape (n,)."""
        raise NotImplementedError

    def gains_at(self, state, idxs: jax.Array) -> jax.Array:
        """Gains for a subset of candidates (default: gather from full sweep).

        Functions with gather-friendly statistics override this with an
        O(k * stat) implementation used by the stochastic/lazy optimizers.
        """
        return self.gains(state)[idxs]

    def gain_backend(self):
        """Advertise a fused full-sweep backend (see optimizers/backends.py).

        Return an object with ``full_sweep(fn, state) -> (n,)`` — typically a
        Pallas-kernel wrapper — or None to use the plain ``gains()`` XLA path.
        Resolution happens at trace time, so the decision may only depend on
        static meta fields.
        """
        return None

    def update(self, state, j: jax.Array):
        raise NotImplementedError

    def evaluate(self, mask: jax.Array) -> jax.Array:
        """f(A) from scratch. ``mask`` is an (n,) bool membership vector."""
        raise NotImplementedError

    def evaluate_state(self, state) -> jax.Array:
        raise NotImplementedError

    # -- helpers -------------------------------------------------------------
    def evaluate_indices(self, idxs) -> jax.Array:
        from repro.common import mask_from_indices

        return self.evaluate(mask_from_indices(idxs, self.n))

    def marginal_gain(self, mask: jax.Array, j) -> jax.Array:
        """Oracle marginal gain f(A + j) - f(A); used by property tests."""
        mask = jnp.asarray(mask, bool)
        return self.evaluate(mask.at[j].set(True)) - self.evaluate(mask)


# the default gather honors the same negative-index contract as overrides
SetFunction.gains_at = _mask_negative_idxs(SetFunction.gains_at)
