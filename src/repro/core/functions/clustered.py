"""Clustered mixtures (paper §8):  f(A) = sum_l f_{C_l}(A ∩ C_l).

For kernel-based functions (FL, GC, LogDet, Disparity*) the mixture over a
hard clustering is exactly the base function evaluated on the *block-masked*
kernel S'_ij = S_ij * [cluster(i) == cluster(j)]: cross-cluster interactions
vanish, so every memoized statistic decomposes per-cluster for free (and for
LogDet the masked kernel is block-diagonal, whose determinant is the product
of per-cluster determinants).  This keeps the clustered mode on the same
vectorized/TPU path as the dense mode.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def cluster_mask(labels) -> jnp.ndarray:
    labels = jnp.asarray(labels)
    return (labels[:, None] == labels[None, :]).astype(jnp.float32)


def clustered(base_from_kernel: Callable, kernel, labels, **kwargs):
    """Build a clustered mixture of a kernel-based function.

    ``base_from_kernel`` is a ``from_kernel``/``from_distance`` constructor;
    ``labels`` is an (n,) int cluster assignment (user-provided, e.g. from
    supervised classes, or produced by :func:`repro.core.similarity.kmeans`).
    """
    kernel = jnp.asarray(kernel)
    return base_from_kernel(kernel * cluster_mask(labels), **kwargs)
