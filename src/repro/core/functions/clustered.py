"""Clustered mixtures (paper §8):  f(A) = sum_l f_{C_l}(A ∩ C_l).

For kernel-based functions (FL, GC, LogDet, Disparity*) the mixture over a
hard clustering is exactly the base function evaluated on the *block-masked*
kernel S'_ij = S_ij * [cluster(i) == cluster(j)]: cross-cluster interactions
vanish, so every memoized statistic decomposes per-cluster for free (and for
LogDet the masked kernel is block-diagonal, whose determinant is the product
of per-cluster determinants).  This keeps the clustered mode on the same
vectorized/TPU path as the dense mode.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def cluster_mask(labels) -> jnp.ndarray:
    labels = jnp.asarray(labels)
    return (labels[:, None] == labels[None, :]).astype(jnp.float32)


def clustered(base_from_kernel: Callable, kernel, labels, **kwargs):
    """Build a clustered mixture of a kernel-based function.

    ``base_from_kernel`` is a ``from_kernel``/``from_distance`` constructor;
    ``labels`` is an (n,) int cluster assignment (user-provided, e.g. from
    supervised classes, or produced by :func:`repro.core.similarity.kmeans`).
    """
    kernel = jnp.asarray(kernel)
    return base_from_kernel(kernel * cluster_mask(labels), **kwargs)


def clustered_matrix_free(base_from_features: Callable, x, labels, **kwargs):
    """Matrix-free clustered mixture: neither the kernel NOR the block mask
    is ever materialized.

    ``base_from_features`` is a matrix-free constructor taking a ``labels``
    keyword (``FacilityLocationMF.from_features`` /
    ``GraphCutMF.from_features``); the labels ride the
    :class:`~repro.core.sources.FeatureSource` and zero cross-cluster
    similarity inside the streamed tile sweep, so the §8 decomposition
    scales to the same n the plain matrix-free path does.
    """
    return base_from_features(x, labels=jnp.asarray(labels, jnp.int32), **kwargs)
