"""Feature-Based function (paper §2.3.3):

  f(A) = sum_{f in F} w_f * g(m_f(A)),   m_f(A) = sum_{x in A} m_f(x)

with g concave in {sqrt, log, inverse}.  Memoized statistic (Table 3): the
accumulated modular feature vector m_f(A).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import get_concave, pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass
class FBState:
    acc: jax.Array  # (F,) accumulated feature mass m_f(A)


class FBPallasSweep:
    """GainBackend: fused add -> concave -> weighted-reduce over the feature
    matrix, streamed tile-wise (no (n, F) concave intermediate in HBM)."""

    name = "pallas-fb"

    def full_sweep(self, fn: "FeatureBased", state: FBState) -> jax.Array:
        from repro.kernels import ops

        return ops.fb_gains(fn.feats, state.acc, fn.w, fn.concave)

    def partial_sweep(
        self, fn: "FeatureBased", state: FBState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        return ops.fb_gains_at(fn.feats, state.acc, fn.w, idx, fn.concave)


@pytree_dataclass(meta_fields=("n", "concave", "use_kernel"))
class FeatureBased(SetFunction):
    feats: jax.Array  # (n, F) non-negative feature scores
    w: jax.Array  # (F,)
    n: int
    concave: str = "sqrt"
    # True/False routes sweeps through the Pallas kernel / XLA; None defers
    # to the trace-time choose_backend heuristic (backends.py)
    use_kernel: bool | None = False

    @staticmethod
    def from_features(
        feats: jax.Array,
        w: jax.Array | None = None,
        concave: str = "sqrt",
        use_kernel: bool | None = False,
    ) -> "FeatureBased":
        feats = jnp.maximum(jnp.asarray(feats, jnp.float32), 0.0)
        F = feats.shape[1]
        w = jnp.ones((F,), jnp.float32) if w is None else jnp.asarray(w, jnp.float32)
        get_concave(concave)  # validate
        return FeatureBased(
            feats=feats,
            w=w,
            n=int(feats.shape[0]),
            concave=concave,
            use_kernel=use_kernel,
        )

    def init_state(self) -> FBState:
        return FBState(acc=jnp.zeros((self.feats.shape[1],), jnp.float32))

    def gains(self, state: FBState) -> jax.Array:
        g = get_concave(self.concave)
        base = g(state.acc)  # (F,)
        # elementwise-multiply + reduce rather than `@ w`: XLA lowers a
        # batched matvec through a different GEMM tiling than the single
        # instance, which shifts gains by ulps under vmap; the reduce form is
        # bit-stable, keeping batched/sharded serving identical to single
        # `maximize` calls.
        diff = g(state.acc[None, :] + self.feats) - base[None, :]
        return (diff * self.w[None, :]).sum(axis=-1)

    def gains_at(self, state: FBState, idxs: jax.Array) -> jax.Array:
        g = get_concave(self.concave)
        base = g(state.acc)
        diff = g(state.acc[None, :] + self.feats[idxs]) - base[None, :]
        return (diff * self.w[None, :]).sum(axis=-1)

    def update(self, state: FBState, j: jax.Array) -> FBState:
        return FBState(acc=state.acc + self.feats[j])

    def gain_backend(self) -> FBPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return FBPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def evaluate(self, mask: jax.Array) -> jax.Array:
        g = get_concave(self.concave)
        acc = jnp.where(mask[:, None], self.feats, 0.0).sum(axis=0)
        return jnp.dot(self.w, g(acc))

    def evaluate_state(self, state: FBState) -> jax.Array:
        g = get_concave(self.concave)
        return jnp.dot(self.w, g(state.acc))
