"""Dispersion / Disparity functions (paper §2.2.1).

DisparitySum    f(X) = (1/2) sum_{i,j in X} d_ij          (supermodular)
DisparityMin    f(X) = min_{i!=j in X} d_ij               (not submodular)
DisparityMinSum f(X) = sum_{i in X} min_{j in X, j!=i} d_ij  (submodular [6])

Conventions: f(X) = 0 for |X| <= 1 for the min-based variants; DisparitySum
counts each unordered pair once.

Per the paper, DisparityMin is optimized with the specialized dispersion
greedy of Dasgupta et al. [11]: ``gains`` returns the dispersion surrogate
``min_{k in A} d_jk - f(A)`` (uncapped), whose argmax is the farthest-point
rule; ``evaluate`` remains the true set function.  Property tests therefore
check the gain/evaluate identity only for Sum and MinSum.

Serving hooks (see docs/functions.md for the coverage matrix):

- ``use_kernel=True`` on DisparitySum / DisparityMin routes full sweeps
  through the fused Pallas kernels in ``kernels/disp_gains.py`` via the
  ``gain_backend()`` hook.  Like GraphCut's, these are *stateless* sweeps
  recomputed from the selection mask (kept in the state for exactly this
  purpose) — the serving shape, where no memoized per-query state is
  resident.  DisparityMin's masked min is float-exact either way; the
  DisparitySum kernel's sum order differs from the incremental ``selsum``
  by ulps, so its mesh ShardRule — which must stay bit-identical to
  single-device ``maximize`` — rejects ``use_kernel=True`` instances
  (same policy as GraphCut; single-device serving handles them fine).
- Both register a zero row+column padder (``launch/coalesce.py``) and a
  candidate-row ShardRule over the memoized statistics
  (``optimizers/distributed.py``), so Disparity requests serve through
  ``SelectionServer`` on and off mesh.  Note the empty-set gain is 0 for
  both, so submit disparity requests with ``stopIfZeroGain=False``.

DisparityMinSum's gains reduce over *all rows* of the distance matrix
(including would-be padding rows), so zero-padding shifts its gains by ulps
— it deliberately registers no padder/ShardRule and is the pinned
unsupported-family error path in ``tests/test_serving.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction

_BIG = 1e30


@pytree_dataclass
class DSumState:
    selsum: jax.Array  # (n,) sum_{k in A} d_jk
    selmask: jax.Array  # (n,) 0/1 selection indicator (feeds the fused sweep)


class DSumPallasSweep:
    """GainBackend: stateless masked-matvec sweep over the distance matrix
    (recomputed from the selection mask; see kernels/disp_gains.py)."""

    name = "pallas-dsum"

    def full_sweep(self, fn: "DisparitySum", state: DSumState) -> jax.Array:
        from repro.kernels import ops

        return ops.dsum_gains(fn.dist, state.selmask)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class DisparitySum(SetFunction):
    dist: jax.Array  # (n, n) pairwise distances, zero diagonal
    n: int
    use_kernel: bool = False  # route full sweeps through the Pallas kernel

    @staticmethod
    def from_distance(dist: jax.Array, use_kernel: bool = False) -> "DisparitySum":
        dist = jnp.asarray(dist)
        return DisparitySum(dist=dist, n=int(dist.shape[0]), use_kernel=use_kernel)

    def init_state(self) -> DSumState:
        return DSumState(
            selsum=jnp.zeros((self.n,), self.dist.dtype),
            selmask=jnp.zeros((self.n,), jnp.float32),
        )

    def gains(self, state: DSumState) -> jax.Array:
        return state.selsum

    def gains_at(self, state: DSumState, idxs: jax.Array) -> jax.Array:
        return state.selsum[idxs]

    def gain_backend(self) -> DSumPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return DSumPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def update(self, state: DSumState, j: jax.Array) -> DSumState:
        return DSumState(
            selsum=state.selsum + self.dist[:, j],
            selmask=state.selmask.at[j].set(1.0),
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        m = mask.astype(self.dist.dtype)
        return 0.5 * (m @ self.dist @ m)

    def evaluate_state(self, state: DSumState) -> jax.Array:
        raise NotImplementedError("needs the selection mask; use evaluate().")


@pytree_dataclass
class DMinState:
    mind: jax.Array  # (n,) min_{k in A} d_jk  (BIG when A empty)
    curmin: jax.Array  # scalar f(A) (0 while |A| <= 1)
    count: jax.Array  # int32
    selmask: jax.Array  # (n,) 0/1 selection indicator (feeds the fused sweep)


class DMinPallasSweep:
    """GainBackend: stateless masked-min sweep recomputing ``mind`` from the
    selection mask (float-exact vs the memoized statistic — min is
    order-independent); see kernels/disp_gains.py."""

    name = "pallas-dmin"

    def full_sweep(self, fn: "DisparityMin", state: DMinState) -> jax.Array:
        from repro.kernels import ops

        return ops.dmin_gains(fn.dist, state.selmask, state.count, state.curmin)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class DisparityMin(SetFunction):
    dist: jax.Array
    n: int
    use_kernel: bool = False  # route full sweeps through the Pallas kernel

    @staticmethod
    def from_distance(dist: jax.Array, use_kernel: bool = False) -> "DisparityMin":
        dist = jnp.asarray(dist)
        return DisparityMin(dist=dist, n=int(dist.shape[0]), use_kernel=use_kernel)

    def init_state(self) -> DMinState:
        return DMinState(
            mind=jnp.full((self.n,), _BIG, self.dist.dtype),
            curmin=jnp.zeros((), self.dist.dtype),
            count=jnp.zeros((), jnp.int32),
            selmask=jnp.zeros((self.n,), jnp.float32),
        )

    def gains(self, state: DMinState) -> jax.Array:
        # Dispersion surrogate (see module docstring): farthest-point rule.
        surrogate = jnp.where(state.count == 0, 0.0, state.mind)
        return jnp.minimum(surrogate, _BIG) - state.curmin

    def gains_at(self, state: DMinState, idxs: jax.Array) -> jax.Array:
        surrogate = jnp.where(state.count == 0, 0.0, state.mind[idxs])
        return jnp.minimum(surrogate, _BIG) - state.curmin

    def gain_backend(self) -> DMinPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return DMinPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def update(self, state: DMinState, j: jax.Array) -> DMinState:
        newmin = jnp.where(
            state.count <= 0,
            state.curmin,  # first element: f stays 0
            jnp.where(
                state.count == 1,
                state.mind[j],  # second element: f = the pair distance
                jnp.minimum(state.curmin, state.mind[j]),
            ),
        )
        return DMinState(
            mind=jnp.minimum(state.mind, self.dist[:, j]),
            curmin=newmin,
            count=state.count + 1,
            selmask=state.selmask.at[j].set(1.0),
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        m = mask
        pair = jnp.logical_and(m[:, None], m[None, :])
        off = ~jnp.eye(self.n, dtype=bool)
        vals = jnp.where(pair & off, self.dist, _BIG)
        mn = jnp.min(vals)
        return jnp.where(jnp.sum(m) >= 2, mn, 0.0)

    def evaluate_state(self, state: DMinState) -> jax.Array:
        return state.curmin


@pytree_dataclass
class DMinSumState:
    t: jax.Array  # (n,): candidates -> min_{k in A} d_jk; selected -> h_i(A)
    selected: jax.Array  # (n,) bool
    count: jax.Array
    value: jax.Array


@pytree_dataclass(meta_fields=("n",))
class DisparityMinSum(SetFunction):
    dist: jax.Array
    n: int

    @staticmethod
    def from_distance(dist: jax.Array) -> "DisparityMinSum":
        dist = jnp.asarray(dist)
        return DisparityMinSum(dist=dist, n=int(dist.shape[0]))

    def init_state(self) -> DMinSumState:
        return DMinSumState(
            t=jnp.full((self.n,), _BIG, self.dist.dtype),
            selected=jnp.zeros((self.n,), bool),
            count=jnp.zeros((), jnp.int32),
            value=jnp.zeros((), self.dist.dtype),
        )

    def gains(self, state: DMinSumState) -> jax.Array:
        t_cand = jnp.minimum(state.t, _BIG)
        # contribution of already-selected elements whose min shrinks to d_ij
        delta = jnp.where(
            state.selected[:, None],
            jnp.minimum(state.t[:, None], self.dist) - state.t[:, None],
            0.0,
        ).sum(axis=0)
        gains = t_cand + delta
        gains = jnp.where(state.count == 1, 2.0 * t_cand, gains)
        return jnp.where(state.count == 0, 0.0, gains)

    def gains_at(self, state: DMinSumState, idxs: jax.Array) -> jax.Array:
        t_cand = jnp.minimum(state.t[idxs], _BIG)
        delta = jnp.where(
            state.selected[:, None],
            jnp.minimum(state.t[:, None], self.dist[:, idxs]) - state.t[:, None],
            0.0,
        ).sum(axis=0)
        gains = t_cand + delta
        gains = jnp.where(state.count == 1, 2.0 * t_cand, gains)
        return jnp.where(state.count == 0, 0.0, gains)

    def update(self, state: DMinSumState, j: jax.Array) -> DMinSumState:
        gain_j = self.gains(state)[j]
        # exclude the self-distance d_jj = 0 so j's own statistic stays
        # min_{k in A} d_jk rather than collapsing to zero
        dj = self.dist[:, j].at[j].set(_BIG)
        # selected elements (incl. the singleton case) take min with d_ij;
        # the newly added j keeps its candidate stat min_{k in A} d_jk.
        t_sel = jnp.where(
            state.count == 1, dj, jnp.minimum(state.t, dj)
        )  # value for previously-selected rows
        t = jnp.where(state.selected, t_sel, jnp.minimum(state.t, dj))
        return DMinSumState(
            t=t,
            selected=state.selected.at[j].set(True),
            count=state.count + 1,
            value=state.value + gain_j,
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        pair = jnp.logical_and(mask[:, None], mask[None, :])
        off = ~jnp.eye(self.n, dtype=bool)
        vals = jnp.where(pair & off, self.dist, _BIG)
        mins = jnp.min(vals, axis=1)
        contrib = jnp.where(mask & (mins < _BIG), mins, 0.0)
        return jnp.where(jnp.sum(mask) >= 2, contrib.sum(), 0.0)

    def evaluate_state(self, state: DMinSumState) -> jax.Array:
        return state.value


# The dispersion footgun, closed at the one resolution point: every
# Disparity* empty-set gain is exactly 0, so the library-wide
# stopIfZeroGain=True default would silently return an EMPTY selection.
# Registering stopIfZeroGain=False here makes SelectionSpec (and therefore
# sequential solve(), batched waves, AND serving) agree on the dispersion
# default — an explicit flag always wins.
from repro.core.optimizers.spec import register_family_defaults  # noqa: E402

for _cls in (DisparitySum, DisparityMin, DisparityMinSum):
    register_family_defaults(_cls, stopIfZeroGain=False)
del _cls
