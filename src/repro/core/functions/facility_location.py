"""Facility Location:  f(A) = sum_{i in U} max_{j in A} S_ij   (paper §2.1.1).

U is the *represented* set (rows of S) which may differ from the ground set V
(columns of S).  Memoized statistic (paper Table 3): ``curmax_i = max_{j in A}
S_ij`` for every i in U; with it a gain query is one fused relu-reduction,
which we evaluate for ALL candidates at once (TPU adaptation, see DESIGN §2).

The per-step full-candidate gain sweep is the compute hotspot and is backed by
the Pallas kernel in ``repro.kernels.fl_gains`` when the matrix is large.

:class:`FacilityLocationMF` is the matrix-free variant: it holds a
:class:`~repro.core.sources.SimilaritySource` (features + metric, sparse
k-NN, or a dense matrix riding the same contract) instead of the
materialized (|U|, n) matrix, so n is bounded by feature bytes, not n^2.
Feature-backed sweeps route through the fused Pallas kernel in
``repro.kernels.flmf_gains`` (similarity computed in-stream).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction
from repro.core.sources import (
    DenseSource,
    FeatureSource,
    dense_source,
    feature_source,
    knn_source,
)


@pytree_dataclass(meta_fields=("n_rows",))
class FLState:
    curmax: jax.Array  # (n_rows,) max similarity of each represented point to A
    n_rows: int


class FLPallasSweep:
    """GainBackend: fused relu-reduce sweep over the similarity matrix (full
    and gathered-subset entry points; see kernels/fl_gains.py)."""

    name = "pallas-fl"

    def full_sweep(self, fn: "FacilityLocation", state: FLState) -> jax.Array:
        from repro.kernels import ops

        return ops.fl_gains(fn.sim, state.curmax)

    def partial_sweep(
        self, fn: "FacilityLocation", state: FLState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        return ops.fl_gains_at(fn.sim, state.curmax, idx)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class FacilityLocation(SetFunction):
    sim: jax.Array  # (|U|, n) similarity, rows = represented set, cols = ground set
    n: int
    # True/False routes the gain sweeps through the Pallas kernel / XLA;
    # None defers to the trace-time choose_backend heuristic (backends.py)
    use_kernel: bool | None = False

    @staticmethod
    def from_kernel(
        sim: jax.Array, use_kernel: bool | None = False
    ) -> "FacilityLocation":
        sim = jnp.asarray(sim)
        return FacilityLocation(sim=sim, n=int(sim.shape[1]), use_kernel=use_kernel)

    def init_state(self) -> FLState:
        # f({}) = 0 with the standard convention max over empty set = 0
        # (requires S >= 0 for monotonicity; similarity.py guarantees this).
        return FLState(
            curmax=jnp.zeros((self.sim.shape[0],), self.sim.dtype),
            n_rows=int(self.sim.shape[0]),
        )

    def gains(self, state: FLState) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops

            return ops.fl_gains(self.sim, state.curmax)
        return jnp.maximum(self.sim - state.curmax[:, None], 0.0).sum(axis=0)

    def gain_backend(self) -> FLPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return FLPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def gains_at(self, state: FLState, idxs: jax.Array) -> jax.Array:
        cols = self.sim[:, idxs]  # (|U|, k)
        return jnp.maximum(cols - state.curmax[:, None], 0.0).sum(axis=0)

    def update(self, state: FLState, j: jax.Array) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.sim[:, j]), n_rows=state.n_rows
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        masked = jnp.where(mask[None, :], self.sim, 0.0)
        best = jnp.max(masked, axis=1, initial=0.0)
        return jnp.sum(best)

    def evaluate_state(self, state: FLState) -> jax.Array:
        return jnp.sum(state.curmax)


class FLMFPallasSweep:
    """GainBackend: matrix-free fused FL sweep — similarity computed
    in-stream from feature tiles (kernels/flmf_gains.py).  Dense sources
    reuse the materialized-matrix kernel (kernels/fl_gains.py)."""

    name = "pallas-flmf"

    def full_sweep(self, fn: "FacilityLocationMF", state: FLState) -> jax.Array:
        from repro.kernels import ops

        src = fn.src
        if isinstance(src, DenseSource):
            return ops.fl_gains(src.sim, state.curmax)
        return ops.flmf_gains(
            src.x, src.y, src.xx, src.yy, state.curmax,
            metric=src.metric, rbf_sigma=src.rbf_sigma,
        )

    def partial_sweep(
        self, fn: "FacilityLocationMF", state: FLState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        src = fn.src
        if isinstance(src, DenseSource):
            return ops.fl_gains_at(src.sim, state.curmax, idx)
        return ops.flmf_gains_at(
            src.x, src.y, src.xx, src.yy, state.curmax, idx,
            metric=src.metric, rbf_sigma=src.rbf_sigma,
        )


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class FacilityLocationMF(SetFunction):
    """Matrix-free Facility Location: same objective and memoized statistic
    as :class:`FacilityLocation`, but sim(i, j) is answered on demand by a
    :class:`~repro.core.sources.SimilaritySource` — the (|U|, n) matrix is
    never written.  Peak memory is O(n * d) feature bytes (or O(n * k)
    sparse entries), which is what unlocks n >= 10^6 selection."""

    src: object  # SimilaritySource (FeatureSource | KnnSource | DenseSource)
    n: int
    # True/False routes sweeps through the fused Pallas kernel / XLA; None
    # defers to the trace-time choose_backend heuristic (backends.py)
    use_kernel: bool | None = False

    @staticmethod
    def from_features(
        x,
        y=None,
        metric: str = "dot",
        rbf_sigma: float | None = None,
        labels=None,
        use_kernel: bool | None = False,
    ) -> "FacilityLocationMF":
        """FL over features + metric.  ``y`` is the candidate (column) side
        and defaults to ``x`` itself; ``labels`` switches on the clustered
        block-masked similarity (paper §8), streamed."""
        src = feature_source(x, y, metric=metric, rbf_sigma=rbf_sigma, labels=labels)
        return FacilityLocationMF(src=src, n=src.n_cols, use_kernel=use_kernel)

    @staticmethod
    def from_knn(
        indices, weights, n_cols: int | None = None,
        use_kernel: bool | None = False,
    ) -> "FacilityLocationMF":
        """FL over precomputed sparse k-NN similarity (indices (n, k) int32
        with -1 pads, nonnegative weights)."""
        src = knn_source(indices, weights, n_cols=n_cols)
        return FacilityLocationMF(src=src, n=src.n_cols, use_kernel=use_kernel)

    @staticmethod
    def from_dense(sim, use_kernel: bool | None = False) -> "FacilityLocationMF":
        """Dense matrix riding the matrix-free contract (interop/testing)."""
        src = dense_source(sim)
        return FacilityLocationMF(src=src, n=src.n_cols, use_kernel=use_kernel)

    def init_state(self) -> FLState:
        return FLState(
            curmax=jnp.zeros((self.src.n_rows,), jnp.float32),
            n_rows=self.src.n_rows,
        )

    def gains(self, state: FLState) -> jax.Array:
        return self.src.fl_gains(state.curmax)

    def gains_at(self, state: FLState, idxs: jax.Array) -> jax.Array:
        return self.src.fl_gains_at(state.curmax, idxs)

    def gain_backend(self) -> FLMFPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        if not kernel_enabled(self.use_kernel, self.n, matrix_free=True):
            return None
        src = self.src
        if isinstance(src, FeatureSource) and src.col_labels is None:
            return FLMFPallasSweep()
        if isinstance(src, DenseSource):
            return FLMFPallasSweep()
        return None  # k-NN / clustered sources stay on the XLA scatter path

    def update(self, state: FLState, j: jax.Array) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.src.col(j)),
            n_rows=state.n_rows,
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        return jnp.sum(self.src.masked_rowmax(mask))

    def evaluate_state(self, state: FLState) -> jax.Array:
        return jnp.sum(state.curmax)
