"""Facility Location:  f(A) = sum_{i in U} max_{j in A} S_ij   (paper §2.1.1).

U is the *represented* set (rows of S) which may differ from the ground set V
(columns of S).  Memoized statistic (paper Table 3): ``curmax_i = max_{j in A}
S_ij`` for every i in U; with it a gain query is one fused relu-reduction,
which we evaluate for ALL candidates at once (TPU adaptation, see DESIGN §2).

The per-step full-candidate gain sweep is the compute hotspot and is backed by
the Pallas kernel in ``repro.kernels.fl_gains`` when the matrix is large.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass(meta_fields=("n_rows",))
class FLState:
    curmax: jax.Array  # (n_rows,) max similarity of each represented point to A
    n_rows: int


class FLPallasSweep:
    """GainBackend: fused relu-reduce sweep over the similarity matrix (full
    and gathered-subset entry points; see kernels/fl_gains.py)."""

    name = "pallas-fl"

    def full_sweep(self, fn: "FacilityLocation", state: FLState) -> jax.Array:
        from repro.kernels import ops

        return ops.fl_gains(fn.sim, state.curmax)

    def partial_sweep(
        self, fn: "FacilityLocation", state: FLState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        return ops.fl_gains_at(fn.sim, state.curmax, idx)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class FacilityLocation(SetFunction):
    sim: jax.Array  # (|U|, n) similarity, rows = represented set, cols = ground set
    n: int
    # True/False routes the gain sweeps through the Pallas kernel / XLA;
    # None defers to the trace-time choose_backend heuristic (backends.py)
    use_kernel: bool | None = False

    @staticmethod
    def from_kernel(
        sim: jax.Array, use_kernel: bool | None = False
    ) -> "FacilityLocation":
        sim = jnp.asarray(sim)
        return FacilityLocation(sim=sim, n=int(sim.shape[1]), use_kernel=use_kernel)

    def init_state(self) -> FLState:
        # f({}) = 0 with the standard convention max over empty set = 0
        # (requires S >= 0 for monotonicity; similarity.py guarantees this).
        return FLState(
            curmax=jnp.zeros((self.sim.shape[0],), self.sim.dtype),
            n_rows=int(self.sim.shape[0]),
        )

    def gains(self, state: FLState) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops

            return ops.fl_gains(self.sim, state.curmax)
        return jnp.maximum(self.sim - state.curmax[:, None], 0.0).sum(axis=0)

    def gain_backend(self) -> FLPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return FLPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def gains_at(self, state: FLState, idxs: jax.Array) -> jax.Array:
        cols = self.sim[:, idxs]  # (|U|, k)
        return jnp.maximum(cols - state.curmax[:, None], 0.0).sum(axis=0)

    def update(self, state: FLState, j: jax.Array) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.sim[:, j]), n_rows=state.n_rows
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        masked = jnp.where(mask[None, :], self.sim, 0.0)
        best = jnp.max(masked, axis=1, initial=0.0)
        return jnp.sum(best)

    def evaluate_state(self, state: FLState) -> jax.Array:
        return jnp.sum(state.curmax)
