"""Graph Cut:  f(A) = sum_{i in U, j in A} S_ij - lam * sum_{i,j in A} S_ij
(paper §2.1.2; monotone submodular for lam <= 0.5, non-monotone above).

Memoized statistic (Table 3): ``selsum_j = sum_{k in A} S_jk`` over the
ground-set kernel, plus the static modular vector ``total_j = sum_{i in U}
S_ij``.  The diversity term of the gain is then

  f(j|A) = total_j - lam * (2 * selsum_j + S_jj)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass
class GCState:
    selsum: jax.Array  # (n,)  sum_{k in A} S_jk for every ground element j
    value: jax.Array  # running f(A), maintained by telescoping gains
    selmask: jax.Array  # (n,) 0/1 selection indicator (feeds the fused sweep)


class GCPallasSweep:
    """GainBackend: one fused pass over S recomputing the sweep from the
    selection mask (masked matvec + diag + combine in a single tile stream).

    NOTE: this is the stateless O(n^2)-streamed sweep; the default memoized
    ``gains()`` is O(n) per step and remains the faster choice inside long
    greedy loops.  ``use_kernel=True`` targets one-shot / serving sweeps
    where no memoized state is resident (see kernels/gc_gains.py)."""

    name = "pallas-gc"

    def full_sweep(self, fn: "GraphCut", state: GCState) -> jax.Array:
        from repro.kernels import ops

        return ops.gc_gains(fn.sim_ground, state.selmask, fn.total, fn.lam)

    def partial_sweep(
        self, fn: "GraphCut", state: GCState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        return ops.gc_gains_at(fn.sim_ground, state.selmask, fn.total, fn.lam, idx)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class GraphCut(SetFunction):
    sim_ground: jax.Array  # (n, n) kernel among ground-set elements
    total: jax.Array  # (n,) sum_{i in U} S_ij  (modular representation term)
    lam: jax.Array  # scalar trade-off
    n: int
    # True/False routes sweeps through the Pallas kernel / XLA; None defers
    # to the trace-time choose_backend heuristic (backends.py)
    use_kernel: bool | None = False

    @staticmethod
    def from_kernel(
        sim_ground: jax.Array,
        lam: float = 0.5,
        sim_rep: jax.Array | None = None,
        use_kernel: bool | None = False,
    ) -> "GraphCut":
        """``sim_rep`` is the (|U|, n) represented-set kernel; defaults to the
        ground kernel itself (U == V), matching the paper's default."""
        sim_ground = jnp.asarray(sim_ground)
        total = jnp.sum(sim_rep if sim_rep is not None else sim_ground, axis=0)
        return GraphCut(
            sim_ground=sim_ground,
            total=total,
            lam=jnp.asarray(lam, sim_ground.dtype),
            n=int(sim_ground.shape[0]),
            use_kernel=use_kernel,
        )

    def init_state(self) -> GCState:
        dt = self.sim_ground.dtype
        return GCState(
            selsum=jnp.zeros((self.n,), dt),
            value=jnp.zeros((), dt),
            selmask=jnp.zeros((self.n,), jnp.float32),
        )

    def gains(self, state: GCState) -> jax.Array:
        diag = jnp.diagonal(self.sim_ground)
        return self.total - self.lam * (2.0 * state.selsum + diag)

    def gains_at(self, state: GCState, idxs: jax.Array) -> jax.Array:
        diag = self.sim_ground[idxs, idxs]
        return self.total[idxs] - self.lam * (2.0 * state.selsum[idxs] + diag)

    def update(self, state: GCState, j: jax.Array) -> GCState:
        gain_j = self.gains_at(state, jnp.asarray(j)[None])[0]
        return GCState(
            selsum=state.selsum + self.sim_ground[:, j],
            value=state.value + gain_j,
            selmask=state.selmask.at[j].set(1.0),
        )

    def gain_backend(self) -> GCPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return GCPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def evaluate(self, mask: jax.Array) -> jax.Array:
        m = mask.astype(self.sim_ground.dtype)
        rep = jnp.dot(self.total, m)
        div = m @ self.sim_ground @ m
        return rep - self.lam * div

    def evaluate_state(self, state: GCState) -> jax.Array:
        return state.value
