"""Graph Cut:  f(A) = sum_{i in U, j in A} S_ij - lam * sum_{i,j in A} S_ij
(paper §2.1.2; monotone submodular for lam <= 0.5, non-monotone above).

Memoized statistic (Table 3): ``selsum_j = sum_{k in A} S_jk`` over the
ground-set kernel, plus the static modular vector ``total_j = sum_{i in U}
S_ij``.  The diversity term of the gain is then

  f(j|A) = total_j - lam * (2 * selsum_j + S_jj)

:class:`GraphCutMF` is the matrix-free variant: the ground kernel lives
behind a :class:`~repro.core.sources.SimilaritySource` and the memoized
statistics (``total``, ``diag``, incremental ``selsum``) are built by
streaming it — the (n, n) matrix is never written.  The stateless fused
sweep for feature sources is ``repro.kernels.gcmf_gains``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction
from repro.core.sources import (
    DenseSource,
    FeatureSource,
    dense_source,
    feature_source,
    knn_source,
)


@pytree_dataclass
class GCState:
    selsum: jax.Array  # (n,)  sum_{k in A} S_jk for every ground element j
    value: jax.Array  # running f(A), maintained by telescoping gains
    selmask: jax.Array  # (n,) 0/1 selection indicator (feeds the fused sweep)


class GCPallasSweep:
    """GainBackend: one fused pass over S recomputing the sweep from the
    selection mask (masked matvec + diag + combine in a single tile stream).

    NOTE: this is the stateless O(n^2)-streamed sweep; the default memoized
    ``gains()`` is O(n) per step and remains the faster choice inside long
    greedy loops.  ``use_kernel=True`` targets one-shot / serving sweeps
    where no memoized state is resident (see kernels/gc_gains.py)."""

    name = "pallas-gc"

    def full_sweep(self, fn: "GraphCut", state: GCState) -> jax.Array:
        from repro.kernels import ops

        return ops.gc_gains(fn.sim_ground, state.selmask, fn.total, fn.lam)

    def partial_sweep(
        self, fn: "GraphCut", state: GCState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        return ops.gc_gains_at(fn.sim_ground, state.selmask, fn.total, fn.lam, idx)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class GraphCut(SetFunction):
    sim_ground: jax.Array  # (n, n) kernel among ground-set elements
    total: jax.Array  # (n,) sum_{i in U} S_ij  (modular representation term)
    lam: jax.Array  # scalar trade-off
    n: int
    # True/False routes sweeps through the Pallas kernel / XLA; None defers
    # to the trace-time choose_backend heuristic (backends.py)
    use_kernel: bool | None = False

    @staticmethod
    def from_kernel(
        sim_ground: jax.Array,
        lam: float = 0.5,
        sim_rep: jax.Array | None = None,
        use_kernel: bool | None = False,
    ) -> "GraphCut":
        """``sim_rep`` is the (|U|, n) represented-set kernel; defaults to the
        ground kernel itself (U == V), matching the paper's default."""
        sim_ground = jnp.asarray(sim_ground)
        total = jnp.sum(sim_rep if sim_rep is not None else sim_ground, axis=0)
        return GraphCut(
            sim_ground=sim_ground,
            total=total,
            lam=jnp.asarray(lam, sim_ground.dtype),
            n=int(sim_ground.shape[0]),
            use_kernel=use_kernel,
        )

    def init_state(self) -> GCState:
        dt = self.sim_ground.dtype
        return GCState(
            selsum=jnp.zeros((self.n,), dt),
            value=jnp.zeros((), dt),
            selmask=jnp.zeros((self.n,), jnp.float32),
        )

    def gains(self, state: GCState) -> jax.Array:
        diag = jnp.diagonal(self.sim_ground)
        return self.total - self.lam * (2.0 * state.selsum + diag)

    def gains_at(self, state: GCState, idxs: jax.Array) -> jax.Array:
        diag = self.sim_ground[idxs, idxs]
        return self.total[idxs] - self.lam * (2.0 * state.selsum[idxs] + diag)

    def update(self, state: GCState, j: jax.Array) -> GCState:
        gain_j = self.gains_at(state, jnp.asarray(j)[None])[0]
        return GCState(
            selsum=state.selsum + self.sim_ground[:, j],
            value=state.value + gain_j,
            selmask=state.selmask.at[j].set(1.0),
        )

    def gain_backend(self) -> GCPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return GCPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def evaluate(self, mask: jax.Array) -> jax.Array:
        m = mask.astype(self.sim_ground.dtype)
        rep = jnp.dot(self.total, m)
        div = m @ self.sim_ground @ m
        return rep - self.lam * div

    def evaluate_state(self, state: GCState) -> jax.Array:
        return state.value


class GCMFPallasSweep:
    """GainBackend: matrix-free stateless GC sweep — similarity computed
    in-stream from feature tiles (kernels/gcmf_gains.py); dense sources
    reuse the materialized-matrix kernel.  Same trade-off as
    :class:`GCPallasSweep`: stateless O(n^2-streamed) answers for one-shot
    / serving sweeps vs the O(n) memoized path inside greedy loops."""

    name = "pallas-gcmf"

    def full_sweep(self, fn: "GraphCutMF", state: GCState) -> jax.Array:
        from repro.kernels import ops

        src = fn.src
        if isinstance(src, DenseSource):
            return ops.gc_gains(src.sim, state.selmask, fn.total, fn.lam)
        return ops.gcmf_gains(
            src.y, src.yy, state.selmask, fn.total, fn.diag, fn.lam,
            metric=src.metric, rbf_sigma=src.rbf_sigma,
        )

    def partial_sweep(
        self, fn: "GraphCutMF", state: GCState, idx: jax.Array
    ) -> jax.Array:
        from repro.kernels import ops

        src = fn.src
        if isinstance(src, DenseSource):
            return ops.gc_gains_at(src.sim, state.selmask, fn.total, fn.lam, idx)
        return ops.gcmf_gains_at(
            src.y, src.yy, state.selmask, fn.total, fn.diag, fn.lam, idx,
            metric=src.metric, rbf_sigma=src.rbf_sigma,
        )


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class GraphCutMF(SetFunction):
    """Matrix-free Graph Cut: same objective and memoized statistics as
    :class:`GraphCut`, with the ground kernel behind a
    :class:`~repro.core.sources.SimilaritySource`.  ``total`` and ``diag``
    are precomputed at build time by streaming the source (O(n * d) work,
    O(n) memory); per-step updates stream one similarity column."""

    src: object  # square SimilaritySource over the ground set
    total: jax.Array  # (n,) sum_{i in U} S_ij
    diag: jax.Array  # (n,) S_jj
    lam: jax.Array  # scalar trade-off
    n: int
    use_kernel: bool | None = False

    @staticmethod
    def from_features(
        x,
        lam: float = 0.5,
        metric: str = "dot",
        rbf_sigma: float | None = None,
        labels=None,
        use_kernel: bool | None = False,
    ) -> "GraphCutMF":
        src = feature_source(x, metric=metric, rbf_sigma=rbf_sigma, labels=labels)
        return GraphCutMF._from_source(src, lam, use_kernel)

    @staticmethod
    def from_knn(
        indices, weights, lam: float = 0.5, use_kernel: bool | None = False
    ) -> "GraphCutMF":
        src = knn_source(indices, weights)
        return GraphCutMF._from_source(src, lam, use_kernel)

    @staticmethod
    def from_dense(
        sim, lam: float = 0.5, use_kernel: bool | None = False
    ) -> "GraphCutMF":
        src = dense_source(sim)
        return GraphCutMF._from_source(src, lam, use_kernel)

    @staticmethod
    def _from_source(src, lam, use_kernel) -> "GraphCutMF":
        if src.n_rows != src.n_cols:
            raise ValueError(
                f"GraphCutMF needs a square ground-set source; got "
                f"({src.n_rows}, {src.n_cols})"
            )
        return GraphCutMF(
            src=src,
            total=src.col_sums(),
            diag=src.diag(),
            lam=jnp.asarray(lam, jnp.float32),
            n=src.n_cols,
            use_kernel=use_kernel,
        )

    def init_state(self) -> GCState:
        return GCState(
            selsum=jnp.zeros((self.n,), jnp.float32),
            value=jnp.zeros((), jnp.float32),
            selmask=jnp.zeros((self.n,), jnp.float32),
        )

    def gains(self, state: GCState) -> jax.Array:
        return self.total - self.lam * (2.0 * state.selsum + self.diag)

    def gains_at(self, state: GCState, idxs: jax.Array) -> jax.Array:
        return self.total[idxs] - self.lam * (
            2.0 * state.selsum[idxs] + self.diag[idxs]
        )

    def update(self, state: GCState, j: jax.Array) -> GCState:
        gain_j = self.gains_at(state, jnp.asarray(j)[None])[0]
        return GCState(
            selsum=state.selsum + self.src.col(j),
            value=state.value + gain_j,
            selmask=state.selmask.at[j].set(1.0),
        )

    def gain_backend(self) -> GCMFPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        if not kernel_enabled(self.use_kernel, self.n, matrix_free=True):
            return None
        src = self.src
        if isinstance(src, FeatureSource) and src.col_labels is None:
            return GCMFPallasSweep()
        if isinstance(src, DenseSource):
            return GCMFPallasSweep()
        return None  # k-NN / clustered sources stay on the XLA path

    def evaluate(self, mask: jax.Array) -> jax.Array:
        m = mask.astype(jnp.float32)
        return jnp.dot(self.total, m) - self.lam * self.src.quad(mask)

    def evaluate_state(self, state: GCState) -> jax.Array:
        return state.value
