"""Log Determinant (DPP MAP):  f(A) = log det(L_A)   (paper §2.2.2).

Implementation follows the paper's note (§5.2.1): Fast Greedy MAP Inference
[Chen et al., NeurIPS'18] via incremental Cholesky factors — but *vectorized
over every candidate simultaneously* (TPU adaptation).  For each ground
element i we maintain

  c_i  in R^{b}    : row of the Cholesky factor of L_{A + i} restricted to A
  d2_i in R        : squared Cholesky pivot = det(L_{A+i}) / det(L_A)

so the marginal gain is  f(i|A) = log d2_i,  and adding j* updates every
candidate with one rank-1 step:

  e_i  = (L_{i,j*} - <c_i, c_{j*}>) / d_{j*}
  c_i <- [c_i, e_i],     d2_i <- d2_i - e_i^2

The candidate buffer C is pre-allocated at ``max_select`` (static), keeping
the whole greedy loop jit-compatible.

Serving hooks: LogDet registers a zero row+column padder
(``launch/coalesce.py`` — a padded candidate has pivot d2 = 0 and therefore
gain NEG_INF) and a candidate-row ShardRule (``optimizers/distributed.py`` —
C rows and d2 shard with the candidates; the winner's Cholesky row and pivot
are psum-broadcast), so LogDet and the logdet_cg / Schur-complement measures
built on it (``core/info/logdet.py``) serve through ``SelectionServer`` on
and off mesh.  The rank-1 update below uses the elementwise-multiply +
reduce form ``(C * c_j).sum(axis=1)`` instead of ``C @ c_j``: a batched
matvec lowers through a different GEMM tiling under vmap, which would shift
e_i by ulps and break the served == sequential bit-identical contract (the
same trick as ``FeatureBased.gains``).  There is no fused Pallas sweep yet
— gains are an O(n) read of d2; the expensive part is this rank-1 update
(see ROADMAP).  docs/functions.md has the coverage matrix and a runnable
snippet.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import NEG_INF, pytree_dataclass
from repro.core.functions.base import SetFunction

_EPS = 1e-12


@pytree_dataclass
class LogDetState:
    C: jax.Array  # (n, max_select) candidate Cholesky rows (zero-padded)
    d2: jax.Array  # (n,) pivot^2 for every candidate
    count: jax.Array  # int32 number of selected items
    value: jax.Array  # running log det


@pytree_dataclass(meta_fields=("n", "max_select"))
class LogDet(SetFunction):
    L: jax.Array  # (n, n) PSD similarity kernel
    n: int
    max_select: int

    @staticmethod
    def from_kernel(L: jax.Array, max_select: int | None = None) -> "LogDet":
        L = jnp.asarray(L)
        n = int(L.shape[0])
        return LogDet(L=L, n=n, max_select=int(max_select or n))

    def init_state(self) -> LogDetState:
        return LogDetState(
            C=jnp.zeros((self.n, self.max_select), self.L.dtype),
            d2=jnp.diagonal(self.L),
            count=jnp.zeros((), jnp.int32),
            value=jnp.zeros((), self.L.dtype),
        )

    def gains(self, state: LogDetState) -> jax.Array:
        return jnp.where(state.d2 > _EPS, jnp.log(jnp.maximum(state.d2, _EPS)), NEG_INF)

    def gains_at(self, state: LogDetState, idxs: jax.Array) -> jax.Array:
        d2 = state.d2[idxs]
        return jnp.where(d2 > _EPS, jnp.log(jnp.maximum(d2, _EPS)), NEG_INF)

    def update(self, state: LogDetState, j: jax.Array) -> LogDetState:
        cj = state.C[j]  # (max_select,)
        dj = jnp.sqrt(jnp.maximum(state.d2[j], _EPS))
        # e_i for every candidate i at once; reduce form, not `C @ cj`
        # (vmap-bit-stable — see module docstring)
        e = (self.L[:, j] - (state.C * cj[None, :]).sum(axis=1)) / dj  # (n,)
        C = state.C.at[:, state.count].set(e, mode="drop")
        d2 = state.d2 - e * e
        return LogDetState(
            C=C,
            d2=d2,
            count=state.count + 1,
            value=state.value + jnp.log(jnp.maximum(state.d2[j], _EPS)),
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        # log det of the masked submatrix: pad unselected rows/cols with the
        # identity so the determinant is unchanged.
        m = mask.astype(self.L.dtype)
        Lm = self.L * m[:, None] * m[None, :] + jnp.diag(1.0 - m)
        sign, logdet = jnp.linalg.slogdet(Lm)
        return jnp.where(jnp.sum(m) > 0, logdet, 0.0)

    def evaluate_state(self, state: LogDetState) -> jax.Array:
        return state.value
