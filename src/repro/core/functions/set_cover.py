"""Set Cover and Probabilistic Set Cover (paper §2.3.1-2.3.2).

SC:   f(A) = sum_u w_u * min(c_u(A), 1)     with cover matrix G (n, m) in {0,1}
PSC:  f(A) = sum_u w_u * (1 - prod_{j in A} (1 - p_ju))

Memoized statistics (Table 3): the covered-concept indicator for SC and the
per-concept miss probability  Pbar_u = prod_{j in A}(1 - p_ju)  for PSC.

Both full sweeps are pluggable through the :class:`GainBackend` layer
(``core/optimizers/backends.py``): building with ``use_kernel=True`` routes
``full_sweep`` through the fused Pallas kernels in ``kernels/sc_gains.py``
(masked max / probability-product over the concept-incidence matrix, one
streamed pass per sweep); the default is the XLA ``gains()`` below.  Both
families also register serving adapters — a zero-row padder
(``launch/coalesce.py``) and a concept-replicated ShardRule
(``optimizers/distributed.py``) — so SC/PSC requests coalesce into padded
waves and shard over a mesh bit-identically.  See docs/functions.md for the
per-family coverage matrix and runnable snippets.

The gains use the elementwise-multiply + reduce form rather than ``@ w``:
a batched matvec lowers through a different GEMM tiling than the single
instance, shifting gains by ulps under vmap; the reduce form is bit-stable,
which is what lets served/batched selections equal single ``maximize`` calls
exactly (the same trick as ``FeatureBased.gains``).

The MI / CG / CMI instantiations of both (paper §5.2.2-5.2.4) are *weight /
cover-set modifications* of the base function, so they are expressed in
``core/info/sc.py`` via ``reweight`` constructors — exactly the
implementation trick the paper uses.  Because those measures ARE SetCover /
ProbabilisticSetCover instances, they inherit the kernel, padder, and
ShardRule coverage for free (registries resolve along the MRO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass
class SCState:
    covered: jax.Array  # (m,) float indicator in [0, 1] of covered concepts


class SCPallasSweep:
    """GainBackend: fused mask -> weight -> reduce over the incidence matrix
    (no (n, m) relu intermediate in HBM); see kernels/sc_gains.py."""

    name = "pallas-sc"

    def full_sweep(self, fn: "SetCover", state: SCState) -> jax.Array:
        from repro.kernels import ops

        return ops.sc_gains(fn.cover, state.covered, fn.w)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class SetCover(SetFunction):
    cover: jax.Array  # (n, m) binary: element i covers concept u
    w: jax.Array  # (m,) concept weights
    n: int
    use_kernel: bool = False  # route full sweeps through the Pallas kernel

    @staticmethod
    def from_cover(
        cover: jax.Array, w: jax.Array | None = None, use_kernel: bool = False
    ) -> "SetCover":
        cover = jnp.asarray(cover, jnp.float32)
        m = cover.shape[1]
        w = jnp.ones((m,), jnp.float32) if w is None else jnp.asarray(w, jnp.float32)
        return SetCover(
            cover=cover, w=w, n=int(cover.shape[0]), use_kernel=use_kernel
        )

    def init_state(self) -> SCState:
        return SCState(covered=jnp.zeros((self.cover.shape[1],), self.cover.dtype))

    def gains(self, state: SCState) -> jax.Array:
        new = jnp.maximum(self.cover - state.covered[None, :], 0.0)  # (n, m)
        return (new * self.w[None, :]).sum(axis=-1)

    def gains_at(self, state: SCState, idxs: jax.Array) -> jax.Array:
        new = jnp.maximum(self.cover[idxs] - state.covered[None, :], 0.0)
        return (new * self.w[None, :]).sum(axis=-1)

    def gain_backend(self) -> SCPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return SCPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def update(self, state: SCState, j: jax.Array) -> SCState:
        return SCState(covered=jnp.maximum(state.covered, self.cover[j]))

    def evaluate(self, mask: jax.Array) -> jax.Array:
        cov = jnp.max(
            jnp.where(mask[:, None], self.cover, 0.0), axis=0, initial=0.0
        )
        return jnp.dot(cov, self.w)

    def evaluate_state(self, state: SCState) -> jax.Array:
        return jnp.dot(state.covered, self.w)


@pytree_dataclass
class PSCState:
    miss: jax.Array  # (m,) Pbar_u(A) = prod_{j in A} (1 - p_ju)


class PSCPallasSweep:
    """GainBackend: fused probability-product sweep, weighting each concept by
    the memoized miss probability; see kernels/sc_gains.py."""

    name = "pallas-psc"

    def full_sweep(self, fn: "ProbabilisticSetCover", state: PSCState) -> jax.Array:
        from repro.kernels import ops

        return ops.psc_gains(fn.probs, state.miss, fn.w)


@pytree_dataclass(meta_fields=("n", "use_kernel"))
class ProbabilisticSetCover(SetFunction):
    log_miss: jax.Array  # (n, m) log(1 - p_ju), precomputed for stable products
    w: jax.Array  # (m,)
    n: int
    use_kernel: bool = False  # route full sweeps through the Pallas kernel

    @staticmethod
    def from_probs(
        probs: jax.Array, w: jax.Array | None = None, use_kernel: bool = False
    ) -> "ProbabilisticSetCover":
        probs = jnp.clip(jnp.asarray(probs, jnp.float32), 0.0, 1.0 - 1e-7)
        m = probs.shape[1]
        w = jnp.ones((m,), jnp.float32) if w is None else jnp.asarray(w, jnp.float32)
        return ProbabilisticSetCover(
            log_miss=jnp.log1p(-probs),
            w=w,
            n=int(probs.shape[0]),
            use_kernel=use_kernel,
        )

    @property
    def probs(self) -> jax.Array:
        return 1.0 - jnp.exp(self.log_miss)

    def init_state(self) -> PSCState:
        return PSCState(miss=jnp.ones((self.log_miss.shape[1],), jnp.float32))

    def gains(self, state: PSCState) -> jax.Array:
        # f(j|A) = sum_u w_u * Pbar_u(A) * p_ju
        return (self.probs * (self.w * state.miss)[None, :]).sum(axis=-1)

    def gains_at(self, state: PSCState, idxs: jax.Array) -> jax.Array:
        return (self.probs[idxs] * (self.w * state.miss)[None, :]).sum(axis=-1)

    def gain_backend(self) -> PSCPallasSweep | None:
        from repro.core.optimizers.backends import kernel_enabled

        return PSCPallasSweep() if kernel_enabled(self.use_kernel, self.n) else None

    def update(self, state: PSCState, j: jax.Array) -> PSCState:
        return PSCState(miss=state.miss * jnp.exp(self.log_miss[j]))

    def evaluate(self, mask: jax.Array) -> jax.Array:
        logm = jnp.where(mask[:, None], self.log_miss, 0.0).sum(axis=0)
        return jnp.dot(self.w, 1.0 - jnp.exp(logm))

    def evaluate_state(self, state: PSCState) -> jax.Array:
        return jnp.dot(self.w, 1.0 - state.miss)
