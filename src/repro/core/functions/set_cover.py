"""Set Cover and Probabilistic Set Cover (paper §2.3.1-2.3.2).

SC:   f(A) = sum_u w_u * min(c_u(A), 1)     with cover matrix G (n, m) in {0,1}
PSC:  f(A) = sum_u w_u * (1 - prod_{j in A} (1 - p_ju))

Memoized statistics (Table 3): the covered-concept indicator for SC and the
per-concept miss probability  Pbar_u = prod_{j in A}(1 - p_ju)  for PSC.

The MI / CG / CMI instantiations of both (paper §5.2.2-5.2.4) are *weight /
cover-set modifications* of the base function, so they are expressed here via
``reweight`` constructors — exactly the implementation trick the paper uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass
class SCState:
    covered: jax.Array  # (m,) float indicator in [0, 1] of covered concepts


@pytree_dataclass(meta_fields=("n",))
class SetCover(SetFunction):
    cover: jax.Array  # (n, m) binary: element i covers concept u
    w: jax.Array  # (m,) concept weights
    n: int

    @staticmethod
    def from_cover(cover: jax.Array, w: jax.Array | None = None) -> "SetCover":
        cover = jnp.asarray(cover, jnp.float32)
        m = cover.shape[1]
        w = jnp.ones((m,), jnp.float32) if w is None else jnp.asarray(w, jnp.float32)
        return SetCover(cover=cover, w=w, n=int(cover.shape[0]))

    def init_state(self) -> SCState:
        return SCState(covered=jnp.zeros((self.cover.shape[1],), self.cover.dtype))

    def gains(self, state: SCState) -> jax.Array:
        new = jnp.maximum(self.cover - state.covered[None, :], 0.0)  # (n, m)
        return new @ self.w

    def gains_at(self, state: SCState, idxs: jax.Array) -> jax.Array:
        new = jnp.maximum(self.cover[idxs] - state.covered[None, :], 0.0)
        return new @ self.w

    def update(self, state: SCState, j: jax.Array) -> SCState:
        return SCState(covered=jnp.maximum(state.covered, self.cover[j]))

    def evaluate(self, mask: jax.Array) -> jax.Array:
        cov = jnp.max(
            jnp.where(mask[:, None], self.cover, 0.0), axis=0, initial=0.0
        )
        return jnp.dot(cov, self.w)

    def evaluate_state(self, state: SCState) -> jax.Array:
        return jnp.dot(state.covered, self.w)


@pytree_dataclass
class PSCState:
    miss: jax.Array  # (m,) Pbar_u(A) = prod_{j in A} (1 - p_ju)


@pytree_dataclass(meta_fields=("n",))
class ProbabilisticSetCover(SetFunction):
    log_miss: jax.Array  # (n, m) log(1 - p_ju), precomputed for stable products
    w: jax.Array  # (m,)
    n: int

    @staticmethod
    def from_probs(
        probs: jax.Array, w: jax.Array | None = None
    ) -> "ProbabilisticSetCover":
        probs = jnp.clip(jnp.asarray(probs, jnp.float32), 0.0, 1.0 - 1e-7)
        m = probs.shape[1]
        w = jnp.ones((m,), jnp.float32) if w is None else jnp.asarray(w, jnp.float32)
        return ProbabilisticSetCover(
            log_miss=jnp.log1p(-probs), w=w, n=int(probs.shape[0])
        )

    @property
    def probs(self) -> jax.Array:
        return 1.0 - jnp.exp(self.log_miss)

    def init_state(self) -> PSCState:
        return PSCState(miss=jnp.ones((self.log_miss.shape[1],), jnp.float32))

    def gains(self, state: PSCState) -> jax.Array:
        # f(j|A) = sum_u w_u * Pbar_u(A) * p_ju
        return self.probs @ (self.w * state.miss)

    def gains_at(self, state: PSCState, idxs: jax.Array) -> jax.Array:
        return self.probs[idxs] @ (self.w * state.miss)

    def update(self, state: PSCState, j: jax.Array) -> PSCState:
        return PSCState(miss=state.miss * jnp.exp(self.log_miss[j]))

    def evaluate(self, mask: jax.Array) -> jax.Array:
        logm = jnp.where(mask[:, None], self.log_miss, 0.0).sum(axis=0)
        return jnp.dot(self.w, 1.0 - jnp.exp(logm))

    def evaluate_state(self, state: PSCState) -> jax.Array:
        return jnp.dot(self.w, 1.0 - state.miss)
