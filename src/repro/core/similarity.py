"""Similarity / distance kernel creation (paper §8 "usage patterns").

Modes
-----
dense      : full (n_rows, n_cols) kernel — the O(n^2 d) hotspot (paper
             Table 5); routed through the Pallas MXU kernel when requested.
sparse     : fixed top-k neighbour layout — similarity beyond the k nearest
             neighbours is zeroed (paper's sparse mode, TPU-friendly dense
             top-k rather than CSR; DESIGN §8.2).
clustered  : see functions/clustered.py.

Metrics: ``dot``, ``cosine`` (shifted to [0,1]), ``euclidean`` (similarity
1/(1+d)), ``rbf``.  All produced similarities are non-negative, which the
monotone functions (FL) require.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_METRICS = ("dot", "cosine", "euclidean", "rbf")


def pairwise_sq_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    xx = jnp.sum(x * x, axis=1)[:, None]
    yy = jnp.sum(y * y, axis=1)[None, :]
    d2 = xx + yy - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def create_kernel(
    x: jax.Array,
    y: jax.Array | None = None,
    metric: str = "cosine",
    mode: str = "dense",
    num_neighbors: int | None = None,
    rbf_sigma: float | None = None,
    use_pallas: bool = False,
) -> jax.Array:
    """Similarity kernel S of shape (n_x, n_y); ``y`` defaults to ``x``.

    Rows are the *represented* set, columns the ground set, matching the
    paper's U-vs-V distinction.
    """
    if metric not in _METRICS:
        raise ValueError(f"unknown metric {metric!r}; choose from {_METRICS}")
    x = jnp.asarray(x)
    y = x if y is None else jnp.asarray(y)

    if use_pallas:
        from repro.kernels import ops

        sim = ops.similarity(x, y, metric=metric, rbf_sigma=rbf_sigma)
    else:
        sim = _reference_kernel(x, y, metric, rbf_sigma)

    if mode == "dense":
        return sim
    if mode == "sparse":
        if num_neighbors is None:
            raise ValueError("sparse mode requires num_neighbors")
        return sparsify_topk(sim, num_neighbors)
    raise ValueError(f"unknown mode {mode!r} (clustered mode lives in functions/clustered.py)")


def _reference_kernel(x, y, metric, rbf_sigma):
    if metric == "dot":
        return x @ y.T
    if metric == "cosine":
        xn = x / jnp.maximum(jnp.linalg.norm(x, axis=1, keepdims=True), 1e-12)
        yn = y / jnp.maximum(jnp.linalg.norm(y, axis=1, keepdims=True), 1e-12)
        return 0.5 * (1.0 + xn @ yn.T)  # shift to [0, 1]
    d2 = pairwise_sq_dists(x, y)
    if metric == "euclidean":
        return 1.0 / (1.0 + jnp.sqrt(d2))
    sigma = rbf_sigma if rbf_sigma is not None else float(x.shape[1]) ** 0.5
    return jnp.exp(-d2 / (2.0 * sigma * sigma))


def sparsify_topk(sim: jax.Array, k: int) -> jax.Array:
    """Keep the k largest entries per row (incl. self), zero the rest."""
    k = min(k, sim.shape[1])
    thresh = jax.lax.top_k(sim, k)[0][:, -1]
    return jnp.where(sim >= thresh[:, None], sim, 0.0)


def kmeans(
    x: jax.Array, k: int, iters: int = 25, key: jax.Array | None = None
) -> jax.Array:
    """Small k-means (labels only) for the internal-clustering option."""
    key = jax.random.PRNGKey(0) if key is None else key
    init = jax.random.choice(key, x.shape[0], (k,), replace=False)
    cents = x[init]

    def step(cents, _):
        d2 = pairwise_sq_dists(x, cents)
        lab = jnp.argmin(d2, axis=1)
        one = jax.nn.one_hot(lab, k, dtype=x.dtype)
        counts = jnp.maximum(one.sum(0)[:, None], 1.0)
        cents = (one.T @ x) / counts
        return cents, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return jnp.argmin(pairwise_sq_dists(x, cents), axis=1)


def build_extended_kernel(
    ground: jax.Array,
    query: jax.Array | None = None,
    private: jax.Array | None = None,
    metric: str = "cosine",
    eta: float = 1.0,
    nu: float = 1.0,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel over V ∪ Q ∪ P with η/ν cross-block scaling (paper §3.4).

    Returns (kernel, q_idx, p_idx); V occupies indices [0, n_v).
    Cross-similarity V<->Q is scaled by η and V<->P by ν, exactly the
    S^{η,ν} construction used by the LogDet information measures.
    """
    parts = [jnp.asarray(ground)]
    n_v = parts[0].shape[0]
    q_idx = jnp.arange(0)
    p_idx = jnp.arange(0)
    if query is not None:
        query = jnp.asarray(query)
        q_idx = jnp.arange(n_v, n_v + query.shape[0])
        parts.append(query)
    if private is not None:
        private = jnp.asarray(private)
        start = n_v + (query.shape[0] if query is not None else 0)
        p_idx = jnp.arange(start, start + private.shape[0])
        parts.append(private)
    allpts = jnp.concatenate(parts, axis=0)
    S = create_kernel(allpts, metric=metric)
    scale = jnp.ones((allpts.shape[0],))
    if query is not None:
        scale = scale.at[q_idx].set(jnp.sqrt(eta) if eta >= 0 else 1.0)
    if private is not None:
        scale = scale.at[p_idx].set(jnp.sqrt(nu) if nu >= 0 else 1.0)
    # symmetric scaling keeps PSD-ness for LogDet: S' = D S D with D diagonal
    S = S * scale[:, None] * scale[None, :]
    # restore untouched diagonal blocks (V-V, Q-Q, P-P keep base similarity)
    grp = jnp.zeros((allpts.shape[0],), jnp.int32)
    grp = grp.at[q_idx].set(1).at[p_idx].set(2)
    same = grp[:, None] == grp[None, :]
    S_base = create_kernel(allpts, metric=metric)
    return jnp.where(same, S_base, S), q_idx, p_idx
