"""Similarity sources: where a kernel-based function's sim(i, j) comes from.

The dense families (``FacilityLocation.from_kernel`` & co) take a
materialized (|U|, n) similarity matrix, which caps n at ~10^4 — the n^2
bytes are the ROADMAP's #1 scale blocker.  A :class:`SimilaritySource` is
the matrix-free replacement: an object that can answer the *same* queries
the memoized statistics need — a single column, a full fused gain sweep, a
gathered-subset sweep — without ever writing the n x n matrix.

Three sources ride one contract:

- :class:`FeatureSource` — raw feature rows plus a metric
  (dot / cosine / euclidean / RBF, matching ``kernels/similarity_kernel.py``).
  Sweeps stream fixed-width column tiles of sim through a ``lax.scan``:
  peak memory is O(n_rows * TILE) per step, O(n * d) overall.  Optional
  integer ``labels`` block-mask the similarity (``sim_ij = 0`` unless
  ``label_i == label_j``) which is exactly the paper's §8 clustered
  decomposition, streamed.
- :class:`KnnSource` — precomputed sparse k-NN similarity in CSR-ish padded
  form: per-row neighbor ``indices`` (int32, -1 = empty slot) and
  nonnegative ``weights``.  Sweeps are O(n * k) scatter-adds.
- :class:`DenseSource` — the materialized matrix itself, so dense requests
  ride the same backend contract (and the existing fused Pallas sweeps).

Sources are frozen pytree dataclasses: they pass through jit / vmap /
``jax.eval_shape`` (the serving coalescer derives group keys shape-only),
and the static meta fields (metric, shapes) key the jit cache.

The queries every source answers (FL = facility location, the relu-reduce
family; the elementwise Graph-Cut statistics ride ``col``/``col_sums``/
``diag``):

  col(j)                 (n_rows,)  similarity of every row to candidate j
  col_sums()             (n_cols,)  per-candidate column sums (GC ``total``)
  diag()                 (n_cols,)  sim(j, j) for square sources (GC diag)
  fl_gains(curmax)       (n_cols,)  sum_i max(sim_ij - curmax_i, 0)
  fl_gains_at(curmax, idx)  (k,)    gathered subset; idx < 0 -> NEG_INF
  masked_rowmax(mask)    (n_rows,)  max_{j: mask_j} sim_ij (empty -> 0)
  quad(mask)             scalar     m^T S m (square sources; GC evaluate)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.common import NEG_INF, pytree_dataclass

# Column-tile width of the streamed feature sweeps.  Static so every
# serving bucket of the same source traces to the same per-column
# computation (zero-padding columns to a bucket and then to a TILE
# multiple is the same array as padding straight to the TILE multiple).
TILE = 512


# -- bit-stable streamed blocks ---------------------------------------------
#
# The serving contract pins every response bit-identical to sequential
# ``solve(spec)``, and the dense families meet it for free: their in-engine
# float work is elementwise (plus gathers of materialized data), and
# elementwise float ops are bit-deterministic no matter how XLA fuses or
# batches them.  A matrix-free sweep is not: its similarity dot and its
# column reduction are order-sensitive, and under ``vmap`` (the batched
# engine, every served wave) their SHAPES change — (B, n, t) instead of
# (n, t) — so XLA may pick a different accumulation order and drift by
# ulps.  Empirically even the batch width alone (a wave of 1 vs a batch of
# 2) flips the last bits of a contraction on CPU.
#
# Two measures make the streamed sweep behave like materialized data:
#
# - ``_fence`` (``lax.optimization_barrier``) around each dot / reduce, so
#   it stays a standalone instruction instead of fusing into whatever
#   engine loop surrounds it;
# - a ``custom_vmap`` rule on the similarity block and the column reduce
#   that lowers batching to ``lax.map`` of the UNBATCHED computation, so a
#   batch member runs the exact instructions the sequential program runs,
#   for any batch width.  (Per-instance streaming is also the memory
#   contract: a vectorized batched sweep would hold B live (n, TILE)
#   blocks.)


def _fence(x: jax.Array) -> jax.Array:
    return jax.lax.optimization_barrier(x)


def _map_unbatched(fn, axis_size, in_batched, args):
    """The shared custom_vmap rule: run ``fn`` per batch member via
    ``lax.map`` so batched execution replays the unbatched instructions."""

    def one(i):
        sliced = [
            jnp.take(a, i, axis=0) if b else a for a, b in zip(args, in_batched)
        ]
        return fn(*sliced)

    return jax.lax.map(one, jnp.arange(axis_size)), True


def _tree_dot(x: jax.Array, yt: jax.Array) -> jax.Array:
    """x (n, d) · yt (t, d)^T -> (n, t) as an explicit balanced add-tree of
    outer products over the (static) feature axis.

    A ``dot_general`` of the same shapes is NOT bit-stable across programs:
    XLA's dot lowering (layout assignment, matvec strength reduction) is
    context-dependent, and the accumulation order over d moves with it.  An
    explicit add DAG of elementwise ops is never reassociated, so every
    program — sequential, vmapped at any width, any serving bucket —
    computes the exact same float sequence per output element."""
    terms = [x[:, k][:, None] * yt[None, :, k] for k in range(x.shape[1])]
    while len(terms) > 1:
        nxt = [a + b for a, b in zip(terms[::2], terms[1::2])]
        if len(terms) % 2:
            nxt.append(terms[-1])
        terms = nxt
    return terms[0]


@functools.lru_cache(maxsize=None)
def _sim_block(metric: str, inv_two_sigma_sq: float, masked: bool):
    """Cached bit-stable similarity block for (metric, sigma, masked)."""

    def base(x, yt, xx, yyt, *labels):
        acc = _tree_dot(x, yt)
        if metric == "dot":
            s = acc
        elif metric == "cosine":
            s = 0.5 * (1.0 + acc)  # rows arrive pre-normalized
        else:
            d2 = jnp.maximum(xx[:, None] + yyt[None, :] - 2.0 * acc, 0.0)
            if metric == "euclidean":
                s = 1.0 / (1.0 + jnp.sqrt(d2))
            else:  # rbf
                s = jnp.exp(-d2 * inv_two_sigma_sq)
        if masked:
            rl, lt = labels
            s = jnp.where(rl[:, None] == lt[None, :], s, 0.0)
        return _fence(s)

    f = jax.custom_batching.custom_vmap(base)

    @f.def_vmap
    def _rule(axis_size, in_batched, *args):
        return _map_unbatched(f, axis_size, in_batched, args)

    return f


@jax.custom_batching.custom_vmap
def _colsum(t: jax.Array) -> jax.Array:
    """Bit-stable column sum: (n_rows, tc) -> (tc,)."""
    return _fence(_fence(t).sum(axis=0))


@_colsum.def_vmap
def _colsum_rule(axis_size, in_batched, t):
    return _map_unbatched(_colsum, axis_size, in_batched, (t,))


def _pad_axis(a: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@pytree_dataclass(meta_fields=("metric", "rbf_sigma", "d", "n_rows", "n_cols"))
class FeatureSource:
    """Features + metric: sim(i, j) = metric(x_i, y_j), computed on demand.

    ``x`` are the represented-set rows, ``y`` the candidate columns (the
    same array for symmetric sources — build with :func:`feature_source`).
    For cosine the rows arrive PRE-normalized (zero-norm rows clamp to the
    zero vector, landing on the 0.5 midpoint after the [0, 1] shift, same
    as ``core/similarity.py``); ``xx``/``yy`` are squared row norms feeding
    the euclidean/RBF epilogues.  ``row_labels``/``col_labels`` (int32,
    >= 0; pad slots are -1) switch on clustered block-masking.
    """

    x: jax.Array  # (n_rows, d) fp32
    y: jax.Array  # (n_cols, d) fp32
    xx: jax.Array  # (n_rows,) squared norms
    yy: jax.Array  # (n_cols,)
    row_labels: jax.Array | None
    col_labels: jax.Array | None
    metric: str
    rbf_sigma: float | None
    d: int
    n_rows: int
    n_cols: int

    # -- similarity blocks ---------------------------------------------------
    def _inv_two_sigma_sq(self) -> float:
        sigma = self.rbf_sigma if self.rbf_sigma is not None else float(self.d) ** 0.5
        return 1.0 / (2.0 * sigma * sigma)

    def _sim_cols(self, yt, yyt, lt) -> jax.Array:
        """Similarity block (n_rows, tc) against the column tile ``yt``."""
        block = _sim_block(self.metric, self._inv_two_sigma_sq(), lt is not None)
        if lt is None:
            return block(self.x, yt, self.xx, yyt)
        return block(self.x, yt, self.xx, yyt, self.row_labels, lt)

    def _col_tiles(self):
        """(y, yy, labels) reshaped to (nt, TILE, ...) for a lax.scan."""
        y = _pad_axis(self.y, TILE, 0)
        yy = _pad_axis(self.yy, TILE, 0)
        nt = y.shape[0] // TILE
        tiles = (y.reshape(nt, TILE, -1), yy.reshape(nt, TILE))
        if self.col_labels is None:
            return tiles + (None,)
        lab = _pad_axis(self.col_labels, TILE, 0, value=-1)
        return tiles + (lab.reshape(nt, TILE),)

    def _scan_cols(self, per_tile, init):
        """Stream column tiles through ``per_tile(carry, sim_block, extras)``.

        ``extras`` is the (yt, yyt, lt, col_mask) tuple of the tile; the
        scan carries ``init`` and stacks per-tile outputs.  Peak live bytes:
        one (n_rows, TILE) block, never (n_rows, n_cols).
        """
        yt_all, yyt_all, lt_all = self._col_tiles()

        def body(carry, args):
            if lt_all is None:
                yt, yyt = args
                lt = None
            else:
                yt, yyt, lt = args
            s = self._sim_cols(yt, yyt, lt)
            return per_tile(carry, s)

        xs = (yt_all, yyt_all) if lt_all is None else (yt_all, yyt_all, lt_all)
        return jax.lax.scan(body, init, xs)

    # -- source contract -----------------------------------------------------
    def col(self, j: jax.Array) -> jax.Array:
        """sim(i, j) for every row i, shape (n_rows,)."""
        safe = jnp.clip(j, 0, self.n_cols - 1)
        lt = None if self.col_labels is None else self.col_labels[safe][None]
        return self._sim_cols(self.y[safe][None], self.yy[safe][None], lt)[:, 0]

    def col_sums(self) -> jax.Array:
        _, out = self._scan_cols(lambda c, s: (c, s.sum(axis=0)), None)
        return out.reshape(-1)[: self.n_cols]

    def diag(self) -> jax.Array:
        """sim(j, j) for square sources, computed metric-exactly (d2 = 0)."""
        if self.metric == "dot":
            return self.yy
        if self.metric == "cosine":
            # yy is the squared norm of the pre-normalized row: 1.0, or 0.0
            # for a zero-norm row (which similarity maps to the 0.5 midpoint)
            return 0.5 * (1.0 + self.yy)
        return jnp.ones((self.n_cols,), jnp.float32)

    def fl_gains(self, curmax: jax.Array) -> jax.Array:
        def per_tile(carry, s):
            return carry, _colsum(jnp.maximum(s - curmax[:, None], 0.0))

        _, out = self._scan_cols(per_tile, None)
        return out.reshape(-1)[: self.n_cols]

    def fl_gains_at(self, curmax: jax.Array, idx: jax.Array) -> jax.Array:
        # the gathered sub-source runs the SAME fixed-TILE scan as the full
        # sweep, so every similarity dot is computed at the same matmul
        # width — subset gains match the full sweep's bit-for-bit (a
        # width-k contraction can differ in the last ulps)
        safe = jnp.clip(idx, 0, self.n_cols - 1)
        sub = dataclasses.replace(
            self,
            y=jnp.take(self.y, safe, axis=0),
            yy=jnp.take(self.yy, safe),
            col_labels=(
                None
                if self.col_labels is None
                else jnp.take(self.col_labels, safe)
            ),
            n_cols=int(idx.shape[0]),
        )
        g = sub.fl_gains(curmax)
        return jnp.where(idx >= 0, g, NEG_INF)

    def masked_rowmax(self, mask: jax.Array) -> jax.Array:
        mask_p = _pad_axis(mask.astype(bool), TILE, 0, value=False)
        nt = mask_p.shape[0] // TILE
        m_tiles = mask_p.reshape(nt, TILE)
        counter = jnp.zeros((), jnp.int32)  # rides the scan index

        def per_tile(carry, s):
            best, t = carry
            sel = jnp.where(m_tiles[t][None, :], s, 0.0)
            return (jnp.maximum(best, jnp.max(sel, axis=1, initial=0.0)), t + 1), None

        (best, _), _ = self._scan_cols(
            per_tile, (jnp.zeros((self.n_rows,), jnp.float32), counter)
        )
        return best

    def quad(self, mask: jax.Array) -> jax.Array:
        """m^T S m for square sources, streamed (GC evaluate oracle)."""
        m = mask.astype(jnp.float32)
        m_rows = m[: self.n_rows]
        mask_p = _pad_axis(m, TILE, 0)
        nt = mask_p.shape[0] // TILE
        m_tiles = mask_p.reshape(nt, TILE)

        def per_tile(carry, s):
            acc, t = carry
            v = (s * m_rows[:, None]).sum(axis=0)  # (tc,)
            return (acc + (v * m_tiles[t]).sum(), t + 1), None

        (acc, _), _ = self._scan_cols(per_tile, (jnp.zeros(()), jnp.zeros((), jnp.int32)))
        return acc


def feature_source(
    x,
    y=None,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    labels=None,
    col_labels=None,
) -> FeatureSource:
    """Build a :class:`FeatureSource` from raw feature rows.

    ``y=None`` builds the symmetric (square) source over ``x`` itself —
    the ground-set kernel shape Graph Cut and self-represented FL want.
    ``labels`` attaches clustered block-masking to the rows (and, for the
    symmetric case, the columns); ``col_labels`` overrides the column side.
    """
    x32 = jnp.asarray(x, jnp.float32)
    if metric == "cosine":
        x32 = x32 / jnp.maximum(jnp.linalg.norm(x32, axis=1, keepdims=True), 1e-12)
    xx = (x32 * x32).sum(axis=1)
    row_labels = None if labels is None else jnp.asarray(labels, jnp.int32)
    if y is None:
        y32, yy = x32, xx
        clab = row_labels if col_labels is None else jnp.asarray(col_labels, jnp.int32)
    else:
        y32 = jnp.asarray(y, jnp.float32)
        if metric == "cosine":
            y32 = y32 / jnp.maximum(
                jnp.linalg.norm(y32, axis=1, keepdims=True), 1e-12
            )
        yy = (y32 * y32).sum(axis=1)
        clab = None if col_labels is None else jnp.asarray(col_labels, jnp.int32)
    if (row_labels is None) != (clab is None):
        raise ValueError("clustered sources need labels on both axes")
    return FeatureSource(
        x=x32,
        y=y32,
        xx=xx,
        yy=yy,
        row_labels=row_labels,
        col_labels=clab,
        metric=metric,
        rbf_sigma=rbf_sigma,
        d=int(x32.shape[1]),
        n_rows=int(x32.shape[0]),
        n_cols=int(y32.shape[0]),
    )


@pytree_dataclass(meta_fields=("n_rows", "n_cols", "k"))
class KnnSource:
    """Sparse k-NN similarity in padded CSR-ish form.

    Row i's neighbors are ``indices[i]`` (int32 column ids, -1 = empty pad
    slot) with similarities ``weights[i]`` (>= 0; pad slots are 0).
    sim(i, j) is ``weights[i, s]`` when ``indices[i, s] == j`` and exactly
    0 otherwise — the sparsified-matrix semantics of
    ``similarity.sparsify_topk``, never materialized.  FL sweeps are
    O(n * k) scatter-adds: off-neighborhood entries contribute
    max(0 - curmax, 0) = 0 exactly (curmax >= 0), so the sparse sweep IS
    the dense sweep over the sparsified matrix.
    """

    indices: jax.Array  # (n_rows, k) int32, -1 pads
    weights: jax.Array  # (n_rows, k) fp32 >= 0
    n_rows: int
    n_cols: int
    k: int

    def _live_w(self) -> jax.Array:
        return jnp.where(self.indices >= 0, self.weights, 0.0)

    def col(self, j: jax.Array) -> jax.Array:
        return jnp.where(self.indices == j, self.weights, 0.0).sum(axis=1)

    def col_sums(self) -> jax.Array:
        return (
            jnp.zeros((self.n_cols,), jnp.float32)
            .at[self.indices]
            .add(self._live_w(), mode="drop")
        )

    def diag(self) -> jax.Array:
        # square sources only (Graph Cut): sim(j, j) is the self-neighbor
        # weight when present, else exactly 0
        row_ids = jnp.arange(self.n_rows, dtype=jnp.int32)[:, None]
        d = jnp.where(self.indices == row_ids, self.weights, 0.0).sum(axis=1)
        if self.n_rows == self.n_cols:
            return d
        return jnp.zeros((self.n_cols,), jnp.float32).at[: self.n_rows].set(
            d[: self.n_cols]
        )

    def fl_gains(self, curmax: jax.Array) -> jax.Array:
        contrib = jnp.where(
            self.indices >= 0,
            jnp.maximum(self.weights - curmax[:, None], 0.0),
            0.0,
        )
        return (
            jnp.zeros((self.n_cols,), jnp.float32)
            .at[self.indices]
            .add(contrib, mode="drop")
        )

    def fl_gains_at(self, curmax: jax.Array, idx: jax.Array) -> jax.Array:
        full = self.fl_gains(curmax)
        safe = jnp.clip(idx, 0, self.n_cols - 1)
        return jnp.where(idx >= 0, full[safe], NEG_INF)

    def masked_rowmax(self, mask: jax.Array) -> jax.Array:
        safe = jnp.clip(self.indices, 0, self.n_cols - 1)
        live = (self.indices >= 0) & mask.astype(bool)[safe]
        return jnp.max(
            jnp.where(live, self.weights, 0.0), axis=1, initial=0.0
        )

    def quad(self, mask: jax.Array) -> jax.Array:
        m = mask.astype(jnp.float32)
        safe = jnp.clip(self.indices, 0, self.n_cols - 1)
        inner = (self._live_w() * m[safe]).sum(axis=1)  # (n_rows,)
        return (inner * m[: self.n_rows]).sum()

    def to_dense(self) -> jax.Array:
        """Materialize the sparsified matrix (tests / small-n interop)."""
        rows = jnp.broadcast_to(
            jnp.arange(self.n_rows, dtype=jnp.int32)[:, None], self.indices.shape
        )
        return (
            jnp.zeros((self.n_rows, self.n_cols), jnp.float32)
            .at[rows, self.indices]
            .add(self._live_w(), mode="drop")
        )


def knn_source(indices, weights, n_cols: int | None = None) -> KnnSource:
    indices = jnp.asarray(indices, jnp.int32)
    weights = jnp.asarray(weights, jnp.float32)
    if indices.shape != weights.shape or indices.ndim != 2:
        raise ValueError(
            f"indices/weights must both be (n, k); got {indices.shape} "
            f"vs {weights.shape}"
        )
    n_rows, k = indices.shape
    return KnnSource(
        indices=indices,
        weights=weights,
        n_rows=n_rows,
        n_cols=int(n_cols) if n_cols is not None else n_rows,
        k=k,
    )


def knn_from_features(
    x, k: int, metric: str = "dot", rbf_sigma: float | None = None,
    batch: int = 2048,
) -> KnnSource:
    """Top-k symmetric k-NN source from features, built in row batches so
    peak memory is O(batch * n), never the full (n, n) matrix."""
    src = feature_source(x, metric=metric, rbf_sigma=rbf_sigma)
    n = src.n_rows
    idx_out, w_out = [], []
    for lo in range(0, n, batch):
        block = dataclasses.replace(
            src,
            x=src.x[lo : lo + batch],
            xx=src.xx[lo : lo + batch],
            n_rows=min(batch, n - lo),
        )
        sim = block._sim_cols(block.y, block.yy, None)  # (b, n)
        w, i = jax.lax.top_k(sim, k)
        idx_out.append(i.astype(jnp.int32))
        w_out.append(w)
    return knn_source(
        jnp.concatenate(idx_out, axis=0), jnp.concatenate(w_out, axis=0), n_cols=n
    )


@pytree_dataclass(meta_fields=("n_rows", "n_cols"))
class DenseSource:
    """The materialized matrix, riding the same source contract (so dense
    requests — and the existing fused Pallas sweeps — plug into the
    matrix-free families unchanged)."""

    sim: jax.Array  # (n_rows, n_cols)
    n_rows: int
    n_cols: int

    def col(self, j: jax.Array) -> jax.Array:
        return self.sim[:, j]

    def col_sums(self) -> jax.Array:
        return self.sim.sum(axis=0)

    def diag(self) -> jax.Array:
        return jnp.diagonal(self.sim)

    def fl_gains(self, curmax: jax.Array) -> jax.Array:
        return jnp.maximum(self.sim - curmax[:, None], 0.0).sum(axis=0)

    def fl_gains_at(self, curmax: jax.Array, idx: jax.Array) -> jax.Array:
        safe = jnp.clip(idx, 0, self.n_cols - 1)
        cols = jnp.take(self.sim, safe, axis=1)
        g = jnp.maximum(cols - curmax[:, None], 0.0).sum(axis=0)
        return jnp.where(idx >= 0, g, NEG_INF)

    def masked_rowmax(self, mask: jax.Array) -> jax.Array:
        masked = jnp.where(mask[None, :], self.sim, 0.0)
        return jnp.max(masked, axis=1, initial=0.0)

    def quad(self, mask: jax.Array) -> jax.Array:
        m = mask.astype(self.sim.dtype)
        return m[: self.n_rows] @ self.sim @ m


def dense_source(sim) -> DenseSource:
    sim = jnp.asarray(sim)
    return DenseSource(sim=sim, n_rows=int(sim.shape[0]), n_cols=int(sim.shape[1]))


SimilaritySource = FeatureSource | KnnSource | DenseSource
