"""Graph-Cut information measures (paper Table 1).

GCMI   I(A;Q)  = 2 * lam * sum_{i in A, j in Q} S_ij     (pure modular — the
                 paper's "pure retrieval" function, Fig. 8)
GCCG   f(A|P)  = f_lam(A) - 2 * lam * nu * sum_{i in A, j in P} S_ij
                 (= GraphCut with a modular penalty folded into ``total``)
GCCMI  == GCMI (paper: the CMI expression does not involve P).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction
from repro.core.functions.graph_cut import GraphCut


@pytree_dataclass(meta_fields=("n",))
class GCMI(SetFunction):
    qsum: jax.Array  # (n,) 2*lam*sum_{j in Q} S_ij — a modular function
    n: int

    @staticmethod
    def build(sim_vq: jax.Array, lam: float = 1.0) -> "GCMI":
        sim_vq = jnp.asarray(sim_vq)  # (n, |Q|)
        return GCMI(qsum=2.0 * lam * sim_vq.sum(axis=1), n=int(sim_vq.shape[0]))

    def init_state(self):
        return jnp.zeros((), self.qsum.dtype)  # running value

    def gains(self, state) -> jax.Array:
        return self.qsum

    def gains_at(self, state, idxs) -> jax.Array:
        return self.qsum[idxs]

    def update(self, state, j):
        return state + self.qsum[j]

    def evaluate(self, mask: jax.Array) -> jax.Array:
        return jnp.dot(mask.astype(self.qsum.dtype), self.qsum)

    def evaluate_state(self, state) -> jax.Array:
        return state


def gccg(
    sim_ground: jax.Array,
    sim_vp: jax.Array,
    lam: float = 0.5,
    nu: float = 1.0,
    sim_rep: jax.Array | None = None,
) -> GraphCut:
    """GCCG as a GraphCut instance with the private-set penalty folded in."""
    sim_ground = jnp.asarray(sim_ground)
    base = GraphCut.from_kernel(sim_ground, lam=lam, sim_rep=sim_rep)
    penalty = 2.0 * lam * nu * jnp.asarray(sim_vp).sum(axis=1)
    return GraphCut(
        sim_ground=base.sim_ground,
        total=base.total - penalty,
        lam=base.lam,
        n=base.n,
    )


gccmi = GCMI.build  # paper: GCCMI expression is identical to GCMI
