"""Set-Cover / Probabilistic-Set-Cover information measures (paper §5.2.2-4).

Exactly the paper's implementation trick: each measure IS the base function
with a modified cover set / reweighted concepts:

  SCMI    = SC with concepts restricted to Γ(Q)
  SCCG    = SC with concepts outside Γ(P)
  SCCMI   = SC with concepts in Γ(Q) \\ Γ(P)
  PSCMI   = PSC with weights w_u * (1 - P_u(Q))
  PSCCG   = PSC with weights w_u * P_u(P)
  PSCCMI  = PSC with weights w_u * (1 - P_u(Q)) * P_u(P)

Because every measure IS a SetCover / ProbabilisticSetCover instance, the
whole family inherits that class's serving stack for free: the fused Pallas
sweep (``use_kernel=True``, forwarded below), the coalescer padder, and the
mesh ShardRule all resolve along the MRO — see docs/functions.md.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.functions.set_cover import ProbabilisticSetCover, SetCover


def _concepts_of(cover_rows: jnp.ndarray) -> jnp.ndarray:
    """(k, m) cover rows -> (m,) indicator of concepts covered by the set."""
    return jnp.max(jnp.asarray(cover_rows, jnp.float32), axis=0, initial=0.0)


def sc_mi(
    cover: jnp.ndarray,
    w: jnp.ndarray,
    cover_q: jnp.ndarray,
    use_kernel: bool = False,
) -> SetCover:
    keep = _concepts_of(cover_q)
    return SetCover.from_cover(cover, jnp.asarray(w) * keep, use_kernel=use_kernel)


def sc_cg(
    cover: jnp.ndarray,
    w: jnp.ndarray,
    cover_p: jnp.ndarray,
    use_kernel: bool = False,
) -> SetCover:
    drop = _concepts_of(cover_p)
    return SetCover.from_cover(
        cover, jnp.asarray(w) * (1.0 - drop), use_kernel=use_kernel
    )


def sc_cmi(
    cover: jnp.ndarray,
    w: jnp.ndarray,
    cover_q: jnp.ndarray,
    cover_p: jnp.ndarray,
    use_kernel: bool = False,
) -> SetCover:
    keep = _concepts_of(cover_q) * (1.0 - _concepts_of(cover_p))
    return SetCover.from_cover(cover, jnp.asarray(w) * keep, use_kernel=use_kernel)


def _miss(probs_rows: jnp.ndarray) -> jnp.ndarray:
    """(k, m) membership probabilities -> (m,) P_u(set) = prod (1 - p)."""
    return jnp.prod(1.0 - jnp.asarray(probs_rows, jnp.float32), axis=0)


def psc_mi(
    probs: jnp.ndarray,
    w: jnp.ndarray,
    probs_q: jnp.ndarray,
    use_kernel: bool = False,
) -> ProbabilisticSetCover:
    return ProbabilisticSetCover.from_probs(
        probs, jnp.asarray(w) * (1.0 - _miss(probs_q)), use_kernel=use_kernel
    )


def psc_cg(
    probs: jnp.ndarray,
    w: jnp.ndarray,
    probs_p: jnp.ndarray,
    use_kernel: bool = False,
) -> ProbabilisticSetCover:
    return ProbabilisticSetCover.from_probs(
        probs, jnp.asarray(w) * _miss(probs_p), use_kernel=use_kernel
    )


def psc_cmi(
    probs: jnp.ndarray,
    w: jnp.ndarray,
    probs_q: jnp.ndarray,
    probs_p: jnp.ndarray,
    use_kernel: bool = False,
) -> ProbabilisticSetCover:
    return ProbabilisticSetCover.from_probs(
        probs,
        jnp.asarray(w) * (1.0 - _miss(probs_q)) * _miss(probs_p),
        use_kernel=use_kernel,
    )
