"""Generic MI / CG / CMI combinators (paper §3).

Any submodular information measure decomposes into two primitives:

  ConditionedFunction   g(A) = f(A ∪ C) - f(C)              (= CG with C = P)
  DifferenceFunction    g(A) = f1(A) - f2(A)

because  I_f(A;Q)   = f(A) - f(A|Q)                         (MI)
         I_f(A;Q|P) = f(A|P) - f(A|Q ∪ P)                   (CMI)

The base function must be built over the *extended* ground set V ∪ Q ∪ P
(see ``similarity.build_extended_kernel``), with V at indices [0, n_v).
These generic forms are the correctness oracles for the closed-form
instantiations (fl.py, gc.py, logdet.py, sc.py) in the property tests.

Serving note: the generic combinators wrap an arbitrary base pytree, so they
register no coalescer padder / mesh ShardRule — serve the *closed-form*
instantiations instead, which are plain instances of already-served families
(FLVMI / FLQMI / FLCG / FLCMI and GCMI register their own adapters; gccg,
the sc_* / psc_* measures, and logdet_cg resolve through GraphCut /
SetCover / ProbabilisticSetCover / LogDet along the MRO).  The generic forms
still work everywhere ``maximize`` does, including the single-device batched
engine when same-shaped.  Coverage matrix + runnable snippets:
docs/functions.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass(meta_fields=("n",))
class ConditionedFunction(SetFunction):
    base: SetFunction
    cond_idx: jax.Array  # indices (in the base ground set) of C
    n: int  # selectable prefix size n_v

    @staticmethod
    def build(base: SetFunction, cond_idx, n_select: int) -> "ConditionedFunction":
        return ConditionedFunction(
            base=base, cond_idx=jnp.asarray(cond_idx, jnp.int32), n=int(n_select)
        )

    def init_state(self):
        state = self.base.init_state()
        if self.cond_idx.shape[0]:

            def body(i, s):
                return self.base.update(s, self.cond_idx[i])

            state = jax.lax.fori_loop(0, self.cond_idx.shape[0], body, state)
        return state

    def gains(self, state) -> jax.Array:
        return self.base.gains(state)[: self.n]

    def gains_at(self, state, idxs) -> jax.Array:
        return self.base.gains_at(state, idxs)

    def update(self, state, j):
        return self.base.update(state, j)

    def _cond_mask(self) -> jax.Array:
        from repro.common import mask_from_indices

        return mask_from_indices(self.cond_idx, self.base.n)

    def evaluate(self, mask: jax.Array) -> jax.Array:
        cmask = self._cond_mask()
        full = jnp.pad(mask, (0, self.base.n - self.n)) | cmask
        return self.base.evaluate(full) - self.base.evaluate(cmask)


@pytree_dataclass(meta_fields=("n",))
class DifferenceFunction(SetFunction):
    f1: SetFunction
    f2: SetFunction
    n: int

    @staticmethod
    def build(f1: SetFunction, f2: SetFunction, n: int) -> "DifferenceFunction":
        return DifferenceFunction(f1=f1, f2=f2, n=int(n))

    def init_state(self):
        return (self.f1.init_state(), self.f2.init_state())

    def gains(self, state) -> jax.Array:
        s1, s2 = state
        return self.f1.gains(s1)[: self.n] - self.f2.gains(s2)[: self.n]

    def gains_at(self, state, idxs) -> jax.Array:
        s1, s2 = state
        return self.f1.gains_at(s1, idxs) - self.f2.gains_at(s2, idxs)

    def update(self, state, j):
        s1, s2 = state
        return (self.f1.update(s1, j), self.f2.update(s2, j))

    def evaluate(self, mask: jax.Array) -> jax.Array:
        m1 = jnp.pad(mask, (0, self.f1.n - self.n))
        m2 = jnp.pad(mask, (0, self.f2.n - self.n))
        return self.f1.evaluate(m1) - self.f2.evaluate(m2)


def generic_mi(base: SetFunction, q_idx, n_select: int) -> DifferenceFunction:
    """I_f(A;Q) = f(A) - f(A|Q), as a set function of A ⊆ V."""
    return DifferenceFunction.build(
        base, ConditionedFunction.build(base, q_idx, n_select), n_select
    )


def generic_cg(base: SetFunction, p_idx, n_select: int) -> ConditionedFunction:
    """f(A|P)."""
    return ConditionedFunction.build(base, p_idx, n_select)


def generic_cmi(base: SetFunction, q_idx, p_idx, n_select: int) -> DifferenceFunction:
    """I_f(A;Q|P) = f(A|P) - f(A|Q ∪ P)."""
    qp = jnp.concatenate(
        [jnp.asarray(q_idx, jnp.int32), jnp.asarray(p_idx, jnp.int32)]
    )
    return DifferenceFunction.build(
        ConditionedFunction.build(base, p_idx, n_select),
        ConditionedFunction.build(base, qp, n_select),
        n_select,
    )
