"""Facility-Location information measures, closed forms (paper Table 1).

FLVMI  I(A;Q)   = sum_i min(max_{j in A} S_ij, eta * max_{j in Q} S_ij)
FLQMI  I(A;Q)   = sum_{q in Q} max_{j in A} S_qj + eta * sum_{i in A} max_q S_iq
FLCG   f(A|P)   = sum_i max(max_{j in A} S_ij - nu * max_{j in P} S_ij, 0)
FLCMI  I(A;Q|P) = sum_i max(min(max_A S_ij, eta qmax_i) - nu pmax_i, 0)

All use the memoized ``curmax`` statistic of FL (paper Table 4), vectorized
over the full candidate set per step.  FLQMI only needs the (Q × V) kernel —
the paper's "very efficient to optimize" variant used for targeted selection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import pytree_dataclass
from repro.core.functions.base import SetFunction
from repro.core.functions.facility_location import FLState


def _fl_state(n_rows: int, dtype) -> FLState:
    return FLState(curmax=jnp.zeros((n_rows,), dtype), n_rows=n_rows)


@pytree_dataclass(meta_fields=("n",))
class FLVMI(SetFunction):
    sim: jax.Array  # (|V|, n) ground kernel
    qmax: jax.Array  # (|V|,) eta * max_{q in Q} S_iq
    n: int

    @staticmethod
    def build(sim: jax.Array, sim_vq: jax.Array, eta: float = 1.0) -> "FLVMI":
        sim = jnp.asarray(sim)
        qmax = eta * jnp.max(jnp.asarray(sim_vq), axis=1)
        return FLVMI(sim=sim, qmax=qmax, n=int(sim.shape[1]))

    def init_state(self) -> FLState:
        return _fl_state(self.sim.shape[0], self.sim.dtype)

    def gains(self, state: FLState) -> jax.Array:
        cur = jnp.minimum(state.curmax, self.qmax)  # (|V|,) current contribution
        new = jnp.minimum(
            jnp.maximum(state.curmax[:, None], self.sim), self.qmax[:, None]
        )
        return (new - cur[:, None]).sum(axis=0)

    def gains_at(self, state: FLState, idxs) -> jax.Array:
        cur = jnp.minimum(state.curmax, self.qmax)
        cols = self.sim[:, idxs]
        new = jnp.minimum(jnp.maximum(state.curmax[:, None], cols), self.qmax[:, None])
        return (new - cur[:, None]).sum(axis=0)

    def update(self, state: FLState, j) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.sim[:, j]), n_rows=state.n_rows
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        mx = jnp.max(jnp.where(mask[None, :], self.sim, 0.0), axis=1, initial=0.0)
        return jnp.minimum(mx, self.qmax).sum()

    def evaluate_state(self, state: FLState) -> jax.Array:
        return jnp.minimum(state.curmax, self.qmax).sum()


@pytree_dataclass(meta_fields=("n",))
class FLQMI(SetFunction):
    sim_qv: jax.Array  # (|Q|, n) query-to-ground kernel — the only kernel needed
    modular: jax.Array  # (n,) eta * max_{q in Q} S_jq
    n: int

    @staticmethod
    def build(sim_qv: jax.Array, eta: float = 1.0) -> "FLQMI":
        sim_qv = jnp.asarray(sim_qv)
        return FLQMI(
            sim_qv=sim_qv,
            modular=eta * jnp.max(sim_qv, axis=0),
            n=int(sim_qv.shape[1]),
        )

    def init_state(self) -> FLState:
        return _fl_state(self.sim_qv.shape[0], self.sim_qv.dtype)

    def gains(self, state: FLState) -> jax.Array:
        rep = jnp.maximum(self.sim_qv - state.curmax[:, None], 0.0).sum(axis=0)
        return rep + self.modular

    def gains_at(self, state: FLState, idxs) -> jax.Array:
        cols = self.sim_qv[:, idxs]
        rep = jnp.maximum(cols - state.curmax[:, None], 0.0).sum(axis=0)
        return rep + self.modular[idxs]

    def update(self, state: FLState, j) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.sim_qv[:, j]), n_rows=state.n_rows
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        mx = jnp.max(jnp.where(mask[None, :], self.sim_qv, 0.0), axis=1, initial=0.0)
        return mx.sum() + jnp.dot(mask.astype(self.modular.dtype), self.modular)

    def evaluate_state(self, state: FLState) -> jax.Array:
        raise NotImplementedError("modular part needs the mask; use evaluate().")


@pytree_dataclass(meta_fields=("n",))
class FLCG(SetFunction):
    sim: jax.Array  # (|V|, n)
    pmax: jax.Array  # (|V|,) nu * max_{p in P} S_ip
    n: int

    @staticmethod
    def build(sim: jax.Array, sim_vp: jax.Array, nu: float = 1.0) -> "FLCG":
        sim = jnp.asarray(sim)
        pmax = nu * jnp.max(jnp.asarray(sim_vp), axis=1)
        return FLCG(sim=sim, pmax=pmax, n=int(sim.shape[1]))

    def init_state(self) -> FLState:
        return _fl_state(self.sim.shape[0], self.sim.dtype)

    def gains(self, state: FLState) -> jax.Array:
        cur = jnp.maximum(state.curmax - self.pmax, 0.0)
        new = jnp.maximum(
            jnp.maximum(state.curmax[:, None], self.sim) - self.pmax[:, None], 0.0
        )
        return (new - cur[:, None]).sum(axis=0)

    def gains_at(self, state: FLState, idxs) -> jax.Array:
        cur = jnp.maximum(state.curmax - self.pmax, 0.0)
        cols = self.sim[:, idxs]
        new = jnp.maximum(
            jnp.maximum(state.curmax[:, None], cols) - self.pmax[:, None], 0.0
        )
        return (new - cur[:, None]).sum(axis=0)

    def update(self, state: FLState, j) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.sim[:, j]), n_rows=state.n_rows
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        mx = jnp.max(jnp.where(mask[None, :], self.sim, 0.0), axis=1, initial=0.0)
        return jnp.maximum(mx - self.pmax, 0.0).sum()

    def evaluate_state(self, state: FLState) -> jax.Array:
        return jnp.maximum(state.curmax - self.pmax, 0.0).sum()


@pytree_dataclass(meta_fields=("n",))
class FLCMI(SetFunction):
    sim: jax.Array  # (|V|, n)
    qmax: jax.Array  # (|V|,) eta-scaled
    pmax: jax.Array  # (|V|,) nu-scaled
    n: int

    @staticmethod
    def build(
        sim: jax.Array,
        sim_vq: jax.Array,
        sim_vp: jax.Array,
        eta: float = 1.0,
        nu: float = 1.0,
    ) -> "FLCMI":
        sim = jnp.asarray(sim)
        return FLCMI(
            sim=sim,
            qmax=eta * jnp.max(jnp.asarray(sim_vq), axis=1),
            pmax=nu * jnp.max(jnp.asarray(sim_vp), axis=1),
            n=int(sim.shape[1]),
        )

    def _contrib(self, curmax: jax.Array) -> jax.Array:
        return jnp.maximum(jnp.minimum(curmax, self.qmax) - self.pmax, 0.0)

    def init_state(self) -> FLState:
        return _fl_state(self.sim.shape[0], self.sim.dtype)

    def gains(self, state: FLState) -> jax.Array:
        cur = self._contrib(state.curmax)
        new = jnp.maximum(
            jnp.minimum(
                jnp.maximum(state.curmax[:, None], self.sim), self.qmax[:, None]
            )
            - self.pmax[:, None],
            0.0,
        )
        return (new - cur[:, None]).sum(axis=0)

    def gains_at(self, state: FLState, idxs) -> jax.Array:
        cur = self._contrib(state.curmax)
        cols = self.sim[:, idxs]
        new = jnp.maximum(
            jnp.minimum(jnp.maximum(state.curmax[:, None], cols), self.qmax[:, None])
            - self.pmax[:, None],
            0.0,
        )
        return (new - cur[:, None]).sum(axis=0)

    def update(self, state: FLState, j) -> FLState:
        return FLState(
            curmax=jnp.maximum(state.curmax, self.sim[:, j]), n_rows=state.n_rows
        )

    def evaluate(self, mask: jax.Array) -> jax.Array:
        mx = jnp.max(jnp.where(mask[None, :], self.sim, 0.0), axis=1, initial=0.0)
        return self._contrib(mx).sum()

    def evaluate_state(self, state: FLState) -> jax.Array:
        return self._contrib(state.curmax).sum()
