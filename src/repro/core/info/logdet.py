"""Log-Determinant information measures (paper §3.4, Table 1).

Built from projected kernels + the difference combinator:

  LogDetMI  (A;Q)   = logdet(S_A) - logdet((S - eta^2 S_.Q S_Q^-1 S_.Q^T)_A)
  LogDetCG  (A|P)   = logdet((S - nu^2 S_.P S_P^-1 S_.P^T)_A)
  LogDetCMI (A;Q|P) = LogDetCG_P(A) - LogDetCG_{Q∪P}(A)

each term being a plain LogDet on a Schur-complement kernel, so the
incremental-Cholesky memoization applies unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.functions.log_det import LogDet
from repro.core.info.combinators import DifferenceFunction

_JITTER = 1e-6


def _schur(S, S_vc, S_cc, scale):
    """S - scale^2 * S_vc S_cc^-1 S_vc^T, with jitter for stability."""
    S_cc = jnp.asarray(S_cc)
    reg = S_cc + _JITTER * jnp.eye(S_cc.shape[0], dtype=S_cc.dtype)
    sol = jnp.linalg.solve(reg, jnp.asarray(S_vc).T)  # (|C|, n)
    return jnp.asarray(S) - (scale * scale) * (jnp.asarray(S_vc) @ sol)


def logdet_mi(
    S: jnp.ndarray,
    S_vq: jnp.ndarray,
    S_qq: jnp.ndarray,
    eta: float = 1.0,
    max_select: int | None = None,
) -> DifferenceFunction:
    n = int(jnp.asarray(S).shape[0])
    f1 = LogDet.from_kernel(S, max_select)
    f2 = LogDet.from_kernel(_schur(S, S_vq, S_qq, eta), max_select)
    return DifferenceFunction.build(f1, f2, n)


def logdet_cg(
    S: jnp.ndarray,
    S_vp: jnp.ndarray,
    S_pp: jnp.ndarray,
    nu: float = 1.0,
    max_select: int | None = None,
) -> LogDet:
    return LogDet.from_kernel(_schur(S, S_vp, S_pp, nu), max_select)


def logdet_cmi(
    S: jnp.ndarray,
    S_vq: jnp.ndarray,
    S_qq: jnp.ndarray,
    S_vp: jnp.ndarray,
    S_pp: jnp.ndarray,
    S_qp: jnp.ndarray,
    eta: float = 1.0,
    nu: float = 1.0,
    max_select: int | None = None,
) -> DifferenceFunction:
    n = int(jnp.asarray(S).shape[0])
    f1 = logdet_cg(S, S_vp, S_pp, nu, max_select)
    # joint conditioning set Q ∪ P with eta/nu cross-scaling on the V side
    S_vqp = jnp.concatenate(
        [eta * jnp.asarray(S_vq), nu * jnp.asarray(S_vp)], axis=1
    )
    top = jnp.concatenate([jnp.asarray(S_qq), jnp.asarray(S_qp)], axis=1)
    bot = jnp.concatenate([jnp.asarray(S_qp).T, jnp.asarray(S_pp)], axis=1)
    S_qpqp = jnp.concatenate([top, bot], axis=0)
    f2 = LogDet.from_kernel(_schur(S, S_vqp, S_qpqp, 1.0), max_select)
    return DifferenceFunction.build(f1, f2, n)
