"""Concave-Over-Modular MI (paper §3.6, Table 1):

  I(A;Q) = eta * sum_{i in A} psi(sum_{j in Q} S_ij)
           + sum_{j in Q} psi(sum_{i in A} S_ij)

Memoized statistic (Table 4): acc_q = sum_{i in A} S_iq for each query q.
The first term is modular (precomputed).  CG/CMI are "Not Useful" per the
paper and intentionally omitted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import get_concave, pytree_dataclass
from repro.core.functions.base import SetFunction


@pytree_dataclass(meta_fields=("n", "concave"))
class ConcaveOverModular(SetFunction):
    sim_vq: jax.Array  # (n, |Q|)
    modular: jax.Array  # (n,) eta * psi(sum_q S_iq)
    n: int
    concave: str = "sqrt"

    @staticmethod
    def build(
        sim_vq: jax.Array, eta: float = 1.0, concave: str = "sqrt"
    ) -> "ConcaveOverModular":
        sim_vq = jnp.asarray(sim_vq)
        psi = get_concave(concave)
        return ConcaveOverModular(
            sim_vq=sim_vq,
            modular=eta * psi(sim_vq.sum(axis=1)),
            n=int(sim_vq.shape[0]),
            concave=concave,
        )

    def init_state(self) -> jax.Array:
        return jnp.zeros((self.sim_vq.shape[1],), self.sim_vq.dtype)  # acc_q

    def gains(self, state: jax.Array) -> jax.Array:
        psi = get_concave(self.concave)
        base = psi(state)  # (|Q|,)
        return self.modular + (psi(state[None, :] + self.sim_vq) - base[None, :]).sum(
            axis=1
        )

    def gains_at(self, state: jax.Array, idxs) -> jax.Array:
        psi = get_concave(self.concave)
        base = psi(state)
        return self.modular[idxs] + (
            psi(state[None, :] + self.sim_vq[idxs]) - base[None, :]
        ).sum(axis=1)

    def update(self, state: jax.Array, j) -> jax.Array:
        return state + self.sim_vq[j]

    def evaluate(self, mask: jax.Array) -> jax.Array:
        psi = get_concave(self.concave)
        acc = jnp.where(mask[:, None], self.sim_vq, 0.0).sum(axis=0)
        return jnp.dot(mask.astype(self.modular.dtype), self.modular) + psi(acc).sum()

    def evaluate_state(self, state: jax.Array) -> jax.Array:
        raise NotImplementedError("modular part needs the mask; use evaluate().")
