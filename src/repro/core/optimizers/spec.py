"""Typed selection specs and the ``solve()`` front door.

The paper's headline is a *rich, flexible API* over one optimization engine
(§7: ``f.maximize(budget, optimizer, stopIfZeroGain, ...)``).  This module is
that API, redesigned so ONE request object travels unchanged through every
execution route the library has grown:

- :class:`OptimizerSpec` — an optimizer name plus validated, defaulted
  hyperparameters, backed by the first-class :func:`register_optimizer`
  registry (which replaced the old ``_OPTIMIZERS`` lambda table in
  ``optimizers/api.py``).  Unknown names raise ``ValueError`` naming the
  registered set; unknown or ill-typed hyperparameters raise ``TypeError``
  naming the valid set — at construction, before any trace or flush.
- :class:`SelectionSpec` — function + budget + optimizer spec + stop rules +
  backend choice.  Stop-rule defaults resolve against the per-family table
  (:func:`register_family_defaults`) in exactly one place, so sequential,
  batched, sharded, and served execution agree (the Disparity*
  ``stopIfZeroGain=False`` default lives here now, not in the server).
- :func:`solve` — the single front door:

      solve(spec)                          # sequential
      solve([s1, s2, ...], mode="batched") # B specs -> one vmap-ed wave
      solve(specs, mesh=mesh)              # sharded over a 2-D device mesh
      solve(specs, mode="served")          # coalesced heterogeneous waves
      solve(specs, mode="async")           # futures via AsyncSelectionServer

  Every route returns :class:`~repro.core.optimizers.greedy.GreedyResult`
  objects that are bit-identical across modes (ids, gains, ``n_evals``) —
  the serving contract the repo pins everywhere.

Both specs are **pytree-serializable**: ``OptimizerSpec`` flattens to zero
leaves (it is pure static metadata, hashable, so it rides jit cache keys);
``SelectionSpec`` flattens to its function pytree with everything else as
static aux data — a spec passes through ``jax.jit`` / ``jax.vmap``
boundaries and round-trips ``to_dict()`` / ``from_dict()``.

The legacy entry points — ``maximize``, ``batched_maximize``,
``BatchedEngine.maximize``, ``SelectionServer.submit(fn, budget, ...)`` —
are deprecated shims over this module (see docs/api.md for the migration
table); ``tools/check_shims.py`` gates that no internal caller uses them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Optional, Sequence

import jax
import numpy as np

from repro.core.functions.base import SetFunction
# stdlib-only module: importable from core without dragging the serving
# stack in (launch has no package __init__)
from repro.launch.resilience import RetryPolicy
from repro.core.optimizers.greedy import (
    GreedyResult,
    lazier_than_lazy_greedy,
    lazy_greedy,
    naive_greedy,
    stochastic_greedy,
)

__all__ = [
    "OptimizerSpec",
    "SelectionSpec",
    "solve",
    "register_optimizer",
    "register_family_defaults",
    "optimizer_names",
    "resolve_optimizer",
    "wave_capable_names",
    "family_defaults",
]


# ---------------------------------------------------------------------------
# Hyperparameter validation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Param:
    """One optimizer hyperparameter: its default and a coercing validator.

    ``convert`` receives the user value and returns the normalized form, or
    raises ``TypeError`` / ``ValueError`` with an actionable message.
    """

    default: object
    convert: Callable[[object], object]
    doc: str = ""


def _int_min(lo: int) -> Callable:
    def convert(v):
        i = int(v)
        if i < lo:
            raise ValueError(f"must be an int >= {lo}, got {v!r}")
        return i

    return convert


def _opt_int_min(lo: int) -> Callable:
    base = _int_min(lo)

    def convert(v):
        return None if v is None else base(v)

    return convert


def _unit_float(v) -> float:
    f = float(v)
    if not 0.0 < f <= 1.0:
        raise ValueError(f"must be a float in (0, 1], got {v!r}")
    return f


# ---------------------------------------------------------------------------
# Optimizer registry (replaces the api.py _OPTIMIZERS lambda table)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OptimizerDef:
    """A registered optimizer: hyperparameter schema + execution hooks.

    ``run`` answers a single sequential query.  ``batched_run`` /
    ``sharded_run`` are the wave-shaped hooks consumed by
    :class:`~repro.core.optimizers.batched.BatchedEngine`; ``None`` means the
    optimizer cannot ride batched / sharded / served waves (it is rejected at
    spec-routing or submit time, never mid-flush).
    """

    name: str
    params: Mapping[str, Param]
    run: Callable  # (fn, budget, stop_zero, stop_neg, **params) -> GreedyResult
    batched_run: Optional[Callable] = None
    sharded_run: Optional[Callable] = None
    # mesh_replicated: the batched hook is valid on a device mesh as-is (the
    # program is sequential in its data pass, so replicating it on every
    # device gives the same answer as one device).  Streaming optimizers set
    # this: they have no collective sharded engine, yet must keep the on-mesh
    # == off-mesh bit-identity contract when a served wave lands on a mesh.
    mesh_replicated: bool = False

    @property
    def batched_capable(self) -> bool:
        return self.batched_run is not None and (
            self.sharded_run is not None or self.mesh_replicated
        )


_OPTIMIZERS: dict[str, OptimizerDef] = {}


def register_optimizer(
    name: str,
    run: Callable,
    *,
    params: Mapping[str, Param] | None = None,
    batched_run: Callable | None = None,
    sharded_run: Callable | None = None,
    mesh_replicated: bool = False,
) -> OptimizerDef:
    """Register (or replace) an optimizer under ``name``.

    ``params`` maps hyperparameter names to :class:`Param` (default +
    validator); :class:`OptimizerSpec` construction validates against it, so
    a misspelled option fails with a ``TypeError`` naming the valid set
    instead of being silently dropped (the old ``kw.get`` behaviour).
    ``mesh_replicated=True`` declares the batched hook safe to run replicated
    on a device mesh (no ``sharded_run`` needed for wave capability).
    """
    defn = OptimizerDef(
        name=name,
        params=dict(params or {}),
        run=run,
        batched_run=batched_run,
        sharded_run=sharded_run,
        mesh_replicated=mesh_replicated,
    )
    _OPTIMIZERS[name] = defn
    return defn


def optimizer_names() -> list[str]:
    """The registered optimizer names, sorted."""
    return sorted(_OPTIMIZERS)


def resolve_optimizer(name: str) -> OptimizerDef:
    """The :class:`OptimizerDef` registered under ``name``, or a
    ``ValueError`` naming the registered set."""
    defn = _OPTIMIZERS.get(name)
    if defn is None:
        raise ValueError(
            f"unknown optimizer {name!r}; choose from {optimizer_names()} "
            "(register new ones via repro.core.register_optimizer)"
        )
    return defn


def wave_capable_names() -> list[str]:
    """Optimizers with BOTH batched and sharded execution hooks — the set a
    wave route (batched / sharded / served / async) can accept.  The single
    source for every 'batched-capable optimizers: [...]' rejection."""
    return [n for n in optimizer_names() if _OPTIMIZERS[n].batched_capable]


# ---------------------------------------------------------------------------
# OptimizerSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, init=False)
class OptimizerSpec:
    """A validated (optimizer name, hyperparameters) pair.

        OptimizerSpec("LazyGreedy", screen_k=16)

    Unspecified hyperparameters are filled with their registered defaults at
    construction, so ``spec.params`` is always the complete resolved set.
    Instances are hashable static metadata: as a pytree they flatten to zero
    leaves (the spec itself is the treedef aux), so they ride jit cache keys
    and wave-coalescing group keys directly.
    """

    name: str
    _params: tuple  # sorted ((name, value), ...), fully defaulted

    def __init__(self, name: str, **params):
        if isinstance(name, OptimizerSpec):  # idempotent copy-construction
            if params:
                raise TypeError(
                    "cannot pass hyperparameters alongside an existing "
                    "OptimizerSpec; build a new one instead"
                )
            object.__setattr__(self, "name", name.name)
            object.__setattr__(self, "_params", name._params)
            return
        defn = resolve_optimizer(name)
        unknown = set(params) - set(defn.params)
        if unknown:
            raise TypeError(
                f"{defn.name} got unknown option(s) {sorted(unknown)}; "
                f"valid options: {sorted(defn.params)}"
            )
        resolved = {}
        for pname, p in defn.params.items():
            value = params.get(pname, p.default)
            try:
                resolved[pname] = p.convert(value)
            except (TypeError, ValueError) as e:
                raise TypeError(
                    f"invalid value for {defn.name} option {pname!r}: {e}"
                ) from None
        object.__setattr__(self, "name", defn.name)
        object.__setattr__(self, "_params", tuple(sorted(resolved.items())))

    @property
    def params(self) -> dict:
        """The fully-resolved hyperparameters as a plain dict."""
        return dict(self._params)

    def to_dict(self) -> dict:
        """JSON-able form: ``{"name": ..., "params": {...}}``."""
        return {"name": self.name, "params": self.params}

    @classmethod
    def from_dict(cls, d: Mapping) -> "OptimizerSpec":
        return cls(d["name"], **dict(d.get("params", {})))

    def __repr__(self) -> str:
        args = ", ".join(f"{k}={v!r}" for k, v in self._params)
        return f"OptimizerSpec({self.name!r}{', ' if args else ''}{args})"


jax.tree_util.register_pytree_node(
    OptimizerSpec,
    lambda s: ((), s),  # zero leaves; the spec IS the (hashable) aux data
    lambda aux, _: aux,
)


# ---------------------------------------------------------------------------
# Per-family stop-rule defaults (the one resolution point)
# ---------------------------------------------------------------------------

_LIBRARY_STOP_DEFAULTS = {"stopIfZeroGain": True, "stopIfNegativeGain": True}

# class -> partial overrides of the library defaults; resolved along the MRO
# (most-derived class wins).  The dispersion families register
# stopIfZeroGain=False here (their empty-set gain is exactly 0, so the
# library default silently returns an empty selection) — see
# core/functions/disparity.py.
_FAMILY_DEFAULTS: dict[type, dict[str, bool]] = {}


def register_family_defaults(cls: type, **defaults: bool) -> None:
    """Override stop-rule defaults for a function family (and subclasses).

    Accepted keys: ``stopIfZeroGain`` / ``stopIfNegativeGain``.  Consumed by
    :class:`SelectionSpec` when the caller leaves a stop rule unset, so every
    execution route — sequential, batched, sharded, served — agrees on the
    family's default stopping semantics.
    """
    unknown = set(defaults) - set(_LIBRARY_STOP_DEFAULTS)
    if unknown:
        raise TypeError(
            f"unknown stop-rule default(s) {sorted(unknown)}; "
            f"valid: {sorted(_LIBRARY_STOP_DEFAULTS)}"
        )
    _FAMILY_DEFAULTS.setdefault(cls, {}).update(
        {k: bool(v) for k, v in defaults.items()}
    )


def family_defaults(cls: type) -> dict[str, bool]:
    """The resolved stop-rule defaults for ``cls`` (library defaults merged
    with registered per-family overrides, most-derived class winning)."""
    out = dict(_LIBRARY_STOP_DEFAULTS)
    for klass in reversed(cls.__mro__):
        out.update(_FAMILY_DEFAULTS.get(klass, {}))
    return out


# ---------------------------------------------------------------------------
# SelectionSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, init=False, eq=False)
class SelectionSpec:
    """One selection request: select ``budget`` items under ``fn``.

        SelectionSpec(fn, budget=8, optimizer="LazyGreedy", screen_k=16)

    Validation happens HERE, at construction — unknown optimizers, unknown
    or ill-typed hyperparameters, non-function ``fn`` objects, and backend
    overrides the family cannot honor all raise before anything is traced,
    dispatched, or flushed.  Stop rules left as ``None`` resolve against the
    per-family default table exactly once (:func:`family_defaults`), so the
    same spec means the same thing on every execution route.

    ``use_kernel`` is the backend choice: ``None`` leaves the function as
    built; ``True`` / ``False`` rebuilds it with the fused-Pallas sweep
    forced on / off at solve time (only for families exposing the flag).

    ``deadline_s`` is an optional per-request latency budget in seconds
    (positive, finite).  Sequential and batched execution ignore it; the
    async serving scheduler honors it by flushing the request's group no
    later than ``deadline_s`` after submission, instead of letting the
    request wait the full coalescing interval for co-travellers (see
    docs/serving.md — a deadline shapes *scheduling*, it never changes the
    selection).

    ``retry`` is an optional :class:`~repro.launch.resilience.RetryPolicy`
    consumed by the serving front doors: transient dispatch failures are
    retried with deterministic backoff, and the request is quarantined with
    a typed :class:`~repro.launch.resilience.RequestFailed` after
    ``max_attempts`` (its ``timeout_s`` is the request's wall-clock budget
    across attempts — distinct from ``deadline_s``, which only shapes
    scheduling).  Sequential and batched ``solve()`` ignore it.

    As a pytree, the function is the only leaf-bearing child; budget,
    optimizer spec, stop rules and backend choice are static aux data — so a
    spec crosses ``jit`` / ``vmap`` boundaries and its static half rides the
    compilation cache key.
    """

    fn: object
    budget: int
    optimizer: OptimizerSpec
    stop_if_zero: bool
    stop_if_negative: bool
    use_kernel: Optional[bool]
    deadline_s: Optional[float]
    retry: Optional[RetryPolicy]

    def __init__(
        self,
        fn,
        budget: int,
        optimizer: str | OptimizerSpec = "NaiveGreedy",
        *,
        stopIfZeroGain: bool | None = None,
        stopIfNegativeGain: bool | None = None,
        use_kernel: bool | None = None,
        deadline_s: float | None = None,
        retry: RetryPolicy | None = None,
        **optimizer_params,
    ):
        if not isinstance(fn, SetFunction):
            raise TypeError(
                "SelectionSpec needs a SetFunction instance (e.g. "
                "FacilityLocation.from_kernel(...)); got "
                f"{type(fn).__name__!r} — see docs/functions.md for the "
                "function families"
            )
        if isinstance(optimizer, OptimizerSpec):
            if optimizer_params:
                raise TypeError(
                    "cannot pass optimizer hyperparameters "
                    f"{sorted(optimizer_params)} alongside an OptimizerSpec; "
                    "set them on the OptimizerSpec itself"
                )
            opt = optimizer
        else:
            defn = resolve_optimizer(optimizer)
            unknown = set(optimizer_params) - set(defn.params)
            if unknown:
                valid = sorted(defn.params) + [
                    "stopIfZeroGain",
                    "stopIfNegativeGain",
                    "use_kernel",
                ]
                raise TypeError(
                    f"{defn.name} got unknown option(s) {sorted(unknown)}; "
                    f"valid options: {valid}"
                )
            opt = OptimizerSpec(optimizer, **optimizer_params)
        budget = int(budget)
        if budget < 1:
            raise ValueError(f"budget must be a positive int, got {budget}")
        if use_kernel is not None:
            names = {f.name for f in dataclasses.fields(fn)}
            if "use_kernel" not in names:
                raise TypeError(
                    f"{type(fn).__name__} has no use_kernel backend flag; "
                    "leave use_kernel=None for this family (see the README "
                    "coverage matrix for the fused-sweep families)"
                )
            use_kernel = bool(use_kernel)
        if deadline_s is not None:
            deadline_s = float(deadline_s)
            if not math.isfinite(deadline_s) or deadline_s <= 0:
                raise ValueError(
                    "deadline_s must be a positive finite number of seconds "
                    f"(or None for no deadline), got {deadline_s!r}"
                )
        if retry is not None and not isinstance(retry, RetryPolicy):
            raise TypeError(
                "retry must be a repro.launch.resilience.RetryPolicy (or "
                f"None for single-attempt semantics), got {type(retry).__name__!r}"
            )
        defaults = family_defaults(type(fn))
        stop_zero = (
            defaults["stopIfZeroGain"]
            if stopIfZeroGain is None
            else bool(stopIfZeroGain)
        )
        stop_neg = (
            defaults["stopIfNegativeGain"]
            if stopIfNegativeGain is None
            else bool(stopIfNegativeGain)
        )
        object.__setattr__(self, "fn", fn)
        object.__setattr__(self, "budget", budget)
        object.__setattr__(self, "optimizer", opt)
        object.__setattr__(self, "stop_if_zero", stop_zero)
        object.__setattr__(self, "stop_if_negative", stop_neg)
        object.__setattr__(self, "use_kernel", use_kernel)
        object.__setattr__(self, "deadline_s", deadline_s)
        object.__setattr__(self, "retry", retry)

    # -- execution-facing helpers -------------------------------------------

    def resolved_fn(self):
        """The function with the spec's backend choice applied (identity when
        ``use_kernel`` is None or already matches)."""
        if self.use_kernel is None or self.use_kernel == self.fn.use_kernel:
            return self.fn
        return dataclasses.replace(self.fn, use_kernel=self.use_kernel)

    @property
    def static_key(self) -> tuple:
        """The non-function half, as one hashable tuple (wave-group keys)."""
        return (
            self.budget,
            self.optimizer,
            self.stop_if_zero,
            self.stop_if_negative,
            self.use_kernel,
            self.deadline_s,
            self.retry,
        )

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        """Dict form mirroring the constructor keywords.  ``fn`` stays the
        live pytree (functions carry device arrays; serialize those with your
        checkpointing layer) — everything else is JSON-able."""
        return {
            "fn": self.fn,
            "budget": self.budget,
            "optimizer": self.optimizer.to_dict(),
            "stopIfZeroGain": self.stop_if_zero,
            "stopIfNegativeGain": self.stop_if_negative,
            "use_kernel": self.use_kernel,
            "deadline_s": self.deadline_s,
            "retry": self.retry.to_dict() if self.retry is not None else None,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "SelectionSpec":
        opt = d.get("optimizer", "NaiveGreedy")
        if isinstance(opt, Mapping):
            opt = OptimizerSpec.from_dict(opt)
        retry = d.get("retry")
        if isinstance(retry, Mapping):
            retry = RetryPolicy.from_dict(retry)
        return cls(
            d["fn"],
            d["budget"],
            opt,
            stopIfZeroGain=d.get("stopIfZeroGain"),
            stopIfNegativeGain=d.get("stopIfNegativeGain"),
            use_kernel=d.get("use_kernel"),
            deadline_s=d.get("deadline_s"),
            retry=retry,
        )

    def __eq__(self, other) -> bool:
        if not isinstance(other, SelectionSpec):
            return NotImplemented
        if self.static_key != other.static_key:
            return False
        if jax.tree.structure(self.fn) != jax.tree.structure(other.fn):
            return False
        return all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(self.fn), jax.tree.leaves(other.fn))
        )

    __hash__ = None  # function leaves are arrays; use static_key for hashing

    def __repr__(self) -> str:
        return (
            f"SelectionSpec({type(self.fn).__name__}(n={self.fn.n}), "
            f"budget={self.budget}, optimizer={self.optimizer!r}, "
            f"stopIfZeroGain={self.stop_if_zero}, "
            f"stopIfNegativeGain={self.stop_if_negative}, "
            f"use_kernel={self.use_kernel}"
            + (f", deadline_s={self.deadline_s}" if self.deadline_s else "")
            + (f", retry={self.retry!r}" if self.retry is not None else "")
            + ")"
        )


def _spec_flatten(s: SelectionSpec):
    return (s.fn,), s.static_key


def _spec_unflatten(aux, children):
    budget, optimizer, stop_zero, stop_neg, use_kernel, deadline_s, retry = aux
    obj = object.__new__(SelectionSpec)
    object.__setattr__(obj, "fn", children[0])
    object.__setattr__(obj, "budget", budget)
    object.__setattr__(obj, "optimizer", optimizer)
    object.__setattr__(obj, "stop_if_zero", stop_zero)
    object.__setattr__(obj, "stop_if_negative", stop_neg)
    object.__setattr__(obj, "use_kernel", use_kernel)
    object.__setattr__(obj, "deadline_s", deadline_s)
    object.__setattr__(obj, "retry", retry)
    return obj


jax.tree_util.register_pytree_node(SelectionSpec, _spec_flatten, _spec_unflatten)


# ---------------------------------------------------------------------------
# solve(): the one front door
# ---------------------------------------------------------------------------

_MODES = ("sequential", "batched", "sharded", "served", "async")


def solve(
    spec: SelectionSpec | Sequence[SelectionSpec],
    *,
    mode: str | None = None,
    mesh=None,
    batch_axis: str = "batch",
    data_axis: str = "data",
    server=None,
):
    """Solve one spec, or a batch of specs, through one execution route.

    Args:
      spec: a :class:`SelectionSpec` (returns one
        :class:`~repro.core.optimizers.greedy.GreedyResult`) or a sequence of
        them (returns a list in the same order).
      mode: ``"sequential"`` (default for one spec; a Python loop for
        several), ``"batched"`` (default for several specs: one vmap-ed wave
        — the specs must agree on family, shapes, optimizer and stop rules;
        heterogeneous workloads belong in ``"served"``), ``"sharded"``
        (batched over a 2-D ``mesh``), ``"served"`` (heterogeneous specs
        coalesced into padded waves by a
        :class:`~repro.launch.serve.SelectionServer`), or ``"async"``
        (submitted to an :class:`~repro.launch.async_serve.AsyncSelectionServer`
        and awaited — the futures route, driven synchronously).
      mesh: a 2-D jax Mesh for the sharded route (passing one with
        mode unset/batched implies ``"sharded"``; served/async servers built
        here also shard over it).
      server: an existing ``SelectionServer`` (served) or
        ``AsyncSelectionServer`` (async) to route through; one is built — and
        torn down — internally when omitted.

    Every route returns results bit-identical to the sequential one (ids,
    gains, and ``n_evals`` — engines count logical evaluations, so bucket
    padding does not leak into a served request's count);
    ``tests/test_spec.py`` pins this, including on a real 2x2 device mesh.
    """
    single = isinstance(spec, SelectionSpec)
    specs = [spec] if single else list(spec)
    for i, s in enumerate(specs):
        if not isinstance(s, SelectionSpec):
            raise TypeError(
                f"solve() takes SelectionSpec objects; item {i} is "
                f"{type(s).__name__!r}"
            )
    if mode is None:
        mode = "sequential" if single and mesh is None else "batched"
    if mode == "batched" and mesh is not None:
        mode = "sharded"
    if mode not in _MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {list(_MODES)}")
    if not specs:
        return []

    if mode == "sequential":
        results = [_run_sequential(s) for s in specs]
    elif mode in ("batched", "sharded"):
        if mode == "sharded" and mesh is None:
            raise ValueError('mode="sharded" needs a 2-D mesh= (batch x data)')
        results = _run_batched(
            specs, mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
        )
    elif mode == "served":
        results = _run_served(
            specs, server, mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
        )
    else:  # async
        results = _run_async(
            specs, server, mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
        )
    return results[0] if single else results


def _run_sequential(spec: SelectionSpec) -> GreedyResult:
    defn = resolve_optimizer(spec.optimizer.name)
    return defn.run(
        spec.resolved_fn(),
        spec.budget,
        spec.stop_if_zero,
        spec.stop_if_negative,
        **spec.optimizer.params,
    )


def _check_uniform(specs: Sequence[SelectionSpec], what: str) -> None:
    head = specs[0]
    for s in specs[1:]:
        if (
            s.optimizer != head.optimizer
            or s.stop_if_zero != head.stop_if_zero
            or s.stop_if_negative != head.stop_if_negative
        ):
            raise ValueError(
                f"mode={what!r} runs one wave, so every spec must share the "
                "optimizer spec and stop rules; mixed workloads belong in "
                'mode="served" (the coalescer groups them into waves)'
            )


def _run_batched(specs, *, mesh, batch_axis, data_axis) -> list[GreedyResult]:
    from repro.core.optimizers.batched import BatchedEngine

    _check_uniform(specs, "sharded" if mesh is not None else "batched")
    head = specs[0]
    engine = BatchedEngine(
        [s.resolved_fn() for s in specs],
        mesh=mesh,
        batch_axis=batch_axis,
        data_axis=data_axis,
    )
    return engine.run(
        [s.budget for s in specs],
        head.optimizer,
        stop_if_zero=head.stop_if_zero,
        stop_if_negative=head.stop_if_negative,
    )


def _run_served(specs, server, *, mesh, batch_axis, data_axis):
    from repro.launch.serve import SelectionServer

    if server is None:
        server = SelectionServer(
            mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
        )
    # select() (not a bare flush) so responses to requests the caller
    # enqueued earlier on their own server are re-held for THEIR next
    # flush() instead of being dropped here
    return [resp.result for resp in server.select(specs)]


def _run_async(specs, server, *, mesh, batch_axis, data_axis):
    from repro.launch.async_serve import AsyncSelectionServer

    owned = server is None
    if owned:
        server = AsyncSelectionServer(
            mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
        )
    try:
        futures = [server.submit(s) for s in specs]
        server.flush_now()
        return [f.result().result for f in futures]
    finally:
        if owned:
            server.close()


# ---------------------------------------------------------------------------
# Built-in optimizer registrations
# ---------------------------------------------------------------------------
# The batched/sharded hooks import lazily: batched.py and distributed.py both
# import THIS module for OptimizerSpec/resolve_optimizer, so the engine side
# must not be a module-level dependency here.

def _naive_run(fn, budget, stop_zero, stop_neg):
    return naive_greedy(fn, budget, stop_zero, stop_neg)


def _naive_batched(stacked, max_budget, budgets, valid, stop_zero, stop_neg):
    from repro.core.optimizers.batched import _batched_naive

    return _batched_naive(stacked, max_budget, budgets, valid, stop_zero, stop_neg)


def _naive_sharded(
    rule, parts, budgets, valid, max_budget, mesh, batch_axes, col_axes,
    stop_zero, stop_neg,
):
    from repro.core.optimizers.distributed import sharded_batched_greedy

    return sharded_batched_greedy(
        rule,
        parts,
        budgets,
        valid,
        max_budget=max_budget,
        mesh=mesh,
        batch_axes=batch_axes,
        col_axes=col_axes,
        stop_if_zero=stop_zero,
        stop_if_negative=stop_neg,
    )


def _lazy_run(fn, budget, stop_zero, stop_neg, *, screen_k):
    return lazy_greedy(fn, budget, screen_k, stop_zero, stop_neg)


def _lazy_batched(
    stacked, max_budget, budgets, valid, stop_zero, stop_neg, *, screen_k
):
    from repro.core.optimizers.batched import _batched_lazy

    return _batched_lazy(
        stacked, max_budget, budgets, valid, screen_k, stop_zero, stop_neg
    )


def _lazy_sharded(
    rule, parts, budgets, valid, max_budget, mesh, batch_axes, col_axes,
    stop_zero, stop_neg, *, screen_k,
):
    from repro.core.optimizers.distributed import sharded_batched_lazy

    return sharded_batched_lazy(
        rule,
        parts,
        budgets,
        valid,
        max_budget=max_budget,
        mesh=mesh,
        batch_axes=batch_axes,
        col_axes=col_axes,
        screen_k=screen_k,
        stop_if_zero=stop_zero,
        stop_if_negative=stop_neg,
    )


def _stochastic_run(
    fn, budget, stop_zero, stop_neg, *, seed, epsilon, sample_size
):
    return stochastic_greedy(
        fn,
        budget,
        jax.random.PRNGKey(seed),
        epsilon,
        sample_size,
        stop_zero,
        stop_neg,
    )


def _ltl_run(
    fn, budget, stop_zero, stop_neg, *, seed, epsilon, sample_size, screen_k
):
    return lazier_than_lazy_greedy(
        fn,
        budget,
        jax.random.PRNGKey(seed),
        epsilon,
        sample_size,
        screen_k,
        stop_zero,
        stop_neg,
    )


_SCREEN_K = Param(8, _int_min(1), "lazy screen width (doubling levels)")
_SAMPLING = {
    "seed": Param(0, _int_min(0), "PRNG seed for the per-step subsample"),
    "epsilon": Param(0.01, _unit_float, "approximation slack in (0, 1]"),
    "sample_size": Param(
        None, _opt_int_min(1), "per-step subsample size (None: from epsilon)"
    ),
}

register_optimizer(
    "NaiveGreedy",
    _naive_run,
    batched_run=_naive_batched,
    sharded_run=_naive_sharded,
)
register_optimizer(
    "LazyGreedy",
    _lazy_run,
    params={"screen_k": _SCREEN_K},
    batched_run=_lazy_batched,
    sharded_run=_lazy_sharded,
)
register_optimizer("StochasticGreedy", _stochastic_run, params=dict(_SAMPLING))
register_optimizer(
    "LazierThanLazyGreedy",
    _ltl_run,
    params={**_SAMPLING, "screen_k": _SCREEN_K},
)

# The streaming optimizers (SieveStreaming / ThresholdGreedy) register
# themselves on import; importing here makes them part of the registry the
# moment the spec module is usable.  Safe against the circular import:
# every name above is already bound when this executes, and streaming.py
# only imports names from this module (never batched.py at module level).
from repro.core.optimizers import streaming as _streaming  # noqa: E402,F401
