"""Pluggable gain-sweep backends for the greedy optimizers.

The per-step full sweep — marginal gains for *every* candidate — is where
greedy submodular maximization spends its time (paper §5, Table 3; apricot
reports the same).  This module decouples *which implementation computes the
sweep* from *which optimizer consumes it*:

- :class:`GainBackend` is the protocol: ``full_sweep(fn, state) -> (n,)``.
- Each :class:`~repro.core.functions.base.SetFunction` may advertise a fused
  implementation by overriding ``gain_backend()`` (e.g. the Pallas kernels
  behind FacilityLocation / GraphCut / FeatureBased).
- :func:`register_gain_backend` lets callers plug in a backend for a function
  class from the outside (profilers, alternative accelerators) without
  touching the function's code; registry entries win over ``gain_backend()``.
- Optimizers call :func:`full_sweep`, which resolves at trace time (backend
  choice rides on static meta fields, so it is jit/vmap-transparent) and
  falls back to the function's plain ``gains()`` XLA path.

Partial sweeps (``gains_at``) stay on the function: they are gather-shaped,
not kernel-shaped.

Shard-local reuse contract (distributed batched serving): backends must be
pure functions of the ``fn`` pytree they are handed — no hidden global-shape
assumptions — because ``optimizers/distributed.py`` applies them to
*candidate-sliced local instances* inside shard_map + vmap.  A backend that
honors this serves single queries, vmap-ed waves, and per-shard sweeps from
the one implementation (the Pallas FL/FB sweeps do; GraphCut's stateless
full-matrix sweep reads the global diagonal, so its shard rule uses the
memoized form instead — see ``GCShardRule``).
"""
from __future__ import annotations

from typing import Callable, Optional, Protocol, runtime_checkable

import jax


@runtime_checkable
class GainBackend(Protocol):
    """A fused full-sweep implementation for one function family."""

    name: str

    def full_sweep(self, fn, state) -> jax.Array:
        """Marginal gains f(j | A) for every ground element j, shape (n,)."""
        ...


class XlaSweep:
    """Default backend: the function's own vectorized ``gains()``."""

    name = "xla"

    def full_sweep(self, fn, state) -> jax.Array:
        return fn.gains(state)


_XLA = XlaSweep()

# class -> factory(fn) -> backend | None; external plug-in point
_REGISTRY: dict[type, Callable[[object], Optional[GainBackend]]] = {}


def register_gain_backend(
    cls: type, factory: Callable[[object], Optional[GainBackend]]
) -> None:
    """Plug a backend factory in for ``cls`` (and subclasses).  The factory
    receives the function instance and may return None to decline."""
    _REGISTRY[cls] = factory


def resolve_backend(fn) -> GainBackend:
    """The backend serving ``fn``'s full sweeps: registry entry, else the
    function's own ``gain_backend()``, else the XLA fallback."""
    for klass in type(fn).__mro__:
        factory = _REGISTRY.get(klass)
        if factory is not None:
            backend = factory(fn)
            if backend is not None:
                return backend
    hook = getattr(fn, "gain_backend", None)
    if callable(hook):
        backend = hook()
        if backend is not None:
            return backend
    return _XLA


def full_sweep(fn, state) -> jax.Array:
    """Marginal gains for all candidates, routed through the resolved backend."""
    return resolve_backend(fn).full_sweep(fn, state)


def backend_name(fn) -> str:
    """Name of the backend serving ``fn``'s full sweeps ("xla", "pallas-fl",
    ...).  Serving uses this to report which implementation answered a wave;
    the README's function x backend matrix is generated from the same hook."""
    return getattr(resolve_backend(fn), "name", "xla")
