"""Pluggable gain-sweep backends for the greedy optimizers.

The per-step full sweep — marginal gains for *every* candidate — is where
greedy submodular maximization spends its time (paper §5, Table 3; apricot
reports the same).  This module decouples *which implementation computes the
sweep* from *which optimizer consumes it*:

- :class:`GainBackend` is the protocol: ``full_sweep(fn, state) -> (n,)``
  plus the optional ``partial_sweep(fn, state, idx) -> (k,)`` gathered form.
- Each :class:`~repro.core.functions.base.SetFunction` may advertise a fused
  implementation by overriding ``gain_backend()`` (e.g. the Pallas kernels
  behind FacilityLocation / GraphCut / FeatureBased).
- :func:`register_gain_backend` lets callers plug in a backend for a function
  class from the outside (profilers, alternative accelerators) without
  touching the function's code; registry entries win over ``gain_backend()``.
- Optimizers call :func:`full_sweep` / :func:`partial_sweep`, which resolve
  at trace time (backend choice rides on static meta fields, so it is
  jit/vmap-transparent) and fall back to the function's plain ``gains()`` /
  ``gains_at()`` XLA paths.

Partial sweeps are the contract behind the bucketed lazy engines
(``optimizers/greedy.py`` / ``optimizers/batched.py``): each lazy step
re-evaluates only the top-K stalest upper bounds through ONE gathered
``partial_sweep`` call, so per-step work is O(K * stat) instead of
O(n * stat).  Every family has a jnp reference implementation (its
``gains_at``); the Pallas families additionally expose fused gather-sweep
kernels (``kernels/*_gains.py`` masked-subset entry points) wired through
their backend's ``partial_sweep``.

Shard-local reuse contract (distributed batched serving): backends must be
pure functions of the ``fn`` pytree they are handed — no hidden global-shape
assumptions — because ``optimizers/distributed.py`` applies them to
*candidate-sliced local instances* inside shard_map + vmap.  A backend that
honors this serves single queries, vmap-ed waves, and per-shard sweeps (full
AND gathered) from the one implementation (the Pallas FL/FB sweeps do;
GraphCut's stateless full-matrix sweep reads the global diagonal, so its
shard rule uses the memoized form instead — see ``GCShardRule``).

Backend *choice* is also pluggable: functions built with ``use_kernel=None``
defer to :func:`choose_backend`, a trace-time decision table over
(ground-set size, budget, device) — an explicit True/False flag always wins.
"""
from __future__ import annotations

from typing import Callable, Optional, Protocol, runtime_checkable

import jax


@runtime_checkable
class GainBackend(Protocol):
    """A fused sweep implementation for one function family."""

    name: str

    def full_sweep(self, fn, state) -> jax.Array:
        """Marginal gains f(j | A) for every ground element j, shape (n,)."""
        ...

    # Optional protocol extension (resolved via getattr, so plain full-sweep
    # backends keep working):
    #
    # def partial_sweep(self, fn, state, idx) -> jax.Array:
    #     """Gains only for the gathered candidate subset ``idx`` (k,)."""


class XlaSweep:
    """Default backend: the function's own vectorized ``gains()``/``gains_at``."""

    name = "xla"

    def full_sweep(self, fn, state) -> jax.Array:
        return fn.gains(state)

    def partial_sweep(self, fn, state, idx) -> jax.Array:
        return fn.gains_at(state, idx)


_XLA = XlaSweep()

# class -> factory(fn) -> backend | None; external plug-in point
_REGISTRY: dict[type, Callable[[object], Optional[GainBackend]]] = {}


def register_gain_backend(
    cls: type, factory: Callable[[object], Optional[GainBackend]]
) -> None:
    """Plug a backend factory in for ``cls`` (and subclasses).  The factory
    receives the function instance and may return None to decline."""
    _REGISTRY[cls] = factory


def resolve_backend(fn) -> GainBackend:
    """The backend serving ``fn``'s sweeps: registry entry, else the
    function's own ``gain_backend()``, else the XLA fallback.

    This is also the "kernel" fault-injection boundary
    (``launch/faults.py``): when a fused (non-XLA) backend resolves, an
    armed FaultPlan addressing ``site="kernel"`` may raise here — the
    host-side resolution the serving stack performs before every dispatch,
    so injected kernel failures are deterministic and hit the same
    retry / breaker / Pallas->XLA fallback path a real kernel failure
    would."""
    backend = None
    for klass in type(fn).__mro__:
        factory = _REGISTRY.get(klass)
        if factory is not None:
            backend = factory(fn)
            if backend is not None:
                break
    if backend is None:
        hook = getattr(fn, "gain_backend", None)
        if callable(hook):
            backend = hook()
    if backend is None:
        backend = _XLA
    name = getattr(backend, "name", "xla")
    if name != "xla":
        from repro.launch import faults

        faults.check("kernel", family=type(fn).__name__, backend=name)
    return backend


def full_sweep(fn, state) -> jax.Array:
    """Marginal gains for all candidates, routed through the resolved backend."""
    return resolve_backend(fn).full_sweep(fn, state)


def partial_sweep(fn, state, idx) -> jax.Array:
    """Marginal gains for the gathered candidate subset ``idx`` only.

    Routed through the resolved backend's ``partial_sweep`` when it has one
    (the fused gather-sweep Pallas kernels), else the function's ``gains_at``
    jnp reference path.  Shape follows ``idx``; entries must be valid
    candidate indices (the kernel entry points additionally treat idx < 0 as
    padding and return NEG_INF there)."""
    backend = resolve_backend(fn)
    impl = getattr(backend, "partial_sweep", None)
    if impl is None:
        return fn.gains_at(state, idx)
    return impl(fn, state, idx)


def backend_name(fn) -> str:
    """Name of the backend serving ``fn``'s full sweeps ("xla", "pallas-fl",
    ...).  Serving uses this to report which implementation answered a wave;
    the README's function x backend matrix is generated from the same hook."""
    return getattr(resolve_backend(fn), "name", "xla")


# ---------------------------------------------------------------------------
# Trace-time backend choice for use_kernel=None ("auto").
# ---------------------------------------------------------------------------

# Below this ground-set size the fused kernels lose to plain XLA: the sweep
# fits in cache and kernel launch / grid overhead dominates (interpret-mode
# CPU numbers in benchmarks/; compile-mode TPU validation is a ROADMAP item).
KERNEL_MIN_N = 4096

# Matrix-free sweeps recompute similarity from feature tiles, so the fused
# kernels start paying off earlier: the XLA alternative is a scan that
# re-materializes every (n_rows, TILE) block through HBM, not a
# cache-resident dense sweep.
MF_KERNEL_MIN_N = 1024

# A stateless O(n^2)-streamed sweep (GraphCut / Disparity style) recomputes
# the full matrix every step; past this many selection steps the memoized
# O(n)-per-step XLA form wins even on TPU.  NOTE: the built-in gain_backend()
# hooks resolve with budget=None — a function object does not know the budget
# it will be maximized under — so this leg only fires for callers that do
# know it: registry factories plugged in via register_gain_backend, or
# schedulers resolving a (fn, budget) pair before dispatch.
KERNEL_MAX_BUDGET_FRACTION = 0.25


def choose_backend(
    n: int,
    budget: int | None = None,
    device: str | None = None,
    matrix_free: bool = False,
) -> str:
    """Decision table: "kernel" or "xla" for a function built with
    ``use_kernel=None``.

    - non-TPU devices (CPU interpret mode, GPU) -> "xla": the Pallas sweeps
      only pay off compiled on TPU;
    - small ground sets (n < KERNEL_MIN_N, or MF_KERNEL_MIN_N for
      ``matrix_free`` sweeps, which have no cache-resident XLA alternative)
      -> "xla": launch overhead dominates;
    - very large budgets relative to n -> "xla": the stateless streamed
      kernels recompute O(n^2) per step, so long greedy loops favor the
      memoized XLA path (pass budget=None for memoized-state kernels).

    Static inputs only — the choice is resolved at trace time and is part of
    the jit cache key via the function's meta fields.
    """
    device = device if device is not None else jax.default_backend()
    if device != "tpu":
        return "xla"
    if n < (MF_KERNEL_MIN_N if matrix_free else KERNEL_MIN_N):
        return "xla"
    if budget is not None and budget > KERNEL_MAX_BUDGET_FRACTION * n:
        return "xla"
    return "kernel"


def kernel_enabled(
    use_kernel: bool | None,
    n: int,
    budget: int | None = None,
    matrix_free: bool = False,
) -> bool:
    """Resolve a family's ``use_kernel`` flag: an explicit True/False always
    wins; None defers to :func:`choose_backend` (manual flag beats heuristic).
    """
    if use_kernel is None:
        return choose_backend(n, budget, matrix_free=matrix_free) == "kernel"
    return bool(use_kernel)
