"""Faithful Minoux accelerated-greedy (paper §5.3.2) on the host.

This is the literal priority-queue algorithm the paper's C++ engine runs —
kept as the reference implementation for the evaluation-count comparison in
``benchmarks/optimizers_bench.py`` (the hardware-independent reproduction of
Table 2; see DESIGN §8.1).  The production path is the jit'd bound-screened
variant in greedy.py.
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np


def host_lazy_greedy(
    fn,
    budget: int,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
):
    """Returns (order, gains, n_evals)."""
    state = fn.init_state()
    ub = np.asarray(jax.device_get(fn.gains(state)), np.float64)
    n_evals = int(ub.shape[0])
    # max-heap of (-upper_bound, index, fresh_at_size)
    heap = [(-ub[i], i, 0) for i in range(ub.shape[0])]
    heapq.heapify(heap)
    order, gains = [], []
    while len(order) < budget and heap:
        neg_ub, j, fresh_at = heapq.heappop(heap)
        if fresh_at == len(order):
            g = -neg_ub  # bound is exact for the current set
        else:
            g = float(fn.gains_at(state, jnp.asarray([j]))[0])
            n_evals += 1
            # push back unless it still tops the heap
            if heap and -heap[0][0] > g + 1e-12:
                heapq.heappush(heap, (-g, j, len(order)))
                continue
        if (stop_if_zero and g <= 0.0) or (stop_if_negative and g < 0.0):
            break
        state = fn.update(state, jnp.asarray(j))
        order.append(j)
        gains.append(g)
    return order, gains, n_evals
