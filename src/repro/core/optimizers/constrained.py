"""Problem 2 (Submodular Cover) and constrained greedy variants (paper §2).

cover_greedy:    min |X| (or cost) s.t. f(X) >= c        [Wolsey '82]
knapsack_greedy: max f(X) s.t. sum cost <= b             [Sviridenko '04,
                 cost-ratio rule + best-feasible-singleton safeguard]
matroid_greedy:  max f(X) s.t. X independent in a partition matroid
                 [Fisher/Nemhauser/Wolsey '78 — 1/2 guarantee]

The declarative side — :class:`Knapsack` and :class:`PartitionMatroid` —
are hashable frozen dataclasses, so a constraint rides an
:class:`~repro.core.optimizers.spec.OptimizerSpec` as static metadata (jit
cache keys, wave-group keys).  The streaming optimizers
(``optimizers/streaming.py``) consume them through the trace-time
``streaming_state`` / ``streaming_feasible`` / ``streaming_add`` helpers,
so constrained streaming is a spec flag, not a forked accept rule.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.common import NEG_INF, pytree_dataclass
from repro.core.optimizers.backends import full_sweep
from repro.core.optimizers.greedy import GreedyResult, _tree_where


# ---------------------------------------------------------------------------
# Declarative constraints (static spec metadata)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Knapsack:
    """``sum(costs[j] for j in X) <= budget`` — item costs must be positive.

    ``costs`` is indexed by ground-set position; hashable (tuples only), so
    it can be an OptimizerSpec hyperparameter / jit static argument.
    """

    costs: tuple
    budget: float

    def __post_init__(self):
        costs = tuple(float(c) for c in self.costs)
        if not costs:
            raise ValueError("Knapsack needs at least one item cost")
        if any(c <= 0 for c in costs):
            raise ValueError("Knapsack costs must all be positive")
        budget = float(self.budget)
        if budget <= 0:
            raise ValueError(f"Knapsack budget must be positive, got {budget}")
        object.__setattr__(self, "costs", costs)
        object.__setattr__(self, "budget", budget)


@dataclasses.dataclass(frozen=True)
class PartitionMatroid:
    """At most ``caps[p]`` picks from each part: ``labels[j]`` names item
    j's part, ``caps`` the per-part capacities.  Hashable static metadata,
    like :class:`Knapsack`."""

    labels: tuple
    caps: tuple

    def __post_init__(self):
        labels = tuple(int(p) for p in self.labels)
        caps = tuple(int(c) for c in self.caps)
        if not caps:
            raise ValueError("PartitionMatroid needs at least one part cap")
        if any(c < 0 for c in caps):
            raise ValueError("PartitionMatroid caps must be >= 0")
        if labels and not all(0 <= p < len(caps) for p in labels):
            raise ValueError(
                f"PartitionMatroid labels must index caps (0..{len(caps) - 1})"
            )
        object.__setattr__(self, "labels", labels)
        object.__setattr__(self, "caps", caps)


def as_constraint(v):
    """Validate an optimizer-spec ``constraint`` value (None passes through).

    The converter behind the streaming optimizers' ``constraint``
    hyperparameter — anything else raises ``TypeError`` naming the accepted
    forms."""
    if v is None or isinstance(v, (Knapsack, PartitionMatroid)):
        return v
    raise TypeError(
        "constraint must be None, a Knapsack, or a PartitionMatroid "
        f"(repro.core.optimizers.constrained); got {type(v).__name__!r}"
    )


# -- trace-time accept-rule hooks (constraint is static, so these dispatch
#    in Python and lower to nothing when constraint is None) ----------------

def streaming_state(constraint, width: int):
    """Per-selector feasibility state, ``width`` independent selectors
    (sieves): spent cost for a knapsack, per-part counts for a matroid, a
    zero-size placeholder when unconstrained."""
    if isinstance(constraint, PartitionMatroid):
        return jnp.zeros((width, len(constraint.caps)), jnp.int32)
    return jnp.zeros((width,), jnp.float32)


def streaming_feasible(constraint, cstate, j):
    """(width,) bool: may element ``j`` join each selector right now?

    ``j`` may exceed ``len(costs)`` on a padded wave — the gather clamps and
    the caller's validity mask keeps padded arrivals out anyway."""
    if constraint is None:
        return jnp.ones(cstate.shape[:1], bool)
    if isinstance(constraint, Knapsack):
        costs = jnp.asarray(constraint.costs, jnp.float32)
        return cstate + costs[j] <= constraint.budget
    labels = jnp.asarray(constraint.labels, jnp.int32)
    caps = jnp.asarray(constraint.caps, jnp.int32)
    lab = labels[j]
    return cstate[:, lab] < caps[lab]


def streaming_add(constraint, cstate, j, accept):
    """Charge element ``j`` to the selectors where ``accept`` is True."""
    if constraint is None:
        return cstate
    if isinstance(constraint, Knapsack):
        costs = jnp.asarray(constraint.costs, jnp.float32)
        return cstate + jnp.where(accept, costs[j], 0.0)
    labels = jnp.asarray(constraint.labels, jnp.int32)
    lab = labels[j]
    return cstate.at[:, lab].add(accept.astype(jnp.int32))


@partial(jax.jit, static_argnums=(2,))
def cover_greedy(fn, coverage: jax.Array, max_steps: int, costs=None) -> GreedyResult:
    """Greedily add the max gain-per-cost element until f(X) >= coverage."""
    n = fn.n
    costs_arr = jnp.ones((n,), jnp.float32) if costs is None else jnp.asarray(costs)
    state = fn.init_state()

    def body(i, carry):
        state, selected, order, gains, value, done = carry
        g = jnp.where(selected, NEG_INF, full_sweep(fn, state))
        ratio = g / costs_arr
        j = jnp.argmax(ratio)
        gj = g[j]
        stop = done | (value >= coverage) | (gj <= 0.0)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        value = value + jnp.where(take, gj, 0.0)
        return state, selected, order, gains, value, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.zeros((max_steps,), jnp.float32),
        jnp.zeros(()),
        jnp.zeros((), bool),
    )
    state, selected, order, gains, value, _ = jax.lax.fori_loop(
        0, max_steps, body, carry
    )
    return GreedyResult(
        order=order, gains=gains, n_evals=jnp.asarray(max_steps * n, jnp.int32),
        value=value,
    )


@partial(jax.jit, static_argnums=(2,))
def knapsack_greedy(fn, budget: jax.Array, max_steps: int, costs=None) -> GreedyResult:
    """Cost-ratio greedy under a knapsack budget sum(cost) <= b."""
    n = fn.n
    costs_arr = jnp.ones((n,), jnp.float32) if costs is None else jnp.asarray(costs)
    state = fn.init_state()

    def body(i, carry):
        state, selected, spent, order, gains, done = carry
        g = full_sweep(fn, state)
        feasible = (~selected) & (spent + costs_arr <= budget)
        ratio = jnp.where(feasible, g / costs_arr, NEG_INF)
        j = jnp.argmax(ratio)
        gj = g[j]
        stop = done | (~feasible[j]) | (gj <= 0.0)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        spent = spent + jnp.where(take, costs_arr[j], 0.0)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        return state, selected, spent, order, gains, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.zeros(()),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.zeros((max_steps,), jnp.float32),
        jnp.zeros((), bool),
    )
    state, selected, spent, order, gains, _ = jax.lax.fori_loop(
        0, max_steps, body, carry
    )
    return GreedyResult(
        order=order, gains=gains, n_evals=jnp.asarray(max_steps * n, jnp.int32),
        value=gains.sum(),
    )


@partial(jax.jit, static_argnums=(1, 2))
def matroid_greedy(
    fn, constraint: PartitionMatroid, max_steps: int
) -> GreedyResult:
    """Greedy under a partition matroid: each step adds the max-gain element
    whose part still has capacity (1/2-approximate for monotone f
    [Fisher/Nemhauser/Wolsey '78]).  ``constraint`` is static — it rides the
    jit cache key like an OptimizerSpec would."""
    n = fn.n
    labels = jnp.asarray(constraint.labels, jnp.int32)
    caps = jnp.asarray(constraint.caps, jnp.int32)
    state = fn.init_state()

    def body(i, carry):
        state, selected, counts, order, gains, done = carry
        g = full_sweep(fn, state)
        feasible = (~selected) & (counts[labels] < caps[labels])
        g = jnp.where(feasible, g, NEG_INF)
        j = jnp.argmax(g)
        gj = g[j]
        stop = done | (~feasible[j]) | (gj <= 0.0)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        counts = counts.at[labels[j]].add(take.astype(jnp.int32))
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        return state, selected, counts, order, gains, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.zeros((len(constraint.caps),), jnp.int32),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.zeros((max_steps,), jnp.float32),
        jnp.zeros((), bool),
    )
    state, selected, counts, order, gains, _ = jax.lax.fori_loop(
        0, max_steps, body, carry
    )
    return GreedyResult(
        order=order, gains=gains, n_evals=jnp.asarray(max_steps * n, jnp.int32),
        value=gains.sum(),
    )
