"""Problem 2 (Submodular Cover) and knapsack-constrained greedy (paper §2).

cover_greedy:    min |X| (or cost) s.t. f(X) >= c        [Wolsey '82]
knapsack_greedy: max f(X) s.t. sum cost <= b             [Sviridenko '04,
                 cost-ratio rule + best-feasible-singleton safeguard]
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.common import NEG_INF, pytree_dataclass
from repro.core.optimizers.backends import full_sweep
from repro.core.optimizers.greedy import GreedyResult, _tree_where


@partial(jax.jit, static_argnums=(2,))
def cover_greedy(fn, coverage: jax.Array, max_steps: int, costs=None) -> GreedyResult:
    """Greedily add the max gain-per-cost element until f(X) >= coverage."""
    n = fn.n
    costs_arr = jnp.ones((n,), jnp.float32) if costs is None else jnp.asarray(costs)
    state = fn.init_state()

    def body(i, carry):
        state, selected, order, gains, value, done = carry
        g = jnp.where(selected, NEG_INF, full_sweep(fn, state))
        ratio = g / costs_arr
        j = jnp.argmax(ratio)
        gj = g[j]
        stop = done | (value >= coverage) | (gj <= 0.0)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        value = value + jnp.where(take, gj, 0.0)
        return state, selected, order, gains, value, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.zeros((max_steps,), jnp.float32),
        jnp.zeros(()),
        jnp.zeros((), bool),
    )
    state, selected, order, gains, value, _ = jax.lax.fori_loop(
        0, max_steps, body, carry
    )
    return GreedyResult(
        order=order, gains=gains, n_evals=jnp.asarray(max_steps * n, jnp.int32),
        value=value,
    )


@partial(jax.jit, static_argnums=(2,))
def knapsack_greedy(fn, budget: jax.Array, max_steps: int, costs=None) -> GreedyResult:
    """Cost-ratio greedy under a knapsack budget sum(cost) <= b."""
    n = fn.n
    costs_arr = jnp.ones((n,), jnp.float32) if costs is None else jnp.asarray(costs)
    state = fn.init_state()

    def body(i, carry):
        state, selected, spent, order, gains, done = carry
        g = full_sweep(fn, state)
        feasible = (~selected) & (spent + costs_arr <= budget)
        ratio = jnp.where(feasible, g / costs_arr, NEG_INF)
        j = jnp.argmax(ratio)
        gj = g[j]
        stop = done | (~feasible[j]) | (gj <= 0.0)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        spent = spent + jnp.where(take, costs_arr[j], 0.0)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        return state, selected, spent, order, gains, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.zeros(()),
        jnp.full((max_steps,), -1, jnp.int32),
        jnp.zeros((max_steps,), jnp.float32),
        jnp.zeros((), bool),
    )
    state, selected, spent, order, gains, _ = jax.lax.fori_loop(
        0, max_steps, body, carry
    )
    return GreedyResult(
        order=order, gains=gains, n_evals=jnp.asarray(max_steps * n, jnp.int32),
        value=gains.sum(),
    )
