"""Single-pass streaming maximizers (ROADMAP item 2: online selection).

Two registry optimizers for candidate streams, both jit-compiled and both
riding the normal ``SelectionSpec`` / ``solve()`` front door:

- **SieveStreaming** [Badanidiyuru et al. '14]: one pass over the arrival
  order, a geometric ladder of thresholds v = (1+eps)^i maintained over the
  running max-singleton estimate m (m <= v <= 2*budget*m), one sieve per
  live threshold.  An arrival e joins sieve S_v when |S_v| < k and
  f(e | S_v) >= (v/2 - f(S_v)) / (k - |S_v|); the best sieve wins.  For
  monotone submodular f this guarantees f >= (1/2 - eps) * OPT —
  ``tests/test_streaming.py`` property-checks the bound against offline
  greedy for every monotone servable family.

- **ThresholdGreedy** [Badanidiyuru & Vondrak '14, buffered]: arrivals are
  buffered into chunks of ``buffer_size``; each chunk first raises the
  running max-singleton estimate d, then is swept by a fixed descending
  ladder tau = d*(1-eps)^l (down to eps*d/n), accepting any element whose
  gain clears the current rung.  Multi-pass over the buffer, still one pass
  over the stream.

Implementation notes (the serving bit-identity contract):

- Every gain goes through the pluggable :func:`partial_sweep` backend, so
  matrix-free sources (``FacilityLocationMF`` over features / k-NN) stream
  without ever materializing an n x n kernel.
- The ladder is realized as a STATIC ring of L slots (L from ``max_budget``
  and eps); each slot carries the sieve for rung i = lo + ((s - lo) mod L).
  Rungs that fall out of the live window [lo, hi] are reset in place.  The
  winning sieve ties break on the RUNG (lowest wins), never the slot index
  — a served wave runs at a bucketed ``max_budget`` whose L differs from
  the sequential run's, so slot layout is not stable but rung identity is.
- ``n_evals`` counts logical oracle calls: 1 singleton probe plus one gain
  per LIVE rung per valid arrival — independent of L, padding, and batch
  shape, so served responses report sequential counts exactly.
- Padded arrivals (``valid`` False) update nothing and cost nothing, and
  the optional ``seed`` shuffle orders items by per-index
  ``jax.random.fold_in`` keys with invalid slots sorted last — the relative
  order of real items is identical at any padded n.
- Constraints (``optimizers/constrained.py``'s :class:`Knapsack` /
  :class:`PartitionMatroid`) gate the accept rule through the trace-time
  ``streaming_feasible`` / ``streaming_add`` hooks; ``constraint=None``
  lowers to nothing.

Both optimizers register with ``mesh_replicated=True``: they are sequential
in the arrival pass (no collective sharded engine), so a served wave on a
device mesh replicates the batched program and keeps on-mesh == off-mesh
bit-identity.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.common import NEG_INF
from repro.core.optimizers.backends import partial_sweep
from repro.core.optimizers.constrained import (
    as_constraint,
    streaming_add,
    streaming_feasible,
    streaming_state,
)
from repro.core.optimizers.greedy import (
    GreedyResult,
    _should_stop,
    _tree_where,
    _where_rows,
)
from repro.core.optimizers.spec import (
    Param,
    _int_min,
    _opt_int_min,
    register_optimizer,
)

__all__ = ["sieve_streaming", "threshold_greedy"]

_INT_BIG = jnp.int32(2**31 - 1)
_RUNG_UNSET = jnp.int32(-(2**31) + 1)


def _ladder_eps(v) -> float:
    f = float(v)
    if not 0.0 < f < 1.0:
        raise ValueError(f"must be a float in (0, 1), got {v!r}")
    return f


def _arrival_order(valid, seed):
    """(n,) arrival permutation: valid items first, invalid last.

    ``seed=None`` keeps index order; an int seed shuffles by per-index
    ``fold_in`` uniforms (ties by index), so the relative order of the
    valid items does not depend on how far the instance was padded.
    """
    n = valid.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    if seed is None:
        primary = jnp.where(valid, 0.0, 2.0)
    else:
        key = jax.random.PRNGKey(seed)
        u = jax.vmap(lambda j: jax.random.uniform(jax.random.fold_in(key, j)))(
            iota
        )
        primary = jnp.where(valid, u, 2.0)
    _, order = jax.lax.sort((primary, iota), dimension=-1, num_keys=2)
    return order


# ---------------------------------------------------------------------------
# SieveStreaming
# ---------------------------------------------------------------------------

def _sieve_slots(max_budget: int, epsilon: float) -> int:
    """Static ring size: one more than the widest possible live window
    [ceil(log_{1+eps} m), floor(log_{1+eps} 2km)], so the rung -> slot
    assignment (rung mod L) is injective over the window."""
    return int(math.floor(math.log(2.0 * max_budget) / math.log1p(epsilon))) + 2


def _sieve_one(
    fn,
    budget_i,
    valid,
    *,
    max_budget: int,
    L: int,
    stop_zero: bool,
    stop_neg: bool,
    epsilon: float,
    seed,
    constraint,
) -> GreedyResult:
    n = fn.n
    log_step = math.log1p(epsilon)
    state0 = fn.init_state()
    states_init = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), state0
    )
    arrival = _arrival_order(valid, seed)
    slots = jnp.arange(L, dtype=jnp.int32)
    kf = budget_i.astype(jnp.float32)

    def window(m):
        """Live rung window [lo, hi] for the current max-singleton m."""
        safe = jnp.maximum(m, jnp.float32(1e-30))
        lo = jnp.ceil(jnp.log(safe) / log_step).astype(jnp.int32)
        hi = jnp.floor(jnp.log(2.0 * kf * safe) / log_step).astype(jnp.int32)
        return lo, hi

    def body(t, carry):
        (m, rungs, states, sizes, values, cstate, orders, gains, evals) = carry
        j = arrival[t]
        av = valid[j]
        # singleton probe: updates m BEFORE this element is offered to sieves
        g0 = partial_sweep(fn, state0, j[None])[0]
        m_new = jnp.where(av, jnp.maximum(m, g0), m)
        lo, hi = window(m_new)
        has = m_new > 0.0
        rung_s = lo + jnp.mod(slots - lo, L)
        live = has & (rung_s <= hi)
        # slots whose rung assignment moved are reset in place (their old
        # sieve belonged to a rung that left the window)
        changed = rung_s != rungs
        states = _where_rows(changed, states_init, states)
        sizes = jnp.where(changed, 0, sizes)
        values = jnp.where(changed, 0.0, values)
        cstate = _where_rows(changed, jnp.zeros_like(cstate), cstate)
        orders = jnp.where(changed[:, None], -1, orders)
        gains = jnp.where(changed[:, None], 0.0, gains)

        g = jax.vmap(lambda st: partial_sweep(fn, st, j[None])[0])(states)
        v = jnp.exp(rung_s.astype(jnp.float32) * jnp.float32(log_step))
        tau = (v / 2.0 - values) / jnp.maximum(kf - sizes, 1.0)
        accept = (
            av
            & live
            & (sizes < budget_i)
            & streaming_feasible(constraint, cstate, j)
            & ~_should_stop(g, stop_zero, stop_neg)
            & (g >= tau)
        )
        new_states = jax.vmap(lambda st: fn.update(st, j))(states)
        states = _where_rows(accept, new_states, states)
        pos = jnp.minimum(sizes, max_budget - 1)
        orders = orders.at[slots, pos].set(
            jnp.where(accept, j, orders[slots, pos])
        )
        gains = gains.at[slots, pos].set(jnp.where(accept, g, gains[slots, pos]))
        values = values + jnp.where(accept, g, 0.0)
        sizes = sizes + accept.astype(jnp.int32)
        cstate = streaming_add(constraint, cstate, j, accept)
        # logical cost: 1 singleton + one gain per live rung, valid arrivals
        # only — a function of the window, never of L / padding / batching
        evals = evals + jnp.where(av, 1 + jnp.sum(live, dtype=jnp.int32), 0)
        return (m_new, rung_s, states, sizes, values, cstate,
                orders, gains, evals)

    carry = (
        jnp.zeros((), jnp.float32),
        jnp.full((L,), _RUNG_UNSET, jnp.int32),
        states_init,
        jnp.zeros((L,), jnp.int32),
        jnp.zeros((L,), jnp.float32),
        streaming_state(constraint, L),
        jnp.full((L, max_budget), -1, jnp.int32),
        jnp.zeros((L, max_budget), jnp.float32),
        jnp.zeros((), jnp.int32),
    )
    m, rungs, states, sizes, values, cstate, orders, gains, evals = (
        jax.lax.fori_loop(0, n, body, carry)
    )
    # best sieve among the final live window, exact-value ties broken by the
    # LOWEST rung (slot layout depends on the max_budget bucket; rungs don't)
    lo, hi = window(m)
    live = (m > 0.0) & (rungs >= lo) & (rungs <= hi)
    masked = jnp.where(live, values, NEG_INF)
    best = jnp.max(masked)
    key = jnp.where(live & (masked == best), rungs, _INT_BIG)
    s = jnp.argmin(key)
    any_live = jnp.any(live)
    order = jnp.where(any_live, orders[s], jnp.full((max_budget,), -1, jnp.int32))
    gain = jnp.where(any_live, gains[s], jnp.zeros((max_budget,), jnp.float32))
    value = jnp.where(any_live, values[s], 0.0)
    return GreedyResult(order=order, gains=gain, n_evals=evals, value=value)


@partial(
    jax.jit,
    static_argnums=(1, 4, 5),
    static_argnames=("epsilon", "seed", "constraint"),
)
def _sieve_batched(
    fns, max_budget, budgets, valid, stop_zero, stop_neg, *, epsilon, seed,
    constraint,
):
    L = _sieve_slots(max_budget, epsilon)
    return jax.vmap(
        lambda fn, b, v: _sieve_one(
            fn,
            b,
            v,
            max_budget=max_budget,
            L=L,
            stop_zero=stop_zero,
            stop_neg=stop_neg,
            epsilon=epsilon,
            seed=seed,
            constraint=constraint,
        )
    )(fns, budgets, valid)


def sieve_streaming(
    fn,
    budget: int,
    epsilon: float = 0.1,
    seed: int | None = None,
    constraint=None,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """One-pass sieve-streaming selection; (1/2 - eps)-approximate for
    monotone submodular ``fn``.  The B = 1 instantiation of the batched
    engine program, so served waves are bit-identical by construction."""
    fns = jax.tree.map(lambda x: jnp.asarray(x)[None], fn)
    res = _sieve_batched(
        fns,
        int(budget),
        jnp.full((1,), int(budget), jnp.int32),
        jnp.ones((1, fn.n), bool),
        stop_if_zero,
        stop_if_negative,
        epsilon=_ladder_eps(epsilon),
        seed=seed,
        constraint=as_constraint(constraint),
    )
    return GreedyResult(
        order=res.order[0],
        gains=res.gains[0],
        n_evals=res.n_evals[0],
        value=res.value[0],
    )


# ---------------------------------------------------------------------------
# ThresholdGreedy (buffered chunks, fixed descending ladder)
# ---------------------------------------------------------------------------

def _threshold_levels(n: int, epsilon: float) -> int:
    """Static ladder length covering tau from d down to (eps/n) * d; levels
    past the TRUE (unpadded) floor are gated off dynamically, so padding
    only ever adds inactive rungs."""
    return int(
        math.ceil(math.log(max(n, 2) / epsilon) / -math.log1p(-epsilon))
    ) + 1


def _threshold_one(
    fn,
    budget_i,
    valid,
    *,
    max_budget: int,
    bs: int,
    stop_zero: bool,
    stop_neg: bool,
    epsilon: float,
    seed,
    constraint,
) -> GreedyResult:
    n = fn.n
    C = -(-n // bs)  # chunks of the arrival stream
    L = _threshold_levels(n, epsilon)
    log_decay = math.log1p(-epsilon)
    state0 = fn.init_state()
    arrival = _arrival_order(valid, seed)
    true_n = jnp.maximum(jnp.sum(valid, dtype=jnp.int32), 1).astype(jnp.float32)

    # one flattened pass: chunk c -> level 0 is the singleton (d-raising)
    # sweep over the chunk, levels 1..L sweep it against tau = d*(1-eps)^(l-1)
    steps = C * (L + 1) * bs

    def body(t, carry):
        state, selected, d, size, cstate, order, gains, evals = carry
        c = t // ((L + 1) * bs)
        r = t % ((L + 1) * bs)
        l = r // bs
        p = r % bs
        pos = c * bs + p
        j = arrival[jnp.minimum(pos, n - 1)]
        av = (pos < n) & valid[j]
        dpass = l == 0
        g0 = partial_sweep(fn, state0, j[None])[0]
        d_new = jnp.where(av & dpass, jnp.maximum(d, g0), d)
        tau = d_new * jnp.exp((l - 1).astype(jnp.float32) * jnp.float32(log_decay))
        # the ladder floor uses the TRUE stream length, so the set of active
        # rungs is identical however far the instance was padded
        active = (~dpass) & (d_new > 0.0) & (tau >= epsilon * d_new / true_n)
        visit = av & active & ~selected[j] & (size < budget_i)
        g = partial_sweep(fn, state, j[None])[0]
        accept = (
            visit
            & streaming_feasible(constraint, cstate, j)[0]
            & ~_should_stop(g, stop_zero, stop_neg)
            & (g >= tau)
        )
        new_state = fn.update(state, j)
        state = _tree_where(accept, new_state, state)
        selected = selected.at[j].set(selected[j] | accept)
        q = jnp.minimum(size, max_budget - 1)
        order = order.at[q].set(jnp.where(accept, j, order[q]))
        gains = gains.at[q].set(jnp.where(accept, g, gains[q]))
        size = size + accept.astype(jnp.int32)
        cstate = streaming_add(constraint, cstate, j, accept[None])
        evals = evals + (av & dpass).astype(jnp.int32) + visit.astype(jnp.int32)
        return state, selected, d_new, size, cstate, order, gains, evals

    carry = (
        state0,
        jnp.zeros((n,), bool),
        jnp.zeros((), jnp.float32),
        jnp.zeros((), jnp.int32),
        streaming_state(constraint, 1),
        jnp.full((max_budget,), -1, jnp.int32),
        jnp.zeros((max_budget,), jnp.float32),
        jnp.zeros((), jnp.int32),
    )
    state, selected, d, size, cstate, order, gains, evals = jax.lax.fori_loop(
        0, steps, body, carry
    )
    return GreedyResult(
        order=order, gains=gains, n_evals=evals, value=gains.sum()
    )


@partial(
    jax.jit,
    static_argnums=(1, 4, 5),
    static_argnames=("epsilon", "buffer_size", "seed", "constraint"),
)
def _threshold_batched(
    fns, max_budget, budgets, valid, stop_zero, stop_neg, *, epsilon,
    buffer_size, seed, constraint,
):
    return jax.vmap(
        lambda fn, b, v: _threshold_one(
            fn,
            b,
            v,
            max_budget=max_budget,
            bs=buffer_size,
            stop_zero=stop_zero,
            stop_neg=stop_neg,
            epsilon=epsilon,
            seed=seed,
            constraint=constraint,
        )
    )(fns, budgets, valid)


def threshold_greedy(
    fn,
    budget: int,
    epsilon: float = 0.1,
    buffer_size: int = 64,
    seed: int | None = None,
    constraint=None,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Buffered threshold greedy over the arrival stream (fixed descending
    eps-ladder per chunk)."""
    fns = jax.tree.map(lambda x: jnp.asarray(x)[None], fn)
    res = _threshold_batched(
        fns,
        int(budget),
        jnp.full((1,), int(budget), jnp.int32),
        jnp.ones((1, fn.n), bool),
        stop_if_zero,
        stop_if_negative,
        epsilon=_ladder_eps(epsilon),
        buffer_size=int(buffer_size),
        seed=seed,
        constraint=as_constraint(constraint),
    )
    return GreedyResult(
        order=res.order[0],
        gains=res.gains[0],
        n_evals=res.n_evals[0],
        value=res.value[0],
    )


# ---------------------------------------------------------------------------
# Registry hooks
# ---------------------------------------------------------------------------

def _sieve_run(fn, budget, stop_zero, stop_neg, *, epsilon, seed, constraint):
    return sieve_streaming(
        fn, budget, epsilon, seed, constraint, stop_zero, stop_neg
    )


def _threshold_run(
    fn, budget, stop_zero, stop_neg, *, epsilon, buffer_size, seed, constraint
):
    return threshold_greedy(
        fn, budget, epsilon, buffer_size, seed, constraint, stop_zero, stop_neg
    )


_STREAM_PARAMS = {
    "epsilon": Param(0.1, _ladder_eps, "threshold-ladder slack in (0, 1)"),
    "seed": Param(
        None, _opt_int_min(0), "arrival-order shuffle seed (None: index order)"
    ),
    "constraint": Param(
        None, as_constraint,
        "optional Knapsack / PartitionMatroid accept-rule constraint",
    ),
}

register_optimizer(
    "SieveStreaming",
    _sieve_run,
    params=dict(_STREAM_PARAMS),
    batched_run=_sieve_batched,
    mesh_replicated=True,
)
register_optimizer(
    "ThresholdGreedy",
    _threshold_run,
    params={
        **_STREAM_PARAMS,
        "buffer_size": Param(
            64, _int_min(1), "buffered chunk length for the ladder passes"
        ),
    },
    batched_run=_threshold_batched,
    mesh_replicated=True,
)
