"""submodlib-style ``maximize`` entry point (paper §7).

    greedy_list = maximize(fn, budget=10, optimizer="NaiveGreedy")

returns [(index, gain), ...] exactly like submodlib's f.maximize().
"""
from __future__ import annotations

import jax

from repro.core.optimizers.greedy import (
    GreedyResult,
    lazier_than_lazy_greedy,
    lazy_greedy,
    naive_greedy,
    stochastic_greedy,
)

_OPTIMIZERS = {
    "NaiveGreedy": lambda fn, b, kw: naive_greedy(
        fn, b, kw.get("stopIfZeroGain", True), kw.get("stopIfNegativeGain", True)
    ),
    "LazyGreedy": lambda fn, b, kw: lazy_greedy(
        fn,
        b,
        kw.get("screen_k", 8),
        kw.get("stopIfZeroGain", True),
        kw.get("stopIfNegativeGain", True),
    ),
    "StochasticGreedy": lambda fn, b, kw: stochastic_greedy(
        fn,
        b,
        kw.get("key", jax.random.PRNGKey(kw.get("seed", 0))),
        kw.get("epsilon", 0.01),
        kw.get("sample_size", None),
        kw.get("stopIfZeroGain", True),
        kw.get("stopIfNegativeGain", True),
    ),
    "LazierThanLazyGreedy": lambda fn, b, kw: lazier_than_lazy_greedy(
        fn,
        b,
        kw.get("key", jax.random.PRNGKey(kw.get("seed", 0))),
        kw.get("epsilon", 0.01),
        kw.get("sample_size", None),
        kw.get("screen_k", 8),
        kw.get("stopIfZeroGain", True),
        kw.get("stopIfNegativeGain", True),
    ),
}


def maximize(
    fn,
    budget: int,
    optimizer: str = "NaiveGreedy",
    return_result: bool = False,
    **kwargs,
) -> list | GreedyResult:
    if optimizer not in _OPTIMIZERS:
        raise ValueError(
            f"unknown optimizer {optimizer!r}; choose from {sorted(_OPTIMIZERS)}"
        )
    result = _OPTIMIZERS[optimizer](fn, budget, kwargs)
    return result if return_result else result.as_list()
