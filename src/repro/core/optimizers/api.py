"""Deprecated submodlib-style ``maximize`` entry point (paper §7).

    greedy_list = maximize(fn, budget=10, optimizer="NaiveGreedy")

``maximize`` is now a thin shim over the typed front door::

    from repro.core import SelectionSpec, solve
    result = solve(SelectionSpec(fn, 10, "NaiveGreedy"))
    greedy_list = result.as_list()

The shim keeps the bit-identical contract (ids, gains, ``n_evals``) and the
submodlib-style ``[(index, gain), ...]`` return value, but emits a single
``DeprecationWarning`` per call — see docs/api.md for the migration table.
Unlike the old implementation, unknown or misspelled options (e.g.
``stopIfZeroGian``) now raise ``TypeError`` naming the valid set instead of
being silently dropped, and stop-rule defaults resolve against the
per-family table (Disparity* defaults to ``stopIfZeroGain=False``, matching
serving).
"""
from __future__ import annotations

import warnings

from repro.core.optimizers.greedy import GreedyResult
from repro.core.optimizers.spec import SelectionSpec, solve


def _warn_shim(old: str, new: str) -> None:
    """One DeprecationWarning per legacy call (shims never chain, so a
    legacy call emits exactly one)."""
    warnings.warn(
        f"{old} is deprecated; use {new} (see docs/api.md for the migration "
        "table)",
        DeprecationWarning,
        stacklevel=3,
    )


def maximize(
    fn,
    budget: int,
    optimizer: str = "NaiveGreedy",
    return_result: bool = False,
    **kwargs,
) -> list | GreedyResult:
    """Deprecated: delegate to ``solve(SelectionSpec(...))``.

    kwargs are split exactly as the spec constructor does: stop rules go to
    the :class:`SelectionSpec`, everything else is validated as optimizer
    hyperparameters — so ``maximize(fn, 5, stopIfZeroGian=False)`` raises a
    ``TypeError`` naming the valid options instead of silently running under
    the wrong stopping semantics.
    """
    _warn_shim(
        "maximize()", "solve(SelectionSpec(fn, budget, optimizer, ...))"
    )
    spec = SelectionSpec(
        fn,
        budget,
        optimizer,
        stopIfZeroGain=kwargs.pop("stopIfZeroGain", None),
        stopIfNegativeGain=kwargs.pop("stopIfNegativeGain", None),
        **kwargs,
    )
    result = solve(spec)
    return result if return_result else result.as_list()
