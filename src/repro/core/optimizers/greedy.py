"""Greedy maximizers (paper §5.3), jit-compatible.

All optimizers return a :class:`GreedyResult` with a fixed-size ``order``
buffer (-1 padded once stopping criteria fire), the per-step gains, and the
number of marginal-gain evaluations performed (the hardware-independent cost
metric used to reproduce the paper's Table 2 ordering; see DESIGN §8.1).

Tie-breaking matches the paper: the *first* best element is added.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common import NEG_INF, pytree_dataclass
from repro.core.optimizers.backends import full_sweep, partial_sweep


@pytree_dataclass
class GreedyResult:
    order: jax.Array  # (budget,) int32 selected indices, -1 once stopped
    gains: jax.Array  # (budget,) float marginal gains (0 once stopped)
    n_evals: jax.Array  # int32 total marginal-gain evaluations
    value: jax.Array  # f(A) of the returned set (telescoped gains)

    def as_list(self):
        """[(index, gain), ...] like submodlib's maximize() return value."""
        order = jax.device_get(self.order)
        gains = jax.device_get(self.gains)
        return [(int(i), float(g)) for i, g in zip(order, gains) if i >= 0]


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _should_stop(gj, stop_if_zero: bool, stop_if_negative: bool):
    stop = jnp.zeros((), bool)
    if stop_if_zero:
        stop |= gj <= 0.0
    if stop_if_negative:
        stop |= gj < 0.0
    return stop


def _naive_impl(
    fn,
    budget: int,
    stop_if_zero: bool,
    stop_if_negative: bool,
    budget_i=None,
    valid=None,
) -> GreedyResult:
    """Single implementation behind :func:`naive_greedy` AND the batched
    engine: ``budget_i`` (dynamic per-instance budget) and ``valid`` (padding
    mask) are None for the plain single-query path — both are trace-time
    decisions, so the None case lowers to exactly the unmasked program."""
    n = fn.n
    state = fn.init_state()
    # n_evals counts LOGICAL oracle evaluations: a padded instance (served
    # at a bucket size, or riding a batched wave) sweeps the padded width,
    # but only the live candidates are reported — so served == sequential.
    true_n = (
        jnp.asarray(n, jnp.int32)
        if valid is None
        else jnp.sum(valid, dtype=jnp.int32)
    )

    def body(i, carry):
        state, selected, order, gains, evals, done = carry
        blocked = selected if valid is None else selected | ~valid
        g = jnp.where(blocked, NEG_INF, full_sweep(fn, state))
        j = jnp.argmax(g)
        gj = g[j]
        past = jnp.zeros((), bool) if budget_i is None else i >= budget_i
        stop = done | past | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done | past, 0, true_n)
        return state, selected, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
    )
    state, selected, order, gains, evals, _ = jax.lax.fori_loop(0, budget, body, carry)
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())


@partial(jax.jit, static_argnums=(1, 2, 3))
def naive_greedy(
    fn,
    budget: int,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Standard greedy [Nemhauser et al. '78]: full gain sweep per step.

    On TPU the sweep is a single fused pass over the memoized statistics —
    the vectorized adaptation of the paper's per-element loop (DESIGN §2).
    """
    return _naive_impl(fn, budget, stop_if_zero, stop_if_negative)


def _screen_levels(n: int, screen_k: int) -> tuple[tuple[int, int], ...]:
    """Static (lo, hi) slices of the per-step stale-bound sort: cumulative
    screen widths screen_k, 2*screen_k, 4*screen_k, ..., capped at n.

    The last level always reaches n, so every step resolves within the
    schedule and each candidate is evaluated at most once per step — the
    per-step eval cost is <= n (a naive sweep) with equality only on a full
    bound-screen miss."""
    levels, lo = [], 0
    hi = min(max(int(screen_k), 1), n)
    while True:
        levels.append((lo, hi))
        if hi >= n:
            return tuple(levels)
        lo, hi = hi, min(2 * hi, n)


def _where_rows(pred, a, b):
    """Per-row select on (B, ...) pytrees: ``pred`` is (B,)."""
    return jax.tree.map(
        lambda x, y: jnp.where(pred.reshape(pred.shape + (1,) * (x.ndim - 1)), x, y),
        a,
        b,
    )


def _lazy_bucketed_impl(
    fns,
    max_budget: int,
    budgets,
    valid,
    screen_k: int,
    stop_if_zero: bool,
    stop_if_negative: bool,
) -> GreedyResult:
    """Bucketed lazy greedy over a B-stacked batch — the ONE implementation
    behind sequential :func:`lazy_greedy` (B = 1) and the batched engine's
    LazyGreedy path, so their ids/gains/``n_evals`` agree bit-for-bit by
    construction.

    Per step, candidates are sorted by stale upper bound (descending, ties
    broken by lowest index — exactly ``lax.top_k``'s order) and evaluated in
    doubling *levels* of that order (``_screen_levels``): every wave member
    re-evaluates its top-K stalest bounds through ONE gathered
    ``partial_sweep`` call, and a level only executes if some instance is
    still unresolved — a *scalar* ``lax.cond`` predicate, which is what the
    old vmap-of-``lax.cond`` formulation could not give us (under vmap cond
    lowers to select, so both branches ran and batched LazyGreedy paid the
    full O(B*n) sweep every step; see ROADMAP "Lazy batched engine
    efficiency").  An instance accepts once the best true gain seen beats
    every remaining stale bound; the last level spans all n, so a full miss
    degenerates to exactly one evaluation per candidate (cost n, all bounds
    refreshed) — per-step cost never exceeds the naive sweep.

    The winner is the first-index argmax over evaluated true gains
    (unevaluated entries held at NEG_INF), matching naive_greedy's tie rule.
    ``n_evals`` counts, per instance, the LIVE (non-padded) candidates in
    the levels that instance was still unresolved for (plus the initial
    bound sweep over its live candidates) — instances that accept early
    stop accruing even when the wave digs deeper for others, and a padded
    instance reports the same count it would sequentially.
    """
    B, n = valid.shape
    levels = _screen_levels(n, screen_k)
    rows = jnp.arange(B)
    state0 = jax.vmap(lambda f: f.init_state())(fns)
    ub0 = jax.vmap(full_sweep)(fns, state0)

    def body(i, carry):
        state, selected, ub, order, gains, evals, done = carry
        blocked = selected | ~valid
        ubm = jnp.where(blocked, NEG_INF, ub)
        # descending stale-bound order, ties by lowest index (lax.sort over
        # (-value, index) — identical on one device and in the sharded
        # engine's gathered merge, unlike raw top_k whose cross-shard merge
        # would reorder equal bounds)
        iota = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))
        neg_sv, si = jax.lax.sort((-ubm, iota), dimension=-1, num_keys=2)
        sv = -neg_sv

        def level(lo, hi, c):
            resolved, geval, evaluated, cost = c
            idx = jax.lax.slice_in_dim(si, lo, hi, axis=1)  # (B, hi-lo)
            g = jax.vmap(partial_sweep)(fns, state, idx)
            blk = jnp.take_along_axis(blocked, idx, axis=1)
            g = jnp.where(blk, NEG_INF, g.astype(geval.dtype))
            live = ~resolved  # instances this level still works for
            geval = jnp.where(
                live[:, None], geval.at[rows[:, None], idx].set(g), geval
            )
            evaluated = jnp.where(
                live[:, None], evaluated.at[rows[:, None], idx].set(True), evaluated
            )
            # logical evaluations only: a padded instance's level still
            # spans hi - lo sorted slots, but the pad candidates in it are
            # not oracle calls — count the live ones so served == sequential
            w_valid = jnp.sum(
                jnp.take_along_axis(valid, idx, axis=1), axis=1, dtype=jnp.int32
            )
            cost = cost + jnp.where(live, w_valid, 0)
            best = jnp.max(geval, axis=1)
            rest = (
                sv[:, hi] if hi < n else jnp.full((B,), NEG_INF, sv.dtype)
            )  # largest stale bound not yet evaluated
            resolved = resolved | (best >= rest - 1e-6)
            return resolved, geval, evaluated, cost

        c = (
            jnp.zeros((B,), bool),
            jnp.full((B, n), NEG_INF, ubm.dtype),
            jnp.zeros((B, n), bool),
            jnp.zeros((B,), jnp.int32),
        )
        for lo, hi in levels:
            # scalar predicate: the whole wave skips the level once everyone
            # has resolved (level 0 always runs)
            c = jax.lax.cond(
                jnp.all(c[0]),
                lambda c: c,
                partial(level, lo, hi),
                c,
            )
        _, geval, evaluated, cost = c

        j = jnp.argmax(geval, axis=1)  # first-index tie-break, like naive
        gj = jnp.take_along_axis(geval, j[:, None], axis=1)[:, 0]
        past = i >= budgets
        stop = done | past | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = jax.vmap(lambda f, s, jj: f.update(s, jj))(fns, state, j)
        state = _where_rows(take, new_state, state)
        selected = selected.at[rows, j].set(selected[rows, j] | take)
        ub = jnp.where(evaluated, geval, ubm)  # refreshed bounds stay valid
        order = order.at[:, i].set(jnp.where(take, j, -1))
        gains = gains.at[:, i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done | past, 0, cost)
        return state, selected, ub, order, gains, evals, stop

    carry = (
        state0,
        jnp.zeros((B, n), bool),
        ub0,
        jnp.full((B, max_budget), -1, jnp.int32),
        jnp.zeros((B, max_budget), jnp.float32),
        jnp.sum(valid, axis=1, dtype=jnp.int32),  # the initial bound sweep
        jnp.zeros((B,), bool),
    )
    out = jax.lax.fori_loop(0, max_budget, body, carry)
    state, selected, ub, order, gains, evals, _ = out
    return GreedyResult(
        order=order, gains=gains, n_evals=evals, value=gains.sum(axis=1)
    )


@partial(jax.jit, static_argnums=(1, 2, 3, 4))
def lazy_greedy(
    fn,
    budget: int,
    screen_k: int = 8,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Bound-screened greedy — the TPU adaptation of Minoux's accelerated
    (lazy) greedy [paper §5.3.2; DESIGN §2].

    A dense vector ``ub`` of stale upper bounds replaces the priority queue
    (valid by submodularity: gains only shrink as A grows).  Each step
    re-evaluates true gains for the candidates with the largest stale bounds
    in doubling screen levels (screen_k, 2*screen_k, ... — see
    ``_lazy_bucketed_impl``), accepting as soon as the best evaluated gain
    beats every remaining stale bound; a full miss degenerates to one
    evaluation per candidate, so a step never costs more than a naive sweep.
    Identical output to naive_greedy, far fewer gain evaluations on peaked
    gain distributions.

    This is literally the B = 1 instantiation of the bucketed batched lazy
    engine, which is what makes batched/served LazyGreedy waves bit-identical
    to this function (ids, gains AND ``n_evals``).
    """
    fns = jax.tree.map(lambda x: jnp.asarray(x)[None], fn)
    res = _lazy_bucketed_impl(
        fns,
        budget,
        jnp.full((1,), budget, jnp.int32),
        jnp.ones((1, fn.n), bool),
        screen_k,
        stop_if_zero,
        stop_if_negative,
    )
    return GreedyResult(
        order=res.order[0],
        gains=res.gains[0],
        n_evals=res.n_evals[0],
        value=res.value[0],
    )


def _sample_unselected(key, selected, size):
    """Uniform random ``size``-subset of unselected indices (Gumbel top-k)."""
    z = jax.random.uniform(key, selected.shape)
    z = jnp.where(selected, -1.0, z)
    return jax.lax.top_k(z, size)[1]


@partial(jax.jit, static_argnums=(1, 3, 4, 5, 6))
def stochastic_greedy(
    fn,
    budget: int,
    key: jax.Array | None = None,
    epsilon: float = 0.01,
    sample_size: int | None = None,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Stochastic greedy [Mirzasoleiman et al. '15] (paper §5.3.3): each step
    evaluates gains on a random (n/b) log(1/eps) subsample of the remaining
    ground set. Linear total running time independent of budget, 1-1/e-eps in
    expectation."""
    import math

    n = fn.n
    key = jax.random.PRNGKey(0) if key is None else key
    s = sample_size or max(1, min(n, int(math.ceil(n / budget * math.log(1.0 / epsilon)))))
    state = fn.init_state()

    def body(i, carry):
        state, selected, order, gains, evals, done = carry
        subkey = jax.random.fold_in(key, i)
        cand = _sample_unselected(subkey, selected, s)
        g = partial_sweep(fn, state, cand)
        # guard: sampled entries that are actually selected (when fewer than s
        # unselected remain) are masked out
        g = jnp.where(selected[cand], NEG_INF, g)
        bi = jnp.argmax(g)
        j, gj = cand[bi], g[bi]
        stop = done | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done, 0, s)
        return state, selected, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
    )
    state, selected, order, gains, evals, _ = jax.lax.fori_loop(0, budget, body, carry)
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())


@partial(jax.jit, static_argnums=(1, 3, 4, 5, 6, 7))
def lazier_than_lazy_greedy(
    fn,
    budget: int,
    key: jax.Array | None = None,
    epsilon: float = 0.01,
    sample_size: int | None = None,
    screen_k: int = 8,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Random sampling + lazy evaluation [Mirzasoleiman et al. '15]
    (paper §5.3.4): per step, draw the stochastic-greedy subsample, then apply
    the stale-bound screen *within the sample* — evaluating true gains only on
    the sample's top-``screen_k`` bounds, falling back to the whole sample on
    a bound violation."""
    import math

    n = fn.n
    key = jax.random.PRNGKey(0) if key is None else key
    s = sample_size or max(1, min(n, int(math.ceil(n / budget * math.log(1.0 / epsilon)))))
    k = min(screen_k, s)
    state = fn.init_state()
    ub0 = full_sweep(fn, state)

    def body(i, carry):
        state, selected, ub, order, gains, evals, done = carry
        subkey = jax.random.fold_in(key, i)
        cand = _sample_unselected(subkey, selected, s)  # (s,)
        ub_cand = jnp.where(selected[cand], NEG_INF, ub[cand])
        top_vals, top_pos = jax.lax.top_k(ub_cand, k)
        top_idx = cand[top_pos]
        true_g = partial_sweep(fn, state, top_idx)
        true_g = jnp.where(selected[top_idx], NEG_INF, true_g)
        bi = jnp.argmax(true_g)
        j_screen, g_screen = top_idx[bi], true_g[bi]
        rest_max = jnp.max(ub_cand.at[top_pos].set(NEG_INF))
        ok = g_screen >= rest_max - 1e-6

        def sample_sweep(_):
            g = partial_sweep(fn, state, cand)
            g = jnp.where(selected[cand], NEG_INF, g)
            b = jnp.argmax(g)
            return cand[b], g[b], g, jnp.int32(s)

        def accept(_):
            # refresh bounds only for the screened entries; the rest keep
            # their stale (still valid) bounds
            g = ub_cand.at[top_pos].set(true_g)
            return j_screen, g_screen, g, jnp.int32(k)

        j, gj, upd_g, cost = jax.lax.cond(ok, accept, sample_sweep, None)
        ub = ub.at[cand].set(upd_g)
        stop = done | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done, 0, cost)
        return state, selected, ub, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        ub0,
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.asarray(n, jnp.int32),
        jnp.zeros((), bool),
    )
    out = jax.lax.fori_loop(0, budget, body, carry)
    state, selected, ub, order, gains, evals, _ = out
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())
