"""Greedy maximizers (paper §5.3), jit-compatible.

All optimizers return a :class:`GreedyResult` with a fixed-size ``order``
buffer (-1 padded once stopping criteria fire), the per-step gains, and the
number of marginal-gain evaluations performed (the hardware-independent cost
metric used to reproduce the paper's Table 2 ordering; see DESIGN §8.1).

Tie-breaking matches the paper: the *first* best element is added.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common import NEG_INF, pytree_dataclass
from repro.core.optimizers.backends import full_sweep


@pytree_dataclass
class GreedyResult:
    order: jax.Array  # (budget,) int32 selected indices, -1 once stopped
    gains: jax.Array  # (budget,) float marginal gains (0 once stopped)
    n_evals: jax.Array  # int32 total marginal-gain evaluations
    value: jax.Array  # f(A) of the returned set (telescoped gains)

    def as_list(self):
        """[(index, gain), ...] like submodlib's maximize() return value."""
        order = jax.device_get(self.order)
        gains = jax.device_get(self.gains)
        return [(int(i), float(g)) for i, g in zip(order, gains) if i >= 0]


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _should_stop(gj, stop_if_zero: bool, stop_if_negative: bool):
    stop = jnp.zeros((), bool)
    if stop_if_zero:
        stop |= gj <= 0.0
    if stop_if_negative:
        stop |= gj < 0.0
    return stop


def _naive_impl(
    fn,
    budget: int,
    stop_if_zero: bool,
    stop_if_negative: bool,
    budget_i=None,
    valid=None,
) -> GreedyResult:
    """Single implementation behind :func:`naive_greedy` AND the batched
    engine: ``budget_i`` (dynamic per-instance budget) and ``valid`` (padding
    mask) are None for the plain single-query path — both are trace-time
    decisions, so the None case lowers to exactly the unmasked program."""
    n = fn.n
    state = fn.init_state()

    def body(i, carry):
        state, selected, order, gains, evals, done = carry
        blocked = selected if valid is None else selected | ~valid
        g = jnp.where(blocked, NEG_INF, full_sweep(fn, state))
        j = jnp.argmax(g)
        gj = g[j]
        past = jnp.zeros((), bool) if budget_i is None else i >= budget_i
        stop = done | past | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done | past, 0, n)
        return state, selected, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
    )
    state, selected, order, gains, evals, _ = jax.lax.fori_loop(0, budget, body, carry)
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())


@partial(jax.jit, static_argnums=(1, 2, 3))
def naive_greedy(
    fn,
    budget: int,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Standard greedy [Nemhauser et al. '78]: full gain sweep per step.

    On TPU the sweep is a single fused pass over the memoized statistics —
    the vectorized adaptation of the paper's per-element loop (DESIGN §2).
    """
    return _naive_impl(fn, budget, stop_if_zero, stop_if_negative)


def _lazy_impl(
    fn,
    budget: int,
    screen_k: int,
    stop_if_zero: bool,
    stop_if_negative: bool,
    budget_i=None,
    valid=None,
) -> GreedyResult:
    """Single implementation behind :func:`lazy_greedy` AND the batched
    engine (see :func:`_naive_impl` for the budget_i / valid contract)."""
    n = fn.n
    k = min(screen_k, n)
    state = fn.init_state()
    ub0 = full_sweep(fn, state)

    def body(i, carry):
        state, selected, ub, order, gains, evals, done = carry
        blocked = selected if valid is None else selected | ~valid
        ubm = jnp.where(blocked, NEG_INF, ub)
        top_vals, top_idx = jax.lax.top_k(ubm, k)
        # mask screened gains of blocked entries: when fewer than k eligible
        # candidates remain, top_k spills into blocked indices whose true
        # gain may be positive — without this they could be (re)selected
        true_g = jnp.where(blocked[top_idx], NEG_INF, fn.gains_at(state, top_idx))
        ub2 = ubm.at[top_idx].set(true_g)
        best_i = jnp.argmax(true_g)
        j_screen, g_screen = top_idx[best_i], true_g[best_i]
        rest_max = jnp.max(ub2.at[top_idx].set(NEG_INF))
        ok = g_screen >= rest_max - 1e-6

        def fallback_sweep(_):
            g_all = jnp.where(blocked, NEG_INF, full_sweep(fn, state))
            j = jnp.argmax(g_all)
            return j, g_all[j], g_all, jnp.int32(n)

        def accept(_):
            return j_screen, g_screen, ub2, jnp.int32(k)

        j, gj, ub_new, cost = jax.lax.cond(ok, accept, fallback_sweep, None)
        past = jnp.zeros((), bool) if budget_i is None else i >= budget_i
        stop = done | past | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        blocked = selected if valid is None else selected | ~valid
        ub = jnp.where(blocked, NEG_INF, ub_new)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done | past, 0, cost)
        return state, selected, ub, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        ub0,
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.asarray(n, jnp.int32),  # the initial bound sweep
        jnp.zeros((), bool),
    )
    out = jax.lax.fori_loop(0, budget, body, carry)
    state, selected, ub, order, gains, evals, _ = out
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())


@partial(jax.jit, static_argnums=(1, 2, 3, 4))
def lazy_greedy(
    fn,
    budget: int,
    screen_k: int = 8,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Bound-screened greedy — the TPU adaptation of Minoux's accelerated
    (lazy) greedy [paper §5.3.2; DESIGN §2].

    A dense vector ``ub`` of stale upper bounds replaces the priority queue
    (valid by submodularity: gains only shrink as A grows).  Each step
    re-evaluates the true gain for only the ``screen_k`` candidates with the
    largest stale bounds; the winner is accepted iff it beats every other
    stale bound, otherwise the step falls back to a full sweep (which also
    refreshes all bounds).  Identical output to naive_greedy, far fewer gain
    evaluations on peaked gain distributions.
    """
    return _lazy_impl(fn, budget, screen_k, stop_if_zero, stop_if_negative)


def _sample_unselected(key, selected, size):
    """Uniform random ``size``-subset of unselected indices (Gumbel top-k)."""
    z = jax.random.uniform(key, selected.shape)
    z = jnp.where(selected, -1.0, z)
    return jax.lax.top_k(z, size)[1]


@partial(jax.jit, static_argnums=(1, 3, 4, 5, 6))
def stochastic_greedy(
    fn,
    budget: int,
    key: jax.Array | None = None,
    epsilon: float = 0.01,
    sample_size: int | None = None,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Stochastic greedy [Mirzasoleiman et al. '15] (paper §5.3.3): each step
    evaluates gains on a random (n/b) log(1/eps) subsample of the remaining
    ground set. Linear total running time independent of budget, 1-1/e-eps in
    expectation."""
    import math

    n = fn.n
    key = jax.random.PRNGKey(0) if key is None else key
    s = sample_size or max(1, min(n, int(math.ceil(n / budget * math.log(1.0 / epsilon)))))
    state = fn.init_state()

    def body(i, carry):
        state, selected, order, gains, evals, done = carry
        subkey = jax.random.fold_in(key, i)
        cand = _sample_unselected(subkey, selected, s)
        g = fn.gains_at(state, cand)
        # guard: sampled entries that are actually selected (when fewer than s
        # unselected remain) are masked out
        g = jnp.where(selected[cand], NEG_INF, g)
        bi = jnp.argmax(g)
        j, gj = cand[bi], g[bi]
        stop = done | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done, 0, s)
        return state, selected, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
    )
    state, selected, order, gains, evals, _ = jax.lax.fori_loop(0, budget, body, carry)
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())


@partial(jax.jit, static_argnums=(1, 3, 4, 5, 6, 7))
def lazier_than_lazy_greedy(
    fn,
    budget: int,
    key: jax.Array | None = None,
    epsilon: float = 0.01,
    sample_size: int | None = None,
    screen_k: int = 8,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
) -> GreedyResult:
    """Random sampling + lazy evaluation [Mirzasoleiman et al. '15]
    (paper §5.3.4): per step, draw the stochastic-greedy subsample, then apply
    the stale-bound screen *within the sample* — evaluating true gains only on
    the sample's top-``screen_k`` bounds, falling back to the whole sample on
    a bound violation."""
    import math

    n = fn.n
    key = jax.random.PRNGKey(0) if key is None else key
    s = sample_size or max(1, min(n, int(math.ceil(n / budget * math.log(1.0 / epsilon)))))
    k = min(screen_k, s)
    state = fn.init_state()
    ub0 = full_sweep(fn, state)

    def body(i, carry):
        state, selected, ub, order, gains, evals, done = carry
        subkey = jax.random.fold_in(key, i)
        cand = _sample_unselected(subkey, selected, s)  # (s,)
        ub_cand = jnp.where(selected[cand], NEG_INF, ub[cand])
        top_vals, top_pos = jax.lax.top_k(ub_cand, k)
        top_idx = cand[top_pos]
        true_g = fn.gains_at(state, top_idx)
        true_g = jnp.where(selected[top_idx], NEG_INF, true_g)
        bi = jnp.argmax(true_g)
        j_screen, g_screen = top_idx[bi], true_g[bi]
        rest_max = jnp.max(ub_cand.at[top_pos].set(NEG_INF))
        ok = g_screen >= rest_max - 1e-6

        def sample_sweep(_):
            g = fn.gains_at(state, cand)
            g = jnp.where(selected[cand], NEG_INF, g)
            b = jnp.argmax(g)
            return cand[b], g[b], g, jnp.int32(s)

        def accept(_):
            # refresh bounds only for the screened entries; the rest keep
            # their stale (still valid) bounds
            g = ub_cand.at[top_pos].set(true_g)
            return j_screen, g_screen, g, jnp.int32(k)

        j, gj, upd_g, cost = jax.lax.cond(ok, accept, sample_sweep, None)
        ub = ub.at[cand].set(upd_g)
        stop = done | _should_stop(gj, stop_if_zero, stop_if_negative)
        take = ~stop
        new_state = fn.update(state, j)
        state = _tree_where(take, new_state, state)
        selected = selected.at[j].set(selected[j] | take)
        order = order.at[i].set(jnp.where(take, j, -1))
        gains = gains.at[i].set(jnp.where(take, gj, 0.0))
        evals = evals + jnp.where(done, 0, cost)
        return state, selected, ub, order, gains, evals, stop

    carry = (
        state,
        jnp.zeros((n,), bool),
        ub0,
        jnp.full((budget,), -1, jnp.int32),
        jnp.zeros((budget,), jnp.float32),
        jnp.asarray(n, jnp.int32),
        jnp.zeros((), bool),
    )
    out = jax.lax.fori_loop(0, budget, body, carry)
    state, selected, ub, order, gains, evals, _ = out
    return GreedyResult(order=order, gains=gains, n_evals=evals, value=gains.sum())
