"""Multi-pod distributed partition greedy (DESIGN §2, §5).

The ground set (kernel columns) is sharded over the data-parallel mesh axes
and the represented set (kernel rows) over the model axis.  Each greedy step:

  1. local partial gains      — fused relu-reduction on the resident block
  2. psum over the row axis   — full gains for the local candidate shard
  3. local argmax             — first-index tie-break inside the shard
  4. pmax + pmin(index)       — O(1)-payload global winner election
  5. masked psum of winner's  — one (U_loc,)-sized broadcast to update the
     column over the col axes   memoized curmax statistic

The per-step collective payload is O(U / mesh_rows) + O(1), independent of
the ground-set size — this is what makes billion-item selection feasible
(the paper's engine is single-node).

Works on any mesh: ``col_axes`` may span ("pod", "data") so a 512-chip
2-pod mesh shards a billion-point ground set 32-ways per pod.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import NEG_INF

_INT_MAX = jnp.int32(2**31 - 1)


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map moved out of jax.experimental in newer releases and the
    ``check_rep`` kwarg was renamed ``check_vma``; dispatch on what exists."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def _axis_size(a: str):
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)  # older jax: no lax.axis_size


def _flat_axis_index(axes: Sequence[str]) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def distributed_fl_greedy(
    sim: jax.Array,
    budget: int,
    mesh: jax.sharding.Mesh,
    row_axes: Sequence[str] | None = ("model",),
    col_axes: Sequence[str] = ("data",),
    stop_if_zero: bool = True,
):
    """Facility-Location greedy over a 2-D sharded similarity kernel.

    ``sim`` is the global (U, V) kernel; rows shard over ``row_axes`` (or are
    replicated when None), columns over ``col_axes``.  Returns
    (order, gains): (budget,) global indices and gains, replicated.
    """
    row_axes = tuple(row_axes) if row_axes else ()
    col_axes = tuple(col_axes)
    in_spec = P(row_axes if row_axes else None, col_axes)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(in_spec,),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def run(S_block):
        U_loc, V_loc = S_block.shape
        col_off = _flat_axis_index(col_axes) * V_loc
        curmax = jnp.zeros((U_loc,), S_block.dtype)

        def body(i, carry):
            curmax, selected, order, gains, done = carry
            part = jnp.maximum(S_block - curmax[:, None], 0.0).sum(axis=0)
            g = jax.lax.psum(part, row_axes) if row_axes else part
            g = jnp.where(selected, NEG_INF, g)
            lbi = jnp.argmax(g)
            lbg = g[lbi]
            gbest = jax.lax.pmax(lbg, col_axes)
            cand = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
            winner = jax.lax.pmin(cand, col_axes)  # lowest index wins ties
            stop = done | (stop_if_zero & (gbest <= 0.0))
            take = ~stop
            is_mine = (winner >= col_off) & (winner < col_off + V_loc)
            wl = jnp.clip(winner - col_off, 0, V_loc - 1)
            col = jnp.where(is_mine, S_block[:, wl], 0.0)
            col = jax.lax.psum(col, col_axes)  # broadcast winner column
            curmax = jnp.where(take, jnp.maximum(curmax, col), curmax)
            selected = selected | (take & is_mine & (jnp.arange(V_loc) == wl))
            order = order.at[i].set(jnp.where(take, winner, -1))
            gains = gains.at[i].set(jnp.where(take, gbest, 0.0))
            return curmax, selected, order, gains, stop

        carry = (
            curmax,
            jnp.zeros((V_loc,), bool),
            jnp.full((budget,), -1, jnp.int32),
            jnp.zeros((budget,), jnp.float32),
            jnp.zeros((), bool),
        )
        _, _, order, gains, _ = jax.lax.fori_loop(0, budget, body, carry)
        return order, gains

    return run(sim)


def distributed_stochastic_fl_greedy(
    sim: jax.Array,
    budget: int,
    mesh: jax.sharding.Mesh,
    key: jax.Array,
    sample_per_shard: int = 1024,
    row_axes: Sequence[str] | None = ("model",),
    col_axes: Sequence[str] = ("data",),
):
    """Stochastic-greedy variant of the partition greedy (§Perf-3 hillclimb).

    Each round, every column-shard group samples ``sample_per_shard`` of its
    unselected candidates (same sample within a column group — the PRNG key
    folds in only the round and the column index, so the row-wise partial
    gains stay psum-compatible) and the sweep touches only those columns:
    HBM traffic per round drops from |V_loc| to sample_per_shard columns
    (~64x here) at stochastic-greedy's usual <1% objective cost.

    Also the straggler-mitigation path (DESIGN §6): a shard that misses a
    round only removes its sample from that round's union."""
    row_axes = tuple(row_axes) if row_axes else ()
    col_axes = tuple(col_axes)
    in_spec = P(row_axes if row_axes else None, col_axes)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(in_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def run(S_block, key):
        U_loc, V_loc = S_block.shape
        s = min(sample_per_shard, V_loc)
        col_idx = _flat_axis_index(col_axes)
        col_off = col_idx * V_loc
        curmax = jnp.zeros((U_loc,), S_block.dtype)

        def body(i, carry):
            curmax, selected, order, gains = carry
            subkey = jax.random.fold_in(jax.random.fold_in(key, i), col_idx)
            z = jnp.where(selected, -1.0, jax.random.uniform(subkey, (V_loc,)))
            cand = jax.lax.top_k(z, s)[1]  # (s,) random unselected columns
            cols = S_block[:, cand]  # (U_loc, s)
            part = jnp.maximum(cols - curmax[:, None], 0.0).sum(axis=0)
            g = jax.lax.psum(part, row_axes) if row_axes else part
            g = jnp.where(selected[cand], NEG_INF, g)
            bi = jnp.argmax(g)
            lbg = g[bi]
            lbi = cand[bi]
            gbest = jax.lax.pmax(lbg, col_axes)
            cand_g = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
            winner = jax.lax.pmin(cand_g, col_axes)
            is_mine = (winner >= col_off) & (winner < col_off + V_loc)
            wl = jnp.clip(winner - col_off, 0, V_loc - 1)
            col = jnp.where(is_mine, S_block[:, wl], 0.0)
            col = jax.lax.psum(col, col_axes)
            curmax = jnp.maximum(curmax, col)
            selected = selected | (is_mine & (jnp.arange(V_loc) == wl))
            order = order.at[i].set(winner)
            gains = gains.at[i].set(gbest)
            return curmax, selected, order, gains

        carry = (
            curmax,
            jnp.zeros((V_loc,), bool),
            jnp.full((budget,), -1, jnp.int32),
            jnp.zeros((budget,), jnp.float32),
        )
        _, _, order, gains = jax.lax.fori_loop(0, budget, body, carry)
        return order, gains

    return run(sim, key)


def distributed_flqmi_greedy(
    sim_qv: jax.Array,
    modular: jax.Array,
    budget: int,
    mesh: jax.sharding.Mesh,
    col_axes: Sequence[str] = ("data",),
    eta: float = 1.0,
):
    """FLQMI targeted selection with the query kernel replicated (|Q| small)
    and the ground set column-sharded — the production configuration for
    targeted data selection at pre-training scale."""
    col_axes = tuple(col_axes)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(None, col_axes), P(col_axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def run(Sq_block, mod_block):
        nq, V_loc = Sq_block.shape
        col_off = _flat_axis_index(col_axes) * V_loc
        curmax = jnp.zeros((nq,), Sq_block.dtype)

        def body(i, carry):
            curmax, selected, order, gains = carry
            g = jnp.maximum(Sq_block - curmax[:, None], 0.0).sum(axis=0) + mod_block
            g = jnp.where(selected, NEG_INF, g)
            lbi = jnp.argmax(g)
            lbg = g[lbi]
            gbest = jax.lax.pmax(lbg, col_axes)
            cand = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
            winner = jax.lax.pmin(cand, col_axes)
            is_mine = (winner >= col_off) & (winner < col_off + V_loc)
            wl = jnp.clip(winner - col_off, 0, V_loc - 1)
            col = jnp.where(is_mine, Sq_block[:, wl], 0.0)
            col = jax.lax.psum(col, col_axes)
            curmax = jnp.maximum(curmax, col)
            selected = selected | (is_mine & (jnp.arange(V_loc) == wl))
            order = order.at[i].set(winner)
            gains = gains.at[i].set(gbest)
            return curmax, selected, order, gains

        carry = (
            curmax,
            jnp.zeros((V_loc,), bool),
            jnp.full((budget,), -1, jnp.int32),
            jnp.zeros((budget,), jnp.float32),
        )
        _, _, order, gains = jax.lax.fori_loop(0, budget, body, carry)
        return order, gains

    return run(sim_qv, modular)
