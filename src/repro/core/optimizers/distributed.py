"""Multi-pod distributed partition greedy (DESIGN §2, §5).

Two layers live here:

1. The original per-function partition greedies (``distributed_fl_greedy``
   and friends): the ground set (kernel columns) sharded over the
   data-parallel mesh axes, the represented set (kernel rows) over the model
   axis.
2. The generic **sharded batched engine** (serving tentpole): a B-query wave
   runs with the batch axis sharded over one mesh axis and every instance's
   candidate axis sharded over another — ``jax.vmap`` over the local batch
   slice composed with the shard_map partition-greedy sweep.  Function
   families plug in through :class:`ShardRule` adapters (registry mirrors
   ``backends.register_gain_backend``), and each shard's gain sweep routes
   through ``backends.full_sweep`` / ``backends.partial_sweep`` on a
   candidate-sliced local instance, so fused Pallas sweeps (full and
   gathered-subset) are reused per shard.  Two step programs share the
   adapters: :func:`sharded_batched_greedy` (naive full sweeps + O(1)
   winner election) and :func:`sharded_batched_lazy` (the eval-sparse
   bucketed lazy engine: merged stale-bound prefixes + sharded gathered
   subsets).

For the original partition greedy, each step:

  1. local partial gains      — fused relu-reduction on the resident block
  2. psum over the row axis   — full gains for the local candidate shard
  3. local argmax             — first-index tie-break inside the shard
  4. pmax + pmin(index)       — O(1)-payload global winner election
  5. masked psum of winner's  — one (U_loc,)-sized broadcast to update the
     column over the col axes   memoized curmax statistic

The per-step collective payload is O(U / mesh_rows) + O(1), independent of
the ground-set size — this is what makes billion-item selection feasible
(the paper's engine is single-node).

Works on any mesh: ``col_axes`` may span ("pod", "data") so a 512-chip
2-pod mesh shards a billion-point ground set 32-ways per pod.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common import NEG_INF

_INT_MAX = jnp.int32(2**31 - 1)


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map moved out of jax.experimental in newer releases and the
    ``check_rep`` kwarg was renamed ``check_vma``; dispatch on what exists."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def _axis_size(a: str):
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)  # older jax: no lax.axis_size


def _flat_axis_index(axes: Sequence[str]) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def distributed_fl_greedy(
    sim: jax.Array,
    budget: int,
    mesh: jax.sharding.Mesh,
    row_axes: Sequence[str] | None = ("model",),
    col_axes: Sequence[str] = ("data",),
    stop_if_zero: bool = True,
):
    """Facility-Location greedy over a 2-D sharded similarity kernel.

    ``sim`` is the global (U, V) kernel; rows shard over ``row_axes`` (or are
    replicated when None), columns over ``col_axes``.  Returns
    (order, gains): (budget,) global indices and gains, replicated.
    """
    row_axes = tuple(row_axes) if row_axes else ()
    col_axes = tuple(col_axes)
    in_spec = P(row_axes if row_axes else None, col_axes)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(in_spec,),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def run(S_block):
        U_loc, V_loc = S_block.shape
        col_off = _flat_axis_index(col_axes) * V_loc
        curmax = jnp.zeros((U_loc,), S_block.dtype)

        def body(i, carry):
            curmax, selected, order, gains, done = carry
            part = jnp.maximum(S_block - curmax[:, None], 0.0).sum(axis=0)
            g = jax.lax.psum(part, row_axes) if row_axes else part
            g = jnp.where(selected, NEG_INF, g)
            lbi = jnp.argmax(g)
            lbg = g[lbi]
            gbest = jax.lax.pmax(lbg, col_axes)
            cand = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
            winner = jax.lax.pmin(cand, col_axes)  # lowest index wins ties
            stop = done | (stop_if_zero & (gbest <= 0.0))
            take = ~stop
            is_mine = (winner >= col_off) & (winner < col_off + V_loc)
            wl = jnp.clip(winner - col_off, 0, V_loc - 1)
            col = jnp.where(is_mine, S_block[:, wl], 0.0)
            col = jax.lax.psum(col, col_axes)  # broadcast winner column
            curmax = jnp.where(take, jnp.maximum(curmax, col), curmax)
            selected = selected | (take & is_mine & (jnp.arange(V_loc) == wl))
            order = order.at[i].set(jnp.where(take, winner, -1))
            gains = gains.at[i].set(jnp.where(take, gbest, 0.0))
            return curmax, selected, order, gains, stop

        carry = (
            curmax,
            jnp.zeros((V_loc,), bool),
            jnp.full((budget,), -1, jnp.int32),
            jnp.zeros((budget,), jnp.float32),
            jnp.zeros((), bool),
        )
        _, _, order, gains, _ = jax.lax.fori_loop(0, budget, body, carry)
        return order, gains

    return run(sim)


def distributed_stochastic_fl_greedy(
    sim: jax.Array,
    budget: int,
    mesh: jax.sharding.Mesh,
    key: jax.Array,
    sample_per_shard: int = 1024,
    row_axes: Sequence[str] | None = ("model",),
    col_axes: Sequence[str] = ("data",),
):
    """Stochastic-greedy variant of the partition greedy (§Perf-3 hillclimb).

    Each round, every column-shard group samples ``sample_per_shard`` of its
    unselected candidates (same sample within a column group — the PRNG key
    folds in only the round and the column index, so the row-wise partial
    gains stay psum-compatible) and the sweep touches only those columns:
    HBM traffic per round drops from |V_loc| to sample_per_shard columns
    (~64x here) at stochastic-greedy's usual <1% objective cost.

    Also the straggler-mitigation path (DESIGN §6): a shard that misses a
    round only removes its sample from that round's union."""
    row_axes = tuple(row_axes) if row_axes else ()
    col_axes = tuple(col_axes)
    in_spec = P(row_axes if row_axes else None, col_axes)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(in_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def run(S_block, key):
        U_loc, V_loc = S_block.shape
        s = min(sample_per_shard, V_loc)
        col_idx = _flat_axis_index(col_axes)
        col_off = col_idx * V_loc
        curmax = jnp.zeros((U_loc,), S_block.dtype)

        def body(i, carry):
            curmax, selected, order, gains = carry
            subkey = jax.random.fold_in(jax.random.fold_in(key, i), col_idx)
            z = jnp.where(selected, -1.0, jax.random.uniform(subkey, (V_loc,)))
            cand = jax.lax.top_k(z, s)[1]  # (s,) random unselected columns
            cols = S_block[:, cand]  # (U_loc, s)
            part = jnp.maximum(cols - curmax[:, None], 0.0).sum(axis=0)
            g = jax.lax.psum(part, row_axes) if row_axes else part
            g = jnp.where(selected[cand], NEG_INF, g)
            bi = jnp.argmax(g)
            lbg = g[bi]
            lbi = cand[bi]
            gbest = jax.lax.pmax(lbg, col_axes)
            cand_g = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
            winner = jax.lax.pmin(cand_g, col_axes)
            is_mine = (winner >= col_off) & (winner < col_off + V_loc)
            wl = jnp.clip(winner - col_off, 0, V_loc - 1)
            col = jnp.where(is_mine, S_block[:, wl], 0.0)
            col = jax.lax.psum(col, col_axes)
            curmax = jnp.maximum(curmax, col)
            selected = selected | (is_mine & (jnp.arange(V_loc) == wl))
            order = order.at[i].set(winner)
            gains = gains.at[i].set(gbest)
            return curmax, selected, order, gains

        carry = (
            curmax,
            jnp.zeros((V_loc,), bool),
            jnp.full((budget,), -1, jnp.int32),
            jnp.zeros((budget,), jnp.float32),
        )
        _, _, order, gains = jax.lax.fori_loop(0, budget, body, carry)
        return order, gains

    return run(sim, key)


def distributed_flqmi_greedy(
    sim_qv: jax.Array,
    modular: jax.Array,
    budget: int,
    mesh: jax.sharding.Mesh,
    col_axes: Sequence[str] = ("data",),
    eta: float = 1.0,
):
    """FLQMI targeted selection with the query kernel replicated (|Q| small)
    and the ground set column-sharded — the production configuration for
    targeted data selection at pre-training scale."""
    col_axes = tuple(col_axes)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(None, col_axes), P(col_axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    def run(Sq_block, mod_block):
        nq, V_loc = Sq_block.shape
        col_off = _flat_axis_index(col_axes) * V_loc
        curmax = jnp.zeros((nq,), Sq_block.dtype)

        def body(i, carry):
            curmax, selected, order, gains = carry
            g = jnp.maximum(Sq_block - curmax[:, None], 0.0).sum(axis=0) + mod_block
            g = jnp.where(selected, NEG_INF, g)
            lbi = jnp.argmax(g)
            lbg = g[lbi]
            gbest = jax.lax.pmax(lbg, col_axes)
            cand = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
            winner = jax.lax.pmin(cand, col_axes)
            is_mine = (winner >= col_off) & (winner < col_off + V_loc)
            wl = jnp.clip(winner - col_off, 0, V_loc - 1)
            col = jnp.where(is_mine, Sq_block[:, wl], 0.0)
            col = jax.lax.psum(col, col_axes)
            curmax = jnp.maximum(curmax, col)
            selected = selected | (is_mine & (jnp.arange(V_loc) == wl))
            order = order.at[i].set(winner)
            gains = gains.at[i].set(gbest)
            return curmax, selected, order, gains

        carry = (
            curmax,
            jnp.zeros((V_loc,), bool),
            jnp.full((budget,), -1, jnp.int32),
            jnp.zeros((budget,), jnp.float32),
        )
        _, _, order, gains = jax.lax.fori_loop(0, budget, body, carry)
        return order, gains

    return run(sim_qv, modular)


# ---------------------------------------------------------------------------
# Sharded batched greedy: B queries x sharded ground set on a 2-D mesh.
# ---------------------------------------------------------------------------
#
# A ShardRule describes how one function family's pytree and greedy state
# decompose over the candidate axis, so ONE generic shard_map program serves
# every family.  Per instance (inside jax.vmap over the local batch slice):
#
#   parts  = the family's dynamic arrays, candidate axis sliced to V_loc
#   state  = the memoized statistic; replicated (FL curmax, FB acc) or
#            itself candidate-sharded (GC selsum)
#   sweep  = local marginal gains for the V_loc resident candidates
#   apply  = fold the globally elected winner into the state; at most one
#            O(stat) psum broadcast (the winner's column/row)
#
# Bit-identical contract: rows/features are never split, so each candidate's
# gain is the same float reduction as on one device; the first-index global
# argmax is recovered exactly by local argmax -> pmax(gain) -> pmin(index).

import dataclasses as _dataclasses


class ShardRule:
    """Family adapter for the generic sharded batched greedy.

    Implementations are frozen dataclasses holding only static meta (they are
    hashed into the jit cache key).  Methods run inside shard_map + vmap, so
    ``parts`` / ``state`` are the per-instance local slices.
    """

    def global_parts(self, fn) -> tuple:
        """Dynamic arrays of one instance, in a fixed order."""
        raise NotImplementedError

    def part_specs(self, batch_axes, col_axes) -> tuple:
        """PartitionSpec per part for the B-stacked arrays (batch dim first)."""
        raise NotImplementedError

    def init_state(self, parts):
        """Greedy state for A = {} from the local parts."""
        raise NotImplementedError

    def local_sweep(self, parts, state) -> jax.Array:
        """Marginal gains for the V_loc local candidates, shape (V_loc,)."""
        raise NotImplementedError

    def local_sweep_at(self, parts, state, idx) -> jax.Array:
        """Gains for the LOCAL candidate subset ``idx`` only (the sharded
        ``partial_sweep`` contract, feeding the bucketed lazy engine).

        The default gathers from a full local sweep — correct for every
        rule, but O(V_loc); rules override it with an O(k * stat) gathered
        form (most route through ``backends.partial_sweep`` on the local
        instance, so fused Pallas subset kernels serve per shard)."""
        return self.local_sweep(parts, state)[idx]

    def apply_winner(self, parts, state, take, is_mine, wl, winner, col_axes):
        """State after adding the elected ``winner`` (global index; ``wl`` is
        its local column on the owning shard).  Must be a no-op when ``take``
        is False and identical on every shard afterwards."""
        raise NotImplementedError


@_dataclasses.dataclass(frozen=True)
class FLShardRule(ShardRule):
    """FacilityLocation: columns sharded, rows (represented set) replicated;
    curmax is replicated and updated via a psum broadcast of the winner's
    column — the same O(U) payload as ``distributed_fl_greedy``."""

    use_kernel: bool = False

    def global_parts(self, fn):
        return (fn.sim,)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, None, col_axes),)

    def init_state(self, parts):
        (sim,) = parts
        return jnp.zeros((sim.shape[0],), sim.dtype)

    def _local_fn(self, parts):
        from repro.core.functions.facility_location import FacilityLocation

        (sim,) = parts
        return FacilityLocation(
            sim=sim, n=int(sim.shape[1]), use_kernel=self.use_kernel
        )

    def local_sweep(self, parts, curmax):
        from repro.core.functions.facility_location import FLState
        from repro.core.optimizers.backends import full_sweep

        (sim,) = parts
        return full_sweep(
            self._local_fn(parts), FLState(curmax=curmax, n_rows=int(sim.shape[0]))
        )

    def local_sweep_at(self, parts, curmax, idx):
        from repro.core.functions.facility_location import FLState
        from repro.core.optimizers.backends import partial_sweep

        (sim,) = parts
        return partial_sweep(
            self._local_fn(parts),
            FLState(curmax=curmax, n_rows=int(sim.shape[0])),
            idx,
        )

    def apply_winner(self, parts, curmax, take, is_mine, wl, winner, col_axes):
        (sim,) = parts
        col = jnp.where(is_mine, sim[:, wl], 0.0)
        col = jax.lax.psum(col, col_axes)
        return jnp.where(take, jnp.maximum(curmax, col), curmax)


@_dataclasses.dataclass(frozen=True)
class GCShardRule(ShardRule):
    """GraphCut: ground-kernel ROWS are the candidate axis (each shard keeps
    the full row of its candidates), so selsum shards with the candidates and
    the winner update is collective-free — every shard already holds the
    winner's kernel value against its own candidates.

    The sweep is the memoized O(n)-per-step form (``total - lam * (2 selsum
    + diag)``); GraphCut's fused Pallas sweep is the *stateless* full-matrix
    recompute, a different float reduction than the memoized form, so a
    ``use_kernel=True`` GraphCut could not keep the bit-identical contract
    here — the factory rejects it (single-device serving handles it fine)."""

    def global_parts(self, fn):
        return (fn.sim_ground, fn.total, jnp.diagonal(fn.sim_ground), fn.lam)

    def part_specs(self, batch_axes, col_axes):
        return (
            P(batch_axes, col_axes, None),
            P(batch_axes, col_axes),
            P(batch_axes, col_axes),
            P(batch_axes),
        )

    def init_state(self, parts):
        block, total, diag, lam = parts
        return jnp.zeros((block.shape[0],), block.dtype)

    def local_sweep(self, parts, selsum):
        block, total, diag, lam = parts
        return total - lam * (2.0 * selsum + diag)

    def local_sweep_at(self, parts, selsum, idx):
        block, total, diag, lam = parts
        return total[idx] - lam * (2.0 * selsum[idx] + diag[idx])

    def apply_winner(self, parts, selsum, take, is_mine, wl, winner, col_axes):
        block, total, diag, lam = parts
        return jnp.where(take, selsum + block[:, winner], selsum)


@_dataclasses.dataclass(frozen=True)
class FBShardRule(ShardRule):
    """FeatureBased: feature rows sharded over candidates, the accumulated
    feature mass replicated; the winner's feature row is psum-broadcast."""

    concave: str = "sqrt"
    use_kernel: bool = False

    def global_parts(self, fn):
        return (fn.feats, fn.w)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes, None), P(batch_axes))

    def init_state(self, parts):
        feats, w = parts
        return jnp.zeros((feats.shape[1],), jnp.float32)

    def _local_fn(self, parts):
        from repro.core.functions.feature_based import FeatureBased

        feats, w = parts
        return FeatureBased(
            feats=feats,
            w=w,
            n=int(feats.shape[0]),
            concave=self.concave,
            use_kernel=self.use_kernel,
        )

    def local_sweep(self, parts, acc):
        from repro.core.functions.feature_based import FBState
        from repro.core.optimizers.backends import full_sweep

        return full_sweep(self._local_fn(parts), FBState(acc=acc))

    def local_sweep_at(self, parts, acc, idx):
        from repro.core.functions.feature_based import FBState
        from repro.core.optimizers.backends import partial_sweep

        return partial_sweep(self._local_fn(parts), FBState(acc=acc), idx)

    def apply_winner(self, parts, acc, take, is_mine, wl, winner, col_axes):
        feats, w = parts
        row = jnp.where(is_mine, feats[wl], 0.0)
        row = jax.lax.psum(row, col_axes)
        return jnp.where(take, acc + row, acc)


@_dataclasses.dataclass(frozen=True)
class SCShardRule(ShardRule):
    """SetCover: incidence rows sharded over candidates, the concept axis
    (and the covered indicator) replicated; the winner's incidence row is
    psum-broadcast — the FeatureBased shape over concepts."""

    use_kernel: bool = False

    def global_parts(self, fn):
        return (fn.cover, fn.w)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes, None), P(batch_axes))

    def init_state(self, parts):
        cover, w = parts
        return jnp.zeros((cover.shape[1],), cover.dtype)

    def _local_fn(self, parts):
        from repro.core.functions.set_cover import SetCover

        cover, w = parts
        return SetCover(
            cover=cover, w=w, n=int(cover.shape[0]), use_kernel=self.use_kernel
        )

    def local_sweep(self, parts, covered):
        from repro.core.functions.set_cover import SCState
        from repro.core.optimizers.backends import full_sweep

        return full_sweep(self._local_fn(parts), SCState(covered=covered))

    def local_sweep_at(self, parts, covered, idx):
        from repro.core.functions.set_cover import SCState
        from repro.core.optimizers.backends import partial_sweep

        return partial_sweep(self._local_fn(parts), SCState(covered=covered), idx)

    def apply_winner(self, parts, covered, take, is_mine, wl, winner, col_axes):
        cover, w = parts
        row = jnp.where(is_mine, cover[wl], 0.0)
        row = jax.lax.psum(row, col_axes)
        return jnp.where(take, jnp.maximum(covered, row), covered)


@_dataclasses.dataclass(frozen=True)
class PSCShardRule(ShardRule):
    """ProbabilisticSetCover: log-miss rows sharded over candidates, the
    memoized per-concept miss probability replicated; the winner's log-miss
    row is psum-broadcast and folded multiplicatively."""

    use_kernel: bool = False

    def global_parts(self, fn):
        return (fn.log_miss, fn.w)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes, None), P(batch_axes))

    def init_state(self, parts):
        log_miss, w = parts
        return jnp.ones((log_miss.shape[1],), jnp.float32)

    def _local_fn(self, parts):
        from repro.core.functions.set_cover import ProbabilisticSetCover

        log_miss, w = parts
        return ProbabilisticSetCover(
            log_miss=log_miss,
            w=w,
            n=int(log_miss.shape[0]),
            use_kernel=self.use_kernel,
        )

    def local_sweep(self, parts, miss):
        from repro.core.functions.set_cover import PSCState
        from repro.core.optimizers.backends import full_sweep

        return full_sweep(self._local_fn(parts), PSCState(miss=miss))

    def local_sweep_at(self, parts, miss, idx):
        from repro.core.functions.set_cover import PSCState
        from repro.core.optimizers.backends import partial_sweep

        return partial_sweep(self._local_fn(parts), PSCState(miss=miss), idx)

    def apply_winner(self, parts, miss, take, is_mine, wl, winner, col_axes):
        log_miss, w = parts
        row = jnp.where(is_mine, log_miss[wl], 0.0)
        row = jax.lax.psum(row, col_axes)
        return jnp.where(take, miss * jnp.exp(row), miss)


@_dataclasses.dataclass(frozen=True)
class DSumShardRule(ShardRule):
    """DisparitySum: distance-matrix ROWS are the candidate axis (each shard
    keeps the full row of its candidates), selsum shards with the candidates,
    and the winner update is collective-free — the GraphCut shape."""

    def global_parts(self, fn):
        return (fn.dist,)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes, None),)

    def init_state(self, parts):
        (dist,) = parts
        return jnp.zeros((dist.shape[0],), dist.dtype)

    def local_sweep(self, parts, selsum):
        return selsum

    def local_sweep_at(self, parts, selsum, idx):
        return selsum[idx]

    def apply_winner(self, parts, selsum, take, is_mine, wl, winner, col_axes):
        (dist,) = parts
        return jnp.where(take, selsum + dist[:, winner], selsum)


@_dataclasses.dataclass(frozen=True)
class DMinShardRule(ShardRule):
    """DisparityMin: ``mind`` shards with the candidate rows; the scalars
    f(A) and |A| are replicated, refreshed from a psum of the winner's
    ``mind`` entry (its owning shard contributes, the rest add exact zeros)."""

    def global_parts(self, fn):
        return (fn.dist,)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes, None),)

    def init_state(self, parts):
        (dist,) = parts
        big = jnp.asarray(1e30, dist.dtype)
        return (
            jnp.full((dist.shape[0],), big, dist.dtype),  # mind (local rows)
            jnp.zeros((), dist.dtype),  # curmin = f(A)
            jnp.zeros((), jnp.int32),  # count = |A|
        )

    def local_sweep(self, parts, state):
        mind, curmin, count = state
        # DisparityMin.gains on the local slice (scalars replicated)
        surrogate = jnp.where(count == 0, 0.0, mind)
        return jnp.minimum(surrogate, 1e30) - curmin

    def local_sweep_at(self, parts, state, idx):
        mind, curmin, count = state
        surrogate = jnp.where(count == 0, 0.0, mind[idx])
        return jnp.minimum(surrogate, 1e30) - curmin

    def apply_winner(self, parts, state, take, is_mine, wl, winner, col_axes):
        (dist,) = parts
        mind, curmin, count = state
        mind_w = jax.lax.psum(jnp.where(is_mine, mind[wl], 0.0), col_axes)
        newmin = jnp.where(
            count <= 0,
            curmin,
            jnp.where(count == 1, mind_w, jnp.minimum(curmin, mind_w)),
        )
        return (
            jnp.where(take, jnp.minimum(mind, dist[:, winner]), mind),
            jnp.where(take, newmin, curmin),
            count + jnp.where(take, 1, 0).astype(jnp.int32),
        )


@_dataclasses.dataclass(frozen=True)
class GCMIShardRule(ShardRule):
    """GCMI: a pure modular function — the query-sum vector shards with the
    candidates, the running value is replicated via a scalar psum."""

    def global_parts(self, fn):
        return (fn.qsum,)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes),)

    def init_state(self, parts):
        (qsum,) = parts
        return jnp.zeros((), qsum.dtype)

    def local_sweep(self, parts, value):
        (qsum,) = parts
        return qsum

    def local_sweep_at(self, parts, value, idx):
        (qsum,) = parts
        return qsum[idx]

    def apply_winner(self, parts, value, take, is_mine, wl, winner, col_axes):
        (qsum,) = parts
        qj = jax.lax.psum(jnp.where(is_mine, qsum[wl], 0.0), col_axes)
        return jnp.where(take, value + qj, value)


@_dataclasses.dataclass(frozen=True)
class LogDetShardRule(ShardRule):
    """LogDet: the candidate Cholesky rows C and pivots d2 shard with the
    candidates (kernel rows); the winner's Cholesky row + pivot are
    psum-broadcast and every shard applies the same rank-1 update.  The
    reduce-form inner product in ``LogDet.update`` is what keeps the local
    e_i floats identical to the single-device sweep."""

    max_select: int = 0

    def global_parts(self, fn):
        return (fn.L, jnp.diagonal(fn.L))

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, col_axes, None), P(batch_axes, col_axes))

    def init_state(self, parts):
        block, diag = parts
        return (
            jnp.zeros((block.shape[0], self.max_select), block.dtype),  # C
            diag,  # d2
            jnp.zeros((), jnp.int32),  # count
        )

    def _local_fn_state(self, parts, state):
        from repro.core.functions.log_det import LogDet, LogDetState

        block, diag = parts
        C, d2, count = state
        fn_loc = LogDet(L=block, n=int(block.shape[0]), max_select=self.max_select)
        st = LogDetState(C=C, d2=d2, count=count, value=jnp.zeros((), block.dtype))
        return fn_loc, st

    def local_sweep(self, parts, state):
        from repro.core.optimizers.backends import full_sweep

        return full_sweep(*self._local_fn_state(parts, state))

    def local_sweep_at(self, parts, state, idx):
        from repro.core.optimizers.backends import partial_sweep

        fn_loc, st = self._local_fn_state(parts, state)
        return partial_sweep(fn_loc, st, idx)

    def apply_winner(self, parts, state, take, is_mine, wl, winner, col_axes):
        from repro.core.functions.log_det import _EPS

        block, diag = parts
        C, d2, count = state
        cj = jax.lax.psum(jnp.where(is_mine, C[wl], jnp.zeros_like(C[wl])), col_axes)
        d2j = jax.lax.psum(jnp.where(is_mine, d2[wl], 0.0), col_axes)
        dj = jnp.sqrt(jnp.maximum(d2j, _EPS))
        e = (block[:, winner] - (C * cj[None, :]).sum(axis=1)) / dj
        C_new = C.at[:, count].set(e, mode="drop")
        return (
            jnp.where(take, C_new, C),
            jnp.where(take, d2 - e * e, d2),
            count + jnp.where(take, 1, 0).astype(jnp.int32),
        )


class _FLInfoShardRule(ShardRule):
    """Shared shape for the FL-family information measures: query-side rows
    replicated, candidate columns sharded, ``curmax`` replicated and updated
    by a psum broadcast of the winner's column — the
    ``distributed_flqmi_greedy`` configuration generalized.  Subclasses
    rebuild the measure on the local column slice; the sweep then routes
    through ``backends.full_sweep`` so the class's own ``gains`` runs."""

    def _local_fn(self, parts):
        raise NotImplementedError

    def init_state(self, parts):
        sim = parts[0]
        return jnp.zeros((sim.shape[0],), sim.dtype)

    def local_sweep(self, parts, curmax):
        from repro.core.functions.facility_location import FLState
        from repro.core.optimizers.backends import full_sweep

        sim = parts[0]
        return full_sweep(
            self._local_fn(parts),
            FLState(curmax=curmax, n_rows=int(sim.shape[0])),
        )

    def local_sweep_at(self, parts, curmax, idx):
        from repro.core.functions.facility_location import FLState
        from repro.core.optimizers.backends import partial_sweep

        sim = parts[0]
        return partial_sweep(
            self._local_fn(parts),
            FLState(curmax=curmax, n_rows=int(sim.shape[0])),
            idx,
        )

    def apply_winner(self, parts, curmax, take, is_mine, wl, winner, col_axes):
        sim = parts[0]
        col = jax.lax.psum(jnp.where(is_mine, sim[:, wl], 0.0), col_axes)
        return jnp.where(take, jnp.maximum(curmax, col), curmax)


@_dataclasses.dataclass(frozen=True)
class FLQMIShardRule(_FLInfoShardRule):
    def global_parts(self, fn):
        return (fn.sim_qv, fn.modular)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, None, col_axes), P(batch_axes, col_axes))

    def _local_fn(self, parts):
        from repro.core.info.fl import FLQMI

        sim_qv, modular = parts
        return FLQMI(sim_qv=sim_qv, modular=modular, n=int(sim_qv.shape[1]))


@_dataclasses.dataclass(frozen=True)
class FLVMIShardRule(_FLInfoShardRule):
    def global_parts(self, fn):
        return (fn.sim, fn.qmax)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, None, col_axes), P(batch_axes, None))

    def _local_fn(self, parts):
        from repro.core.info.fl import FLVMI

        sim, qmax = parts
        return FLVMI(sim=sim, qmax=qmax, n=int(sim.shape[1]))


@_dataclasses.dataclass(frozen=True)
class FLCGShardRule(_FLInfoShardRule):
    def global_parts(self, fn):
        return (fn.sim, fn.pmax)

    def part_specs(self, batch_axes, col_axes):
        return (P(batch_axes, None, col_axes), P(batch_axes, None))

    def _local_fn(self, parts):
        from repro.core.info.fl import FLCG

        sim, pmax = parts
        return FLCG(sim=sim, pmax=pmax, n=int(sim.shape[1]))


@_dataclasses.dataclass(frozen=True)
class FLCMIShardRule(_FLInfoShardRule):
    def global_parts(self, fn):
        return (fn.sim, fn.qmax, fn.pmax)

    def part_specs(self, batch_axes, col_axes):
        return (
            P(batch_axes, None, col_axes),
            P(batch_axes, None),
            P(batch_axes, None),
        )

    def _local_fn(self, parts):
        from repro.core.info.fl import FLCMI

        sim, qmax, pmax = parts
        return FLCMI(sim=sim, qmax=qmax, pmax=pmax, n=int(sim.shape[1]))


# class -> factory(fn) -> ShardRule | None, resolved along the MRO (the same
# plug-in shape as backends.register_gain_backend)
_SHARD_RULES: dict[type, Any] = {}


def register_shard_rule(cls: type, factory) -> None:
    """Plug a :class:`ShardRule` factory in for ``cls`` (and subclasses)."""
    _SHARD_RULES[cls] = factory


def shard_rule(fn) -> ShardRule:
    """Resolve the shard rule serving ``fn``'s family, or raise."""
    for klass in type(fn).__mro__:
        factory = _SHARD_RULES.get(klass)
        if factory is not None:
            rule = factory(fn)
            if rule is not None:
                return rule
    raise NotImplementedError(
        f"{type(fn).__name__} has no registered ShardRule, so it cannot be "
        "mesh-sharded; plug one in via "
        "repro.core.optimizers.distributed.register_shard_rule (see "
        "docs/functions.md for the families served out of the box)"
    )


def _reject_kernel_on_mesh(name: str) -> None:
    raise ValueError(
        f"{name} with use_kernel=True cannot be mesh-sharded bit-identically: "
        "single-device maximize sweeps through the stateless Pallas recompute "
        "while the shard rule must use the memoized form, and their float "
        "reductions differ. Serve it single-device, or build the function "
        "with use_kernel=False."
    )


def _register_builtin_rules():
    from repro.core.functions.disparity import DisparityMin, DisparitySum
    from repro.core.functions.facility_location import FacilityLocation
    from repro.core.functions.feature_based import FeatureBased
    from repro.core.functions.graph_cut import GraphCut
    from repro.core.functions.log_det import LogDet
    from repro.core.functions.set_cover import ProbabilisticSetCover, SetCover
    from repro.core.info.fl import FLCG, FLCMI, FLQMI, FLVMI
    from repro.core.info.gc import GCMI
    from repro.core.optimizers.backends import kernel_enabled

    # use_kernel=None ("auto") is resolved HERE, against the GLOBAL ground-set
    # size, and the concrete bool is baked into the rule: the rules rebuild
    # candidate-sliced local instances whose n is V_loc, and letting the
    # heuristic re-resolve per shard could pick a different float path than
    # the sequential reference, breaking the bit-identical contract.

    def _gc_rule(fn):
        if kernel_enabled(fn.use_kernel, fn.n):
            _reject_kernel_on_mesh("GraphCut")
        return GCShardRule()

    def _dsum_rule(fn):
        if kernel_enabled(fn.use_kernel, fn.n):
            _reject_kernel_on_mesh("DisparitySum")
        return DSumShardRule()

    def _dmin_rule(fn):
        if kernel_enabled(fn.use_kernel, fn.n):
            _reject_kernel_on_mesh("DisparityMin")
        return DMinShardRule()

    register_shard_rule(
        FacilityLocation,
        lambda fn: FLShardRule(use_kernel=kernel_enabled(fn.use_kernel, fn.n)),
    )
    register_shard_rule(GraphCut, _gc_rule)
    register_shard_rule(
        FeatureBased,
        lambda fn: FBShardRule(
            concave=fn.concave, use_kernel=kernel_enabled(fn.use_kernel, fn.n)
        ),
    )
    register_shard_rule(
        SetCover,
        lambda fn: SCShardRule(use_kernel=kernel_enabled(fn.use_kernel, fn.n)),
    )
    register_shard_rule(
        ProbabilisticSetCover,
        lambda fn: PSCShardRule(use_kernel=kernel_enabled(fn.use_kernel, fn.n)),
    )
    register_shard_rule(DisparitySum, _dsum_rule)
    register_shard_rule(DisparityMin, _dmin_rule)
    register_shard_rule(GCMI, lambda fn: GCMIShardRule())
    register_shard_rule(
        LogDet, lambda fn: LogDetShardRule(max_select=fn.max_select)
    )
    register_shard_rule(FLQMI, lambda fn: FLQMIShardRule())
    register_shard_rule(FLVMI, lambda fn: FLVMIShardRule())
    register_shard_rule(FLCG, lambda fn: FLCGShardRule())
    register_shard_rule(FLCMI, lambda fn: FLCMIShardRule())


_register_builtin_rules()


def stack_parts(rule: ShardRule, fns: Sequence) -> tuple:
    """Stack each instance's ``rule.global_parts`` into (B, ...) arrays."""
    per = [rule.global_parts(f) for f in fns]
    return tuple(
        jnp.stack([p[k] for p in per]) for k in range(len(per[0]))
    )


@partial(
    jax.jit,
    static_argnames=(
        "rule",
        "max_budget",
        "mesh",
        "batch_axes",
        "col_axes",
        "stop_if_zero",
        "stop_if_negative",
    ),
)
def sharded_batched_greedy(
    rule: ShardRule,
    parts: tuple,
    budgets: jax.Array,
    valid: jax.Array,
    *,
    max_budget: int,
    mesh: jax.sharding.Mesh,
    batch_axes: Sequence[str] = ("batch",),
    col_axes: Sequence[str] = ("data",),
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
):
    """Run a B-query naive-greedy wave over a (batch x data) mesh.

    Args:
      rule: the family's :class:`ShardRule` (static — part of the jit key).
      parts: B-stacked dynamic arrays from :func:`stack_parts`.
      budgets: (B,) int32 per-instance budgets (instances freeze once spent).
      valid: (B, n) bool; False marks padded candidates.
      max_budget: static loop bound, >= max(budgets).
      mesh: mesh carrying ``batch_axes`` (batch sharding) + ``col_axes``
        (candidate sharding); B and n must be multiples of the respective
        axis sizes.

    Returns ``(order, gains, n_evals, value)`` with shapes ``(B, max_budget)``,
    ``(B, max_budget)``, ``(B,)``, ``(B,)`` — per instance bit-identical to
    ``naive_greedy`` on one device (same sweep -> argmax -> update ordering,
    same stopping rule, ``n_evals`` counting the LIVE candidates per step —
    pad columns sweep along but are not logical oracle calls).
    """
    from repro.core.optimizers.greedy import _should_stop

    batch_axes = tuple(batch_axes)
    col_axes = tuple(col_axes)
    B, n = valid.shape

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(
            rule.part_specs(batch_axes, col_axes),
            P(batch_axes),
            P(batch_axes, col_axes),
        ),
        out_specs=(
            P(batch_axes, None),
            P(batch_axes, None),
            P(batch_axes),
            P(batch_axes),
        ),
        check_vma=False,
    )
    def run(parts_l, budgets_l, valid_l):
        def one(parts_i, budget_i, valid_i):
            V_loc = valid_i.shape[0]
            col_off = _flat_axis_index(col_axes) * V_loc
            state0 = rule.init_state(parts_i)
            # logical sweep width: live candidates across every shard
            true_n = jax.lax.psum(
                jnp.sum(valid_i, dtype=jnp.int32), col_axes
            )

            def body(i, carry):
                state, selected, order, gains, evals, done = carry
                blocked = selected | ~valid_i
                g = jnp.where(blocked, NEG_INF, rule.local_sweep(parts_i, state))
                lbi = jnp.argmax(g)
                lbg = g[lbi]
                gbest = jax.lax.pmax(lbg, col_axes)
                cand = jnp.where(lbg >= gbest, col_off + lbi, _INT_MAX)
                winner = jax.lax.pmin(cand, col_axes)  # first global argmax
                past = i >= budget_i
                stop = done | past | _should_stop(
                    gbest, stop_if_zero, stop_if_negative
                )
                take = ~stop
                is_mine = (winner >= col_off) & (winner < col_off + V_loc)
                wl = jnp.clip(winner - col_off, 0, V_loc - 1)
                state = rule.apply_winner(
                    parts_i, state, take, is_mine, wl, winner, col_axes
                )
                selected = selected | (
                    take & is_mine & (jnp.arange(V_loc) == wl)
                )
                order = order.at[i].set(jnp.where(take, winner, -1))
                gains = gains.at[i].set(jnp.where(take, gbest, 0.0))
                evals = evals + jnp.where(done | past, 0, true_n)
                return state, selected, order, gains, evals, stop

            carry = (
                state0,
                jnp.zeros((V_loc,), bool),
                jnp.full((max_budget,), -1, jnp.int32),
                jnp.zeros((max_budget,), jnp.float32),
                jnp.zeros((), jnp.int32),
                jnp.zeros((), bool),
            )
            _, _, order, gains, evals, _ = jax.lax.fori_loop(
                0, max_budget, body, carry
            )
            return order, gains, evals, gains.sum()

        return jax.vmap(one)(parts_l, budgets_l, valid_l)

    return run(parts, budgets, valid)


def _all_gather_cols(x: jax.Array, col_axes: Sequence[str]) -> jax.Array:
    """Concatenate a (B_loc, k) array across the column shards along axis 1,
    ordered by the flat column-shard index (matches ``_flat_axis_index``)."""
    # gather the fastest-varying axis first so blocks land in flat-index order
    for a in reversed(tuple(col_axes)):
        x = jax.lax.all_gather(x, a, axis=1, tiled=True)
    return x


@partial(
    jax.jit,
    static_argnames=(
        "rule",
        "max_budget",
        "mesh",
        "batch_axes",
        "col_axes",
        "screen_k",
        "stop_if_zero",
        "stop_if_negative",
    ),
)
def sharded_batched_lazy(
    rule: ShardRule,
    parts: tuple,
    budgets: jax.Array,
    valid: jax.Array,
    *,
    max_budget: int,
    mesh: jax.sharding.Mesh,
    batch_axes: Sequence[str] = ("batch",),
    col_axes: Sequence[str] = ("data",),
    screen_k: int = 8,
    stop_if_zero: bool = True,
    stop_if_negative: bool = True,
):
    """Run a B-query **bucketed lazy** wave over a (batch x data) mesh — the
    eval-sparse counterpart of :func:`sharded_batched_greedy`.

    Same arguments plus ``screen_k``.  Per step, per level of the doubling
    screen schedule (``greedy._screen_levels``):

    1. every shard sorts its local stale bounds once per step (descending,
       ties by lowest GLOBAL index — the same ``lax.sort`` keys as the
       single-device engine, so cross-shard merges cannot reorder equal
       bounds the way raw top_k would);
    2. the level's prefix of each shard's sorted (bound, index) pairs is
       ``all_gather``-ed over the column shards and merge-sorted — an
       O(level width) payload, NOT O(n) — reproducing the global sort prefix
       exactly;
    3. **the gathered subset is sharded back for evaluation**: each shard
       computes true gains only for the screened candidates it owns
       (``rule.local_sweep_at`` — an O(k * stat) partial sweep, Pallas
       subset kernels per shard where the family has them) and a ``psum``
       assembles the replicated (B_loc, k) true-gain block;
    4. acceptance (best evaluated gain beats every remaining stale bound)
       is decided on replicated values, so every shard agrees; the level
       itself is skipped via a ``lax.cond`` whose predicate is uniform
       within each column group once the whole local wave has resolved.

    The winner is already replicated (no pmax/pmin election needed), and
    ``rule.apply_winner`` folds it in exactly as the naive engine does.
    Results are bit-identical to single-device ``lazy_greedy`` per instance
    — ids, gains, and the per-instance ``n_evals`` level accounting.
    """
    from repro.core.optimizers.greedy import _screen_levels, _should_stop

    batch_axes = tuple(batch_axes)
    col_axes = tuple(col_axes)
    B, n = valid.shape
    levels = _screen_levels(n, screen_k)

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(
            rule.part_specs(batch_axes, col_axes),
            P(batch_axes),
            P(batch_axes, col_axes),
        ),
        out_specs=(
            P(batch_axes, None),
            P(batch_axes, None),
            P(batch_axes),
            P(batch_axes),
        ),
        check_vma=False,
    )
    def run(parts_l, budgets_l, valid_l):
        B_loc, V_loc = valid_l.shape
        col_off = _flat_axis_index(col_axes) * V_loc
        gidx = col_off + jnp.arange(V_loc, dtype=jnp.int32)  # global ids
        rows = jnp.arange(B_loc)
        state0 = jax.vmap(rule.init_state)(parts_l)
        ub0 = jax.vmap(rule.local_sweep)(parts_l, state0)  # (B_loc, V_loc)

        def body(i, carry):
            state, selected, ub, order, gains, evals, done = carry
            blocked = selected | ~valid_l
            ubm = jnp.where(blocked, NEG_INF, ub)
            # local descending stale-bound order, ties by global index (one
            # sort per step; levels slice its prefix)
            neg_lv, li = jax.lax.sort(
                (-ubm, jnp.broadcast_to(gidx, (B_loc, V_loc))),
                dimension=-1,
                num_keys=2,
            )

            def level(lo, hi, c):
                resolved, best_g, best_j, geval, evaluated, cost = c
                kl = min(hi + 1, V_loc)  # covers the global top-(hi+1)
                # merge the column shards' sorted prefixes: payload O(hi)
                gv = -_all_gather_cols(neg_lv[:, :kl], col_axes)
                gi = _all_gather_cols(li[:, :kl], col_axes)
                neg_sv, mi = jax.lax.sort((-gv, gi), dimension=-1, num_keys=2)
                sv = -neg_sv  # == the global stale-bound sort through hi
                idx = mi[:, lo:hi]  # (B_loc, w) global candidate ids
                own = (idx >= col_off) & (idx < col_off + V_loc)
                lread = jnp.clip(idx - col_off, 0, V_loc - 1)
                g_loc = jax.vmap(rule.local_sweep_at)(parts_l, state, lread)
                blk = jnp.take_along_axis(blocked, lread, axis=1)
                g_loc = jnp.where(blk, NEG_INF, g_loc.astype(ub.dtype))
                # each screened candidate's gain comes from its owning shard
                g = jax.lax.psum(jnp.where(own, g_loc, 0.0), col_axes)

                live = ~resolved
                # refresh the local shard of the bound vector (owned slots)
                lwrite = jnp.where(own, lread, V_loc)  # V_loc -> dropped
                geval = jnp.where(
                    live[:, None],
                    geval.at[rows[:, None], lwrite].set(g, mode="drop"),
                    geval,
                )
                evaluated = jnp.where(
                    live[:, None],
                    evaluated.at[rows[:, None], lwrite].set(True, mode="drop"),
                    evaluated,
                )
                # logical evaluations only: count the LIVE candidates in
                # the level, summed over the owning shards (matches the
                # single-device engine's padded-instance accounting)
                w_valid = jax.lax.psum(
                    jnp.sum(
                        jnp.take_along_axis(valid_l, lread, axis=1) & own,
                        axis=1,
                        dtype=jnp.int32,
                    ),
                    col_axes,
                )
                cost = cost + jnp.where(live, w_valid, 0)
                # running first-index argmax over everything evaluated so far
                lvl_best = jnp.max(g, axis=1)
                lvl_j = jnp.min(
                    jnp.where(g == lvl_best[:, None], idx, _INT_MAX), axis=1
                )
                better = lvl_best > best_g
                tie = (lvl_best == best_g) & (lvl_j < best_j)
                best_j = jnp.where(live & (better | tie), lvl_j, best_j)
                best_g = jnp.where(live & better, lvl_best, best_g)
                rest = (
                    sv[:, hi]
                    if hi < n
                    else jnp.full((B_loc,), NEG_INF, sv.dtype)
                )
                resolved = resolved | (best_g >= rest - 1e-6)
                return resolved, best_g, best_j, geval, evaluated, cost

            c = (
                jnp.zeros((B_loc,), bool),
                jnp.full((B_loc,), NEG_INF, ub.dtype),
                # matches the single-device argmax over an all-NEG_INF
                # buffer, which degenerates to index 0
                jnp.zeros((B_loc,), jnp.int32),
                jnp.full((B_loc, V_loc), NEG_INF, ub.dtype),
                jnp.zeros((B_loc, V_loc), bool),
                jnp.zeros((B_loc,), jnp.int32),
            )
            for lo, hi in levels:
                # predicate is replicated within each column group (inputs
                # all replicated), so the collectives inside stay uniform
                c = jax.lax.cond(
                    jnp.all(c[0]), lambda c: c, partial(level, lo, hi), c
                )
            _, best_g, best_j, geval, evaluated, cost = c

            gj = best_g
            past = i >= budgets_l
            stop = done | past | _should_stop(gj, stop_if_zero, stop_if_negative)
            take = ~stop
            is_mine = (best_j >= col_off) & (best_j < col_off + V_loc)
            wl = jnp.clip(best_j - col_off, 0, V_loc - 1)
            state = jax.vmap(
                lambda p, s, t, im, w_, wn: rule.apply_winner(
                    p, s, t, im, w_, wn, col_axes
                )
            )(parts_l, state, take, is_mine, wl, best_j)
            selected = selected | (
                take[:, None]
                & is_mine[:, None]
                & (jnp.arange(V_loc)[None, :] == wl[:, None])
            )
            ub = jnp.where(evaluated, geval, ubm)
            order = order.at[:, i].set(jnp.where(take, best_j, -1))
            gains = gains.at[:, i].set(jnp.where(take, gj, 0.0))
            evals = evals + jnp.where(done | past, 0, cost)
            return state, selected, ub, order, gains, evals, stop

        carry = (
            state0,
            jnp.zeros((B_loc, V_loc), bool),
            ub0,
            jnp.full((B_loc, max_budget), -1, jnp.int32),
            jnp.zeros((B_loc, max_budget), jnp.float32),
            jax.lax.psum(  # the initial bound sweep (live candidates)
                jnp.sum(valid_l, axis=1, dtype=jnp.int32), col_axes
            ),
            jnp.zeros((B_loc,), bool),
        )
        out = jax.lax.fori_loop(0, max_budget, body, carry)
        _, _, _, order, gains, evals, _ = out
        return order, gains, evals, gains.sum(axis=1)

    return run(parts, budgets, valid)
