"""Batched multi-query greedy engine (serving-shaped maximization).

``maximize`` answers one selection query per call; a deployment answering
many users wants the B-query form: run B independent greedy problems — same
function family, different kernels / queries / budgets — as ONE vmap-ed,
jitted program, so every per-step full sweep becomes a single batched
matmul-shaped op on the accelerator instead of B dispatches.

Heterogeneity is expressed with padding masks rather than shape polymorphism:

- different ground-set sizes: pad every instance's arrays to a common n and
  pass ``valid`` (B, n) — padded candidates are masked to -inf and never
  selected (``n_evals`` still counts the padded sweep width);
- different budgets: pass a per-instance budget vector; the engine runs to
  max(budgets) internally and freezes an instance once its budget is spent.

The per-instance results are bit-identical to a Python loop of single
``maximize`` calls (same sweep -> argmax -> update ordering, same stopping
rule, same ``n_evals`` accounting); ``tests/test_batched.py`` pins this.
Full sweeps route through the pluggable gain backend (backends.py), so a
function family's fused Pallas sweep is used inside the batch too.

Passing ``mesh=`` (a 2-D jax Mesh) promotes the engine to the **distributed
batched** form: the batch axis shards over ``batch_axis`` and every
instance's candidate axis over ``data_axis``, running the shard_map
engines from ``optimizers/distributed.py`` — the partition-greedy sweep for
"NaiveGreedy" and the bucketed lazy engine (gathered-subset partial sweeps
+ merged stale-bound prefixes) for "LazyGreedy".  Results keep the same
bit-identical contract (``tests/test_serving.py`` pins it on a >=4-device
host mesh).

LazyGreedy's eval savings survive batching because its screen levels branch
on *scalar* ``lax.cond`` predicates shared by the wave, instead of the old
per-instance ``lax.cond`` that vmap lowers to select (both branches
executing, i.e. a full sweep every step — the ROADMAP "Lazy batched engine
efficiency" item this module closed).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optimizers.greedy import (
    GreedyResult,
    _lazy_bucketed_impl,
    _naive_impl,
)


def stack_functions(fns: Sequence) -> object:
    """Stack B same-family SetFunction pytrees into one batched pytree.

    All instances must share the treedef (same class, same static meta — n,
    concave, use_kernel, ...) and per-leaf shapes; pad kernels/features to a
    common n first and express the true sizes through ``valid`` masks.
    """
    fns = list(fns)
    if not fns:
        raise ValueError("stack_functions: need at least one function")
    treedefs = {jax.tree.structure(f) for f in fns}
    if len(treedefs) != 1:
        raise ValueError(
            "stack_functions: all instances must share one function family and "
            f"static meta fields; got {len(treedefs)} distinct structures"
        )
    try:
        return jax.tree.map(lambda *leaves: jnp.stack(leaves), *fns)
    except (ValueError, TypeError) as e:
        raise ValueError(
            "stack_functions: leaf shapes differ across instances — pad every "
            "kernel/feature matrix to a common ground-set size and pass a "
            "`valid` mask to batched_maximize"
        ) from e


@partial(jax.jit, static_argnums=(1, 4, 5))
def _batched_naive(fns, max_budget, budgets, valid, stop_if_zero, stop_if_negative):
    # per-instance behaviour is greedy._naive_impl itself — the bit-identical
    # contract with sequential naive_greedy holds by construction
    return jax.vmap(
        lambda fn, b, v: _naive_impl(
            fn, max_budget, stop_if_zero, stop_if_negative, budget_i=b, valid=v
        )
    )(fns, budgets, valid)


@partial(jax.jit, static_argnums=(1, 4, 5, 6))
def _batched_lazy(
    fns, max_budget, budgets, valid, screen_k, stop_if_zero, stop_if_negative
):
    # the bucketed lazy sweep IS the sequential lazy_greedy (B=1) run with an
    # explicit batch dimension, so bit-identity holds by construction — and,
    # unlike the old vmap(_lazy_impl) form, its screen levels gate on SCALAR
    # lax.cond predicates, so an all-accept step costs O(B * screen_k)
    # gathered evals instead of the O(B * n) select-lowered full sweep
    return _lazy_bucketed_impl(
        fns, max_budget, budgets, valid, screen_k, stop_if_zero, stop_if_negative
    )


class BatchedEngine:
    """A reusable B-instance selection engine (the serving shape).

    Stacking B kernel/feature matrices costs O(B * n * stat) HBM traffic, so
    a server does it ONCE at ingest and then answers many selection calls
    against the resident batch; each :meth:`maximize` is a single jitted
    dispatch.  ``batched_maximize`` is the one-shot convenience wrapper.

    With ``mesh=`` the resident batch is laid out over a 2-D device mesh:
    batch axis over ``batch_axis``, candidate axis over ``data_axis`` (B and
    n must each be a multiple of the corresponding mesh axis size — the
    serving coalescer in ``launch/coalesce.py`` pads waves to guarantee
    this).
    """

    def __init__(
        self,
        fns: Sequence,
        valid: jax.Array | None = None,
        mesh: jax.sharding.Mesh | None = None,
        batch_axis: str = "batch",
        data_axis: str = "data",
    ):
        fns = list(fns)
        if not fns:
            raise ValueError("BatchedEngine: need at least one instance")
        self.batch_size = len(fns)
        self.n = fns[0].n
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.data_axis = data_axis
        if mesh is None:
            self.stacked = stack_functions(fns)
        else:
            from repro.core.optimizers.distributed import shard_rule, stack_parts

            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for name, dim, what in (
                (batch_axis, self.batch_size, "batch size"),
                (data_axis, self.n, "ground-set size"),
            ):
                if name not in sizes:
                    raise ValueError(
                        f"mesh has no axis {name!r} (axes: {mesh.axis_names})"
                    )
                if dim % sizes[name]:
                    raise ValueError(
                        f"{what} {dim} is not a multiple of mesh axis "
                        f"{name!r} size {sizes[name]}"
                    )
            self.rule = shard_rule(fns[0])
            self.parts = stack_parts(self.rule, fns)
        self.valid = (
            jnp.ones((self.batch_size, self.n), bool)
            if valid is None
            else jnp.asarray(valid, bool)
        )
        if self.valid.shape != (self.batch_size, self.n):
            raise ValueError(
                f"valid mask must be ({self.batch_size}, {self.n}), "
                f"got {self.valid.shape}"
            )

    def maximize(
        self,
        budget: int | Sequence[int],
        optimizer: str = "NaiveGreedy",
        return_result: bool = False,
        max_budget: int | None = None,
        **kwargs,
    ) -> list:
        """Solve the resident batch.  ``max_budget`` optionally raises the
        static loop bound above max(budgets) — serving uses bucketed bounds so
        waves with different budget mixes share one compiled program."""
        B = self.batch_size
        budgets = (
            [int(budget)] * B
            if isinstance(budget, (int, np.integer))
            else [int(b) for b in budget]
        )
        if len(budgets) != B:
            raise ValueError(
                f"budget list has {len(budgets)} entries for {B} instances"
            )
        max_budget = max(budgets) if max_budget is None else int(max_budget)
        if max_budget < max(budgets):
            raise ValueError(
                f"max_budget {max_budget} < largest per-instance budget "
                f"{max(budgets)}"
            )
        b_arr = jnp.asarray(budgets, jnp.int32)
        stop_zero = kwargs.get("stopIfZeroGain", True)
        stop_neg = kwargs.get("stopIfNegativeGain", True)
        if self.mesh is not None:
            if optimizer == "NaiveGreedy":
                from repro.core.optimizers.distributed import sharded_batched_greedy

                order, gains, evals, value = sharded_batched_greedy(
                    self.rule,
                    self.parts,
                    b_arr,
                    self.valid,
                    max_budget=max_budget,
                    mesh=self.mesh,
                    batch_axes=(self.batch_axis,),
                    col_axes=(self.data_axis,),
                    stop_if_zero=stop_zero,
                    stop_if_negative=stop_neg,
                )
            elif optimizer == "LazyGreedy":
                from repro.core.optimizers.distributed import sharded_batched_lazy

                order, gains, evals, value = sharded_batched_lazy(
                    self.rule,
                    self.parts,
                    b_arr,
                    self.valid,
                    max_budget=max_budget,
                    mesh=self.mesh,
                    batch_axes=(self.batch_axis,),
                    col_axes=(self.data_axis,),
                    screen_k=int(kwargs.get("screen_k", 8)),
                    stop_if_zero=stop_zero,
                    stop_if_negative=stop_neg,
                )
            else:
                raise ValueError(
                    f"unknown optimizer {optimizer!r}; the sharded engine "
                    "supports 'NaiveGreedy' and 'LazyGreedy'"
                )
            res = GreedyResult(order=order, gains=gains, n_evals=evals, value=value)
        elif optimizer == "NaiveGreedy":
            res = _batched_naive(
                self.stacked, max_budget, b_arr, self.valid, stop_zero, stop_neg
            )
        elif optimizer == "LazyGreedy":
            res = _batched_lazy(
                self.stacked,
                max_budget,
                b_arr,
                self.valid,
                kwargs.get("screen_k", 8),
                stop_zero,
                stop_neg,
            )
        else:
            raise ValueError(
                f"unknown optimizer {optimizer!r}; batched engine supports "
                "'NaiveGreedy' and 'LazyGreedy'"
            )
        # one transfer for the whole batch, then host-side slicing — B tiny
        # device slices would dominate small-query serving latency
        order, gains, evals, value = jax.device_get(
            (res.order, res.gains, res.n_evals, res.value)
        )
        results = [
            GreedyResult(
                order=order[i, :b],
                gains=gains[i, :b],
                n_evals=evals[i],
                value=value[i],
            )
            for i, b in enumerate(budgets)
        ]
        return results if return_result else [r.as_list() for r in results]


def batched_maximize(
    fns: Sequence,
    budget: int | Sequence[int],
    optimizer: str = "NaiveGreedy",
    valid: jax.Array | None = None,
    return_result: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    batch_axis: str = "batch",
    data_axis: str = "data",
    **kwargs,
) -> list:
    """Solve B selection problems in one jitted program.

    Args:
      fns: B same-family SetFunction instances (identical static meta).
      budget: shared int or per-instance sequence of ints.
      optimizer: "NaiveGreedy" or "LazyGreedy" (both also run sharded).
      valid: optional (B, n) bool — False marks padded candidates.
      return_result: True -> list of per-instance :class:`GreedyResult`
        (order/gains sliced to that instance's budget), False -> list of
        submodlib-style [(index, gain), ...] lists.
      mesh: optional 2-D mesh — shard the batch axis over ``batch_axis`` and
        the candidate axis over ``data_axis`` (the distributed batched form).
      kwargs: stopIfZeroGain / stopIfNegativeGain / screen_k, as `maximize`.

    For repeated selections over the same instances, build a
    :class:`BatchedEngine` once and call its ``maximize`` — that skips the
    per-call restacking of the B kernels.
    """
    fns = list(fns)
    if not fns:
        return []
    engine = BatchedEngine(
        fns, valid=valid, mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
    )
    return engine.maximize(
        budget, optimizer=optimizer, return_result=return_result, **kwargs
    )
