"""Batched multi-query greedy engine (serving-shaped maximization).

``maximize`` answers one selection query per call; a deployment answering
many users wants the B-query form: run B independent greedy problems — same
function family, different kernels / queries / budgets — as ONE vmap-ed,
jitted program, so every per-step full sweep becomes a single batched
matmul-shaped op on the accelerator instead of B dispatches.

Heterogeneity is expressed with padding masks rather than shape polymorphism:

- different ground-set sizes: pad every instance's arrays to a common n and
  pass ``valid`` (B, n) — padded candidates are masked to -inf and never
  selected, and ``n_evals`` counts only the live candidates, so a padded
  instance reports the same count it would sequentially;
- different budgets: pass a per-instance budget vector; the engine runs to
  max(budgets) internally and freezes an instance once its budget is spent.

The per-instance results are bit-identical to a Python loop of single
``maximize`` calls (same sweep -> argmax -> update ordering, same stopping
rule, same ``n_evals`` accounting); ``tests/test_batched.py`` pins this.
Full sweeps route through the pluggable gain backend (backends.py), so a
function family's fused Pallas sweep is used inside the batch too.

Passing ``mesh=`` (a 2-D jax Mesh) promotes the engine to the **distributed
batched** form: the batch axis shards over ``batch_axis`` and every
instance's candidate axis over ``data_axis``, running the shard_map
engines from ``optimizers/distributed.py`` — the partition-greedy sweep for
"NaiveGreedy" and the bucketed lazy engine (gathered-subset partial sweeps
+ merged stale-bound prefixes) for "LazyGreedy".  Results keep the same
bit-identical contract (``tests/test_serving.py`` pins it on a >=4-device
host mesh).

LazyGreedy's eval savings survive batching because its screen levels branch
on *scalar* ``lax.cond`` predicates shared by the wave, instead of the old
per-instance ``lax.cond`` that vmap lowers to select (both branches
executing, i.e. a full sweep every step — the ROADMAP "Lazy batched engine
efficiency" item this module closed).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.optimizers.greedy import (
    GreedyResult,
    _lazy_bucketed_impl,
    _naive_impl,
)
from repro.core.optimizers.spec import (
    OptimizerSpec,
    resolve_optimizer,
    wave_capable_names,
)


def stack_functions(fns: Sequence) -> object:
    """Stack B same-family SetFunction pytrees into one batched pytree.

    All instances must share the treedef (same class, same static meta — n,
    concave, use_kernel, ...) and per-leaf shapes; pad kernels/features to a
    common n first and express the true sizes through ``valid`` masks.
    """
    fns = list(fns)
    if not fns:
        raise ValueError("stack_functions: need at least one function")
    treedefs = {jax.tree.structure(f) for f in fns}
    if len(treedefs) != 1:
        raise ValueError(
            "stack_functions: all instances must share one function family and "
            f"static meta fields; got {len(treedefs)} distinct structures"
        )
    try:
        return jax.tree.map(lambda *leaves: jnp.stack(leaves), *fns)
    except (ValueError, TypeError) as e:
        raise ValueError(
            "stack_functions: leaf shapes differ across instances — pad every "
            "kernel/feature matrix to a common ground-set size and pass a "
            "`valid` mask to batched_maximize"
        ) from e


@partial(jax.jit, static_argnums=(1, 4, 5))
def _batched_naive(fns, max_budget, budgets, valid, stop_if_zero, stop_if_negative):
    # per-instance behaviour is greedy._naive_impl itself — the bit-identical
    # contract with sequential naive_greedy holds by construction
    return jax.vmap(
        lambda fn, b, v: _naive_impl(
            fn, max_budget, stop_if_zero, stop_if_negative, budget_i=b, valid=v
        )
    )(fns, budgets, valid)


@partial(jax.jit, static_argnums=(1, 4, 5, 6))
def _batched_lazy(
    fns, max_budget, budgets, valid, screen_k, stop_if_zero, stop_if_negative
):
    # the bucketed lazy sweep IS the sequential lazy_greedy (B=1) run with an
    # explicit batch dimension, so bit-identity holds by construction — and,
    # unlike the old vmap(_lazy_impl) form, its screen levels gate on SCALAR
    # lax.cond predicates, so an all-accept step costs O(B * screen_k)
    # gathered evals instead of the O(B * n) select-lowered full sweep
    return _lazy_bucketed_impl(
        fns, max_budget, budgets, valid, screen_k, stop_if_zero, stop_if_negative
    )


class BatchedEngine:
    """A reusable B-instance selection engine (the serving shape).

    Stacking B kernel/feature matrices costs O(B * n * stat) HBM traffic, so
    a server does it ONCE at ingest and then answers many selection calls
    against the resident batch; each :meth:`maximize` is a single jitted
    dispatch.  ``batched_maximize`` is the one-shot convenience wrapper.

    With ``mesh=`` the resident batch is laid out over a 2-D device mesh:
    batch axis over ``batch_axis``, candidate axis over ``data_axis`` (B and
    n must each be a multiple of the corresponding mesh axis size — the
    serving coalescer in ``launch/coalesce.py`` pads waves to guarantee
    this).
    """

    def __init__(
        self,
        fns: Sequence,
        valid: jax.Array | None = None,
        mesh: jax.sharding.Mesh | None = None,
        batch_axis: str = "batch",
        data_axis: str = "data",
    ):
        fns = list(fns)
        if not fns:
            raise ValueError("BatchedEngine: need at least one instance")
        self.fns = fns
        self.batch_size = len(fns)
        self.n = fns[0].n
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.data_axis = data_axis
        self._stacked = None
        if mesh is None:
            self._stacked = stack_functions(fns)
        else:
            from repro.core.optimizers.distributed import shard_rule, stack_parts

            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for name, dim, what in (
                (batch_axis, self.batch_size, "batch size"),
                (data_axis, self.n, "ground-set size"),
            ):
                if name not in sizes:
                    raise ValueError(
                        f"mesh has no axis {name!r} (axes: {mesh.axis_names})"
                    )
                if dim % sizes[name]:
                    raise ValueError(
                        f"{what} {dim} is not a multiple of mesh axis "
                        f"{name!r} size {sizes[name]}"
                    )
            self.rule = shard_rule(fns[0])
            self.parts = stack_parts(self.rule, fns)
        self.valid = (
            jnp.ones((self.batch_size, self.n), bool)
            if valid is None
            else jnp.asarray(valid, bool)
        )
        if self.valid.shape != (self.batch_size, self.n):
            raise ValueError(
                f"valid mask must be ({self.batch_size}, {self.n}), "
                f"got {self.valid.shape}"
            )

    @property
    def stacked(self):
        """The B-stacked function pytree; built lazily on a mesh (only the
        mesh-replicated optimizer path needs it there)."""
        if self._stacked is None:
            self._stacked = stack_functions(self.fns)
        return self._stacked

    def run(
        self,
        budget: int | Sequence[int],
        optimizer: OptimizerSpec | str = "NaiveGreedy",
        *,
        stop_if_zero: bool = True,
        stop_if_negative: bool = True,
        max_budget: int | None = None,
    ) -> list[GreedyResult]:
        """Solve the resident batch through the optimizer registry.

        This is the typed engine path behind ``solve()`` and the serving
        dispatch: the optimizer (an :class:`OptimizerSpec`, or a name built
        into one) carries its validated hyperparameters, and the registry
        supplies the batched / sharded execution hook — an optimizer without
        one is rejected here with the batched-capable set named, never
        mid-trace.  ``max_budget`` optionally raises the static loop bound
        above max(budgets) — serving uses bucketed bounds so waves with
        different budget mixes share one compiled program.
        """
        opt = OptimizerSpec(optimizer) if not isinstance(optimizer, OptimizerSpec) else optimizer
        defn = resolve_optimizer(opt.name)
        B = self.batch_size
        budgets = (
            [int(budget)] * B
            if isinstance(budget, (int, np.integer))
            else [int(b) for b in budget]
        )
        if len(budgets) != B:
            raise ValueError(
                f"budget list has {len(budgets)} entries for {B} instances"
            )
        max_budget = max(budgets) if max_budget is None else int(max_budget)
        if max_budget < max(budgets):
            raise ValueError(
                f"max_budget {max_budget} < largest per-instance budget "
                f"{max(budgets)}"
            )
        b_arr = jnp.asarray(budgets, jnp.int32)
        # on a mesh: a collective sharded engine when the optimizer has one,
        # else a mesh-replicated optimizer runs its batched hook as-is (the
        # program is sequential in its data pass, so every device computes
        # the identical answer — on-mesh == off-mesh bit-identity holds)
        sharded = self.mesh is not None and defn.sharded_run is not None
        hook = defn.sharded_run if sharded else defn.batched_run
        if hook is None or (
            self.mesh is not None and not sharded and not defn.mesh_replicated
        ):
            raise ValueError(
                f"optimizer {opt.name!r} does not support "
                f"{'sharded' if self.mesh is not None else 'batched'} "
                f"execution; batched-capable optimizers: {wave_capable_names()}"
            )
        if sharded:
            order, gains, evals, value = hook(
                self.rule,
                self.parts,
                b_arr,
                self.valid,
                max_budget,
                self.mesh,
                (self.batch_axis,),
                (self.data_axis,),
                stop_if_zero,
                stop_if_negative,
                **opt.params,
            )
            res = GreedyResult(order=order, gains=gains, n_evals=evals, value=value)
        else:
            res = hook(
                self.stacked,
                max_budget,
                b_arr,
                self.valid,
                stop_if_zero,
                stop_if_negative,
                **opt.params,
            )
        # one transfer for the whole batch, then host-side slicing — B tiny
        # device slices would dominate small-query serving latency
        order, gains, evals, value = jax.device_get(
            (res.order, res.gains, res.n_evals, res.value)
        )
        return [
            GreedyResult(
                order=order[i, :b],
                gains=gains[i, :b],
                n_evals=evals[i],
                value=value[i],
            )
            for i, b in enumerate(budgets)
        ]

    def maximize(
        self,
        budget: int | Sequence[int],
        optimizer: str = "NaiveGreedy",
        return_result: bool = False,
        max_budget: int | None = None,
        **kwargs,
    ) -> list:
        """Deprecated: delegate to :meth:`run` with an :class:`OptimizerSpec`
        built from ``optimizer`` + kwargs (unknown options now raise)."""
        from repro.core.optimizers.api import _warn_shim

        _warn_shim(
            "BatchedEngine.maximize()",
            "BatchedEngine.run(budgets, OptimizerSpec(...))",
        )
        opt, stop_zero, stop_neg = _legacy_optimizer_spec(optimizer, kwargs)
        results = self.run(
            budget,
            opt,
            stop_if_zero=stop_zero,
            stop_if_negative=stop_neg,
            max_budget=max_budget,
        )
        return results if return_result else [r.as_list() for r in results]


def _legacy_optimizer_spec(optimizer: str, kwargs: dict):
    """Split legacy ``**kwargs`` into (OptimizerSpec, stop_zero, stop_neg).

    Shared by the deprecated engine entry points: stop rules keep their old
    engine-level ``True`` defaults (family defaults are a spec-layer
    concern), everything else is validated as optimizer hyperparameters —
    so a misspelled flag raises instead of being silently dropped.
    """
    kwargs = dict(kwargs)
    stop_zero = bool(kwargs.pop("stopIfZeroGain", True))
    stop_neg = bool(kwargs.pop("stopIfNegativeGain", True))
    return OptimizerSpec(optimizer, **kwargs), stop_zero, stop_neg


def batched_maximize(
    fns: Sequence,
    budget: int | Sequence[int],
    optimizer: str = "NaiveGreedy",
    valid: jax.Array | None = None,
    return_result: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    batch_axis: str = "batch",
    data_axis: str = "data",
    **kwargs,
) -> list:
    """Deprecated one-shot wrapper: solve B selection problems in one jitted
    program.  Use ``solve([SelectionSpec(...), ...], mode="batched")`` (or
    ``mesh=`` for the sharded route); for a padded batch with a ``valid``
    mask, build a :class:`BatchedEngine` and call :meth:`BatchedEngine.run`.

    Args:
      fns: B same-family SetFunction instances (identical static meta).
      budget: shared int or per-instance sequence of ints.
      optimizer: "NaiveGreedy" or "LazyGreedy" (both also run sharded).
      valid: optional (B, n) bool — False marks padded candidates.
      return_result: True -> list of per-instance :class:`GreedyResult`
        (order/gains sliced to that instance's budget), False -> list of
        submodlib-style [(index, gain), ...] lists.
      mesh: optional 2-D mesh — shard the batch axis over ``batch_axis`` and
        the candidate axis over ``data_axis`` (the distributed batched form).
      kwargs: stopIfZeroGain / stopIfNegativeGain / optimizer
        hyperparameters (screen_k); unknown options raise ``TypeError``.
    """
    from repro.core.optimizers.api import _warn_shim

    _warn_shim(
        "batched_maximize()",
        'solve([SelectionSpec(...), ...], mode="batched")',
    )
    fns = list(fns)
    if not fns:
        return []
    opt, stop_zero, stop_neg = _legacy_optimizer_spec(optimizer, kwargs)
    engine = BatchedEngine(
        fns, valid=valid, mesh=mesh, batch_axis=batch_axis, data_axis=data_axis
    )
    results = engine.run(
        budget, opt, stop_if_zero=stop_zero, stop_if_negative=stop_neg
    )
    return results if return_result else [r.as_list() for r in results]
