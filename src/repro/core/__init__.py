# The paper's primary contribution: submodular functions, submodular
# information measures, and greedy maximizers — vectorized for TPU and
# distributable over a multi-pod mesh (see DESIGN.md §2, §5).
from repro.core.functions.base import SetFunction
from repro.core.functions.clustered import (
    cluster_mask,
    clustered,
    clustered_matrix_free,
)
from repro.core.functions.disparity import DisparityMin, DisparityMinSum, DisparitySum
from repro.core.functions.facility_location import FacilityLocation, FacilityLocationMF
from repro.core.functions.feature_based import FeatureBased
from repro.core.functions.graph_cut import GraphCut, GraphCutMF
from repro.core.functions.log_det import LogDet
from repro.core.functions.set_cover import ProbabilisticSetCover, SetCover
from repro.core.info.com import ConcaveOverModular
from repro.core.info.combinators import (
    ConditionedFunction,
    DifferenceFunction,
    generic_cg,
    generic_cmi,
    generic_mi,
)
from repro.core.info.fl import FLCG, FLCMI, FLQMI, FLVMI
from repro.core.info.gc import GCMI, gccg, gccmi
from repro.core.info.logdet import logdet_cg, logdet_cmi, logdet_mi
from repro.core.info.sc import psc_cg, psc_cmi, psc_mi, sc_cg, sc_cmi, sc_mi
from repro.core.optimizers.api import maximize
from repro.core.optimizers.spec import (
    OptimizerSpec,
    SelectionSpec,
    family_defaults,
    optimizer_names,
    register_family_defaults,
    register_optimizer,
    resolve_optimizer,
    solve,
    wave_capable_names,
)
from repro.core.optimizers.backends import (
    GainBackend,
    full_sweep,
    register_gain_backend,
    resolve_backend,
)
from repro.core.optimizers.batched import (
    BatchedEngine,
    batched_maximize,
    stack_functions,
)
from repro.core.optimizers.constrained import (
    Knapsack,
    PartitionMatroid,
    cover_greedy,
    knapsack_greedy,
    matroid_greedy,
)
from repro.core.optimizers.distributed import (
    distributed_fl_greedy,
    distributed_flqmi_greedy,
    register_shard_rule,
    shard_rule,
    sharded_batched_greedy,
    stack_parts,
)
from repro.core.optimizers.greedy import (
    GreedyResult,
    lazier_than_lazy_greedy,
    lazy_greedy,
    naive_greedy,
    stochastic_greedy,
)
from repro.core.optimizers.host_lazy import host_lazy_greedy
from repro.core.optimizers.streaming import sieve_streaming, threshold_greedy
from repro.core.similarity import (
    build_extended_kernel,
    create_kernel,
    kmeans,
    sparsify_topk,
)
from repro.core.sources import (
    DenseSource,
    FeatureSource,
    KnnSource,
    dense_source,
    feature_source,
    knn_from_features,
    knn_source,
)

__all__ = [
    "SetFunction",
    "FacilityLocation",
    "GraphCut",
    "LogDet",
    "SetCover",
    "ProbabilisticSetCover",
    "FeatureBased",
    "DisparitySum",
    "DisparityMin",
    "DisparityMinSum",
    "ConcaveOverModular",
    "clustered",
    "cluster_mask",
    "clustered_matrix_free",
    "FacilityLocationMF",
    "GraphCutMF",
    "FeatureSource",
    "KnnSource",
    "DenseSource",
    "feature_source",
    "knn_source",
    "knn_from_features",
    "dense_source",
    "FLVMI",
    "FLQMI",
    "FLCG",
    "FLCMI",
    "GCMI",
    "gccg",
    "gccmi",
    "logdet_mi",
    "logdet_cg",
    "logdet_cmi",
    "sc_mi",
    "sc_cg",
    "sc_cmi",
    "psc_mi",
    "psc_cg",
    "psc_cmi",
    "generic_mi",
    "generic_cg",
    "generic_cmi",
    "ConditionedFunction",
    "DifferenceFunction",
    "SelectionSpec",
    "OptimizerSpec",
    "solve",
    "register_optimizer",
    "register_family_defaults",
    "optimizer_names",
    "resolve_optimizer",
    "wave_capable_names",
    "family_defaults",
    "maximize",
    "batched_maximize",
    "BatchedEngine",
    "stack_functions",
    "GainBackend",
    "register_gain_backend",
    "resolve_backend",
    "full_sweep",
    "naive_greedy",
    "lazy_greedy",
    "stochastic_greedy",
    "lazier_than_lazy_greedy",
    "host_lazy_greedy",
    "cover_greedy",
    "knapsack_greedy",
    "matroid_greedy",
    "Knapsack",
    "PartitionMatroid",
    "sieve_streaming",
    "threshold_greedy",
    "distributed_fl_greedy",
    "distributed_flqmi_greedy",
    "sharded_batched_greedy",
    "shard_rule",
    "register_shard_rule",
    "stack_parts",
    "GreedyResult",
    "create_kernel",
    "build_extended_kernel",
    "sparsify_topk",
    "kmeans",
]
