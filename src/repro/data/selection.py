"""SubmodularSelector — the paper's technique as a training-pipeline stage.

Every ``rounds`` steps: embed a candidate pool with the current model, build
a similarity kernel (Pallas-backed), maximize a submodular function
(distributed partition greedy on the training mesh), train on the coreset.

Selection objectives (paper §1 applications):
  representative : FacilityLocation       — vanilla coreset ("efficient training")
  targeted       : FLQMI vs a query set   — "targeted learning"
  diverse        : DisparitySum           — diversity sampling
  privacy        : FLCG vs a private set  — "privacy-preserving selection"
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (
    FLCG,
    FLQMI,
    DisparitySum,
    FacilityLocation,
    create_kernel,
    naive_greedy,
    lazy_greedy,
    stochastic_greedy,
)


@dataclasses.dataclass
class SelectorConfig:
    objective: Literal["representative", "targeted", "diverse", "privacy"] = (
        "representative"
    )
    budget: int = 64
    metric: str = "euclidean"
    optimizer: str = "LazyGreedy"
    eta: float = 1.0
    nu: float = 1.0
    use_pallas_kernel: bool = True


class SubmodularSelector:
    def __init__(self, cfg: ArchConfig, sel: SelectorConfig):
        self.cfg = cfg
        self.sel = sel

    def build_function(
        self,
        pool_emb: jax.Array,
        query_emb: jax.Array | None = None,
        private_emb: jax.Array | None = None,
    ):
        mk = lambda x, y=None: create_kernel(
            x, y, metric=self.sel.metric, use_pallas=self.sel.use_pallas_kernel
        )
        if self.sel.objective == "representative":
            return FacilityLocation.from_kernel(mk(pool_emb))
        if self.sel.objective == "targeted":
            assert query_emb is not None
            return FLQMI.build(mk(query_emb, pool_emb), eta=self.sel.eta)
        if self.sel.objective == "diverse":
            sim = mk(pool_emb)
            dist = 1.0 / jnp.maximum(sim, 1e-6) - 1.0  # invert 1/(1+d)
            return DisparitySum.from_distance(dist)
        if self.sel.objective == "privacy":
            assert private_emb is not None
            return FLCG.build(mk(pool_emb), mk(pool_emb, private_emb), nu=self.sel.nu)
        raise ValueError(self.sel.objective)

    def select(
        self,
        pool_emb: jax.Array,
        query_emb: jax.Array | None = None,
        private_emb: jax.Array | None = None,
    ) -> np.ndarray:
        fn = self.build_function(pool_emb, query_emb, private_emb)
        budget = min(self.sel.budget, fn.n)
        if self.sel.optimizer == "LazyGreedy":
            res = lazy_greedy(fn, budget, 8, False, False)
        elif self.sel.optimizer == "StochasticGreedy":
            res = stochastic_greedy(
                fn, budget, jax.random.PRNGKey(0), 0.01, None, False, False
            )
        else:
            res = naive_greedy(fn, budget, False, False)
        order = np.asarray(jax.device_get(res.order))
        return order[order >= 0]

    def selection_step(self, pool_emb, mesh, budget: int | None = None):
        """Distributed selection on the training mesh (used by dryrun.py):
        the FL kernel rows/cols shard over the mesh and the greedy runs as a
        shard_map program with O(1)-payload winner elections (DESIGN §2)."""
        from repro.core import distributed_fl_greedy
        from repro.distributed.sharding import data_axes

        sim = create_kernel(pool_emb, metric=self.sel.metric)
        return distributed_fl_greedy(
            sim,
            budget or self.sel.budget,
            mesh,
            row_axes=("model",),
            col_axes=data_axes(mesh),
        )
