"""Data pipeline: synthetic deterministic token stream + the paper's
technique as a first-class stage — submodular coreset / targeted selection
over example embeddings (DESIGN §2 'what the framework adds')."""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


class SyntheticTokens:
    """Deterministic clustered token stream.

    Examples are drawn from ``n_modes`` latent modes (each mode = a Zipf-ish
    distribution over a vocab slice) so that subset selection has real
    structure to exploit: a representative coreset covers the modes."""

    def __init__(self, cfg: ArchConfig, seq_len: int, n_modes: int = 16, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.n_modes = n_modes
        self.seed = seed

    def example(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + idx)
        mode = idx % self.n_modes
        lo = (self.cfg.vocab * mode) // self.n_modes
        hi = (self.cfg.vocab * (mode + 1)) // self.n_modes
        # Zipf-ish: most mass on a few mode-anchor tokens, then the mode's
        # vocab slice, then global noise — gives the selection objectives a
        # strong mode signal in embedding space
        anchor_rng = np.random.default_rng(self.seed * 7919 + mode)
        anchors = anchor_rng.integers(lo, hi, 8)
        tok_anchor = anchors[rng.integers(0, 8, self.seq_len)]
        tok_local = rng.integers(lo, hi, self.seq_len)
        tok_noise = rng.integers(0, self.cfg.vocab, self.seq_len)
        u = rng.random(self.seq_len)
        return np.where(
            u < 0.7, tok_anchor, np.where(u < 0.9, tok_local, tok_noise)
        ).astype(np.int32)

    def mode_of(self, idx: int) -> int:
        return idx % self.n_modes

    def batch(self, indices) -> dict:
        toks = np.stack([self.example(int(i)) for i in indices])
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "audio":
            rng = np.random.default_rng(self.seed + 7)
            batch["frames"] = jnp.asarray(
                rng.normal(size=(len(indices), self.cfg.enc_positions, self.cfg.d_model)),
                jnp.float32,
            )
        if self.cfg.family == "vlm":
            rng = np.random.default_rng(self.seed + 11)
            batch["patches"] = jnp.asarray(
                rng.normal(size=(len(indices), self.cfg.n_patches, self.cfg.d_model)),
                jnp.float32,
            )
        return batch

    def stream(self, batch_size: int, start: int = 0) -> Iterator[dict]:
        i = start
        while True:
            yield self.batch(range(i, i + batch_size))
            i += batch_size


def embed_examples(cfg: ArchConfig, params, batch) -> jax.Array:
    """Mean-pooled final hidden states — the selection feature space.

    Architecture-agnostic: works for every assigned arch, which is why the
    paper's technique applies to all 10 (DESIGN §4)."""
    from repro.models.model import _backbone, _embed, _whisper_encode  # noqa

    tokens = batch["tokens"]
    B, L = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    if cfg.family == "audio":
        enc = _whisper_encode(cfg, params, batch["frames"])
        return enc.mean(axis=1).astype(jnp.float32)
    x = _embed(cfg, params, tokens)
    x = _backbone(cfg, params, x, positions)
    return x.mean(axis=1).astype(jnp.float32)
