"""Pallas TPU kernel: pairwise similarity (the paper's O(n^2 d) hotspot).

The paper's C++ engine builds the kernel element-wise; Table 5 shows it
dominating wall-time at scale.  On TPU the problem is matmul-shaped, so the
kernel is tiled for the MXU:

  grid = (n/BN, m/BM, d/BK), K innermost; each step multiplies a
  (BN, BK) x (BK, BM) tile pair on the MXU into an fp32 VMEM accumulator
  (the output block, revisited across the K steps), and the final K step
  applies the metric epilogue (cosine shift / euclidean / RBF) in-register —
  the distance matrix is never materialized in HBM.

VMEM working set at the default BN=BM=128, BK=512:
  x tile 128*512*4 + y tile 512*128*4 + out 128*128*4 ≈ 0.6 MiB  « 16 MiB.
MXU dims (128, 128, 512) are all multiples of the 128-lane width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 128  # rows per tile
BM = 128  # cols per tile
BK = 512  # contraction strip


def _sim_kernel(x_ref, y_ref, xx_ref, yy_ref, out_ref, *, metric, inv_two_sigma_sq, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)  # (BN, BK)
    y = y_ref[...].astype(jnp.float32)  # (BM, BK)
    out_ref[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = out_ref[...]
        if metric == "dot":
            return
        if metric == "cosine":
            # inputs arrive pre-normalized; shift to [0, 1]
            out_ref[...] = 0.5 * (1.0 + acc)
            return
        xx = xx_ref[...].astype(jnp.float32)  # (BN, 1)
        yy = yy_ref[...].astype(jnp.float32)  # (1, BM)
        d2 = jnp.maximum(xx + yy - 2.0 * acc, 0.0)
        if metric == "euclidean":
            out_ref[...] = 1.0 / (1.0 + jnp.sqrt(d2))
        else:  # rbf
            out_ref[...] = jnp.exp(-d2 * inv_two_sigma_sq)


def _pad_to(a: jax.Array, mult: int, axis: int, value=0.0) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("metric", "rbf_sigma", "interpret", "bn", "bm", "bk")
)
def similarity_pallas(
    x: jax.Array,
    y: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    interpret: bool = False,
    bn: int = BN,
    bm: int = BM,
    bk: int = BK,
) -> jax.Array:
    """(n, d), (m, d) -> (n, m) similarity in fp32."""
    n, d = x.shape
    m = y.shape[0]
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    if metric == "cosine":
        x32 = x32 / jnp.maximum(jnp.linalg.norm(x32, axis=1, keepdims=True), 1e-12)
        y32 = y32 / jnp.maximum(jnp.linalg.norm(y32, axis=1, keepdims=True), 1e-12)
    xp = _pad_to(_pad_to(x32, bn, 0), bk, 1)
    yp = _pad_to(_pad_to(y32, bm, 0), bk, 1)
    xx = (xp * xp).sum(axis=1, keepdims=True)  # (np, 1)
    yy = (yp * yp).sum(axis=1, keepdims=True).T  # (1, mp)
    npad, dp = xp.shape
    mpad = yp.shape[0]
    nk = dp // bk
    sigma = rbf_sigma if rbf_sigma is not None else float(d) ** 0.5
    grid = (npad // bn, mpad // bm, nk)
    out = pl.pallas_call(
        functools.partial(
            _sim_kernel,
            metric=metric,
            inv_two_sigma_sq=1.0 / (2.0 * sigma * sigma),
            nk=nk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bm), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, mpad), jnp.float32),
        interpret=interpret,
    )(xp, yp, xx, yy)
    return out[:n, :m]
