"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity_ref(
    x: jax.Array, y: jax.Array, metric: str = "dot", rbf_sigma: float | None = None
) -> jax.Array:
    """Pairwise similarity, (n, d) x (m, d) -> (n, m), fp32 accumulation."""
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    if metric == "dot":
        return x32 @ y32.T
    if metric == "cosine":
        xn = x32 / jnp.maximum(jnp.linalg.norm(x32, axis=1, keepdims=True), 1e-12)
        yn = y32 / jnp.maximum(jnp.linalg.norm(y32, axis=1, keepdims=True), 1e-12)
        return 0.5 * (1.0 + xn @ yn.T)
    d2 = jnp.maximum(
        (x32 * x32).sum(1)[:, None] + (y32 * y32).sum(1)[None, :] - 2.0 * x32 @ y32.T,
        0.0,
    )
    if metric == "euclidean":
        return 1.0 / (1.0 + jnp.sqrt(d2))
    if metric == "rbf":
        sigma = rbf_sigma if rbf_sigma is not None else float(x.shape[1]) ** 0.5
        return jnp.exp(-d2 / (2.0 * sigma * sigma))
    raise ValueError(f"unknown metric {metric!r}")


def fl_gains_ref(sim: jax.Array, curmax: jax.Array) -> jax.Array:
    """Facility-location marginal gains for all candidates.

    gains_j = sum_i max(S_ij - curmax_i, 0);  sim (u, n), curmax (u,) -> (n,)
    """
    s32 = sim.astype(jnp.float32)
    return jnp.maximum(s32 - curmax.astype(jnp.float32)[:, None], 0.0).sum(axis=0)


def fl_gains_update_ref(
    sim: jax.Array, curmax: jax.Array, winner: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused greedy step: gains, then the updated curmax for column ``winner``."""
    g = fl_gains_ref(sim, curmax)
    new_curmax = jnp.maximum(
        curmax.astype(jnp.float32), sim[:, winner].astype(jnp.float32)
    )
    return g, new_curmax
