"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity_ref(
    x: jax.Array, y: jax.Array, metric: str = "dot", rbf_sigma: float | None = None
) -> jax.Array:
    """Pairwise similarity, (n, d) x (m, d) -> (n, m), fp32 accumulation."""
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    if metric == "dot":
        return x32 @ y32.T
    if metric == "cosine":
        xn = x32 / jnp.maximum(jnp.linalg.norm(x32, axis=1, keepdims=True), 1e-12)
        yn = y32 / jnp.maximum(jnp.linalg.norm(y32, axis=1, keepdims=True), 1e-12)
        return 0.5 * (1.0 + xn @ yn.T)
    d2 = jnp.maximum(
        (x32 * x32).sum(1)[:, None] + (y32 * y32).sum(1)[None, :] - 2.0 * x32 @ y32.T,
        0.0,
    )
    if metric == "euclidean":
        return 1.0 / (1.0 + jnp.sqrt(d2))
    if metric == "rbf":
        sigma = rbf_sigma if rbf_sigma is not None else float(x.shape[1]) ** 0.5
        return jnp.exp(-d2 / (2.0 * sigma * sigma))
    raise ValueError(f"unknown metric {metric!r}")


def fl_gains_ref(sim: jax.Array, curmax: jax.Array) -> jax.Array:
    """Facility-location marginal gains for all candidates.

    gains_j = sum_i max(S_ij - curmax_i, 0);  sim (u, n), curmax (u,) -> (n,)
    """
    s32 = sim.astype(jnp.float32)
    return jnp.maximum(s32 - curmax.astype(jnp.float32)[:, None], 0.0).sum(axis=0)


def gc_gains_ref(
    sim: jax.Array, selmask: jax.Array, total: jax.Array, lam: jax.Array
) -> jax.Array:
    """Graph-cut marginal gains for all candidates from the selection mask.

    gains_j = total_j - lam * (2 * selsum_j + S_jj),
    selsum_j = sum_k S_jk * m_k;  sim (n, n), selmask/total (n,) -> (n,)
    """
    s32 = sim.astype(jnp.float32)
    selsum = (s32 * selmask.astype(jnp.float32)[None, :]).sum(axis=-1)
    diag = jnp.diagonal(s32)
    return total.astype(jnp.float32) - jnp.asarray(lam, jnp.float32) * (
        2.0 * selsum + diag
    )


def fb_gains_ref(
    feats: jax.Array, acc: jax.Array, w: jax.Array, concave: str = "sqrt"
) -> jax.Array:
    """Feature-based (concave-over-modular) gains for all candidates.

    gains_j = sum_f w_f * (g(acc_f + X_jf) - g(acc_f));  feats (n, F) -> (n,)
    """
    from repro.common import get_concave

    g = get_concave(concave)
    x32 = feats.astype(jnp.float32)
    a32 = acc.astype(jnp.float32)
    return ((g(a32[None, :] + x32) - g(a32)[None, :]) * w.astype(jnp.float32)).sum(
        axis=1
    )


def sc_gains_ref(cover: jax.Array, covered: jax.Array, w: jax.Array) -> jax.Array:
    """Set-cover marginal gains for all candidates from the covered indicator.

    gains_j = sum_u w_u * max(G_ju - covered_u, 0);  cover (n, m) -> (n,)
    """
    g32 = cover.astype(jnp.float32)
    new = jnp.maximum(g32 - covered.astype(jnp.float32)[None, :], 0.0)
    return (new * w.astype(jnp.float32)[None, :]).sum(axis=-1)


def psc_gains_ref(probs: jax.Array, miss: jax.Array, w: jax.Array) -> jax.Array:
    """Probabilistic-set-cover gains from the memoized miss probabilities.

    gains_j = sum_u w_u * Pbar_u(A) * p_ju;  probs (n, m), miss/w (m,) -> (n,)
    """
    p32 = probs.astype(jnp.float32)
    wm = w.astype(jnp.float32) * miss.astype(jnp.float32)
    return (p32 * wm[None, :]).sum(axis=-1)


def dsum_gains_ref(dist: jax.Array, selmask: jax.Array) -> jax.Array:
    """Disparity-sum gains from the selection mask.

    gains_j = sum_k d_jk * m_k;  dist (n, n), selmask (n,) -> (n,)
    """
    d32 = dist.astype(jnp.float32)
    return (d32 * selmask.astype(jnp.float32)[None, :]).sum(axis=-1)


def dmin_gains_ref(
    dist: jax.Array, selmask: jax.Array, count: jax.Array, curmin: jax.Array
) -> jax.Array:
    """Disparity-min surrogate gains (farthest-point rule) from the mask.

    gains_j = min(surr_j, BIG) - curmin,  surr_j = 0 if count == 0 else
    min_{k: m_k} d_jk;  dist (n, n), selmask (n,), count/curmin scalars -> (n,)
    """
    big = 1e30
    d32 = dist.astype(jnp.float32)
    vals = jnp.where(selmask.astype(bool)[None, :], d32, big)
    mind = jnp.min(vals, axis=1)
    surrogate = jnp.where(jnp.asarray(count) == 0, 0.0, mind)
    return jnp.minimum(surrogate, big) - jnp.asarray(curmin, jnp.float32)


def _subset(full: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather a full-sweep oracle at ``idx``; idx < 0 slots return NEG_INF
    (the masked-subset entry-point contract)."""
    from repro.common import NEG_INF

    safe = jnp.clip(idx, 0, full.shape[0] - 1)
    return jnp.where(idx >= 0, full[safe], NEG_INF)


def fl_gains_at_ref(sim, curmax, idx) -> jax.Array:
    """Subset oracle: ``fl_gains_ref`` gathered at ``idx`` (k,) -> (k,)."""
    return _subset(fl_gains_ref(sim, curmax), idx)


def gc_gains_at_ref(sim, selmask, total, lam, idx) -> jax.Array:
    """Subset oracle: ``gc_gains_ref`` gathered at ``idx`` (k,) -> (k,)."""
    return _subset(gc_gains_ref(sim, selmask, total, lam), idx)


def fb_gains_at_ref(feats, acc, w, idx, concave: str = "sqrt") -> jax.Array:
    """Subset oracle: ``fb_gains_ref`` gathered at ``idx`` (k,) -> (k,)."""
    return _subset(fb_gains_ref(feats, acc, w, concave), idx)


def flmf_gains_ref(
    x: jax.Array,
    y: jax.Array,
    curmax: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
) -> jax.Array:
    """Matrix-free FL oracle: materialize the similarity, then sweep.

    x (u, d), y (n, d), curmax (u,) -> (n,).  The tested kernels never
    build the (u, n) matrix; this reference deliberately does.
    """
    return fl_gains_ref(similarity_ref(x, y, metric, rbf_sigma), curmax)


def gcmf_gains_ref(
    y: jax.Array,
    selmask: jax.Array,
    total: jax.Array,
    lam: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    diag: jax.Array | None = None,
) -> jax.Array:
    """Matrix-free GC oracle: materialize the ground kernel, then sweep.

    ``diag`` defaults to the materialized kernel's diagonal; pass the
    precomputed statistic to match the fused kernel bit-for-bit.
    """
    sim = similarity_ref(y, y, metric, rbf_sigma)
    s32 = sim.astype(jnp.float32)
    selsum = (s32 * selmask.astype(jnp.float32)[None, :]).sum(axis=-1)
    dg = jnp.diagonal(s32) if diag is None else diag.astype(jnp.float32)
    return total.astype(jnp.float32) - jnp.asarray(lam, jnp.float32) * (
        2.0 * selsum + dg
    )


def flmf_gains_at_ref(x, y, curmax, idx, metric="dot", rbf_sigma=None) -> jax.Array:
    """Subset oracle: ``flmf_gains_ref`` gathered at ``idx`` (k,) -> (k,)."""
    return _subset(flmf_gains_ref(x, y, curmax, metric, rbf_sigma), idx)


def gcmf_gains_at_ref(
    y, selmask, total, lam, idx, metric="dot", rbf_sigma=None, diag=None
) -> jax.Array:
    """Subset oracle: ``gcmf_gains_ref`` gathered at ``idx`` (k,) -> (k,)."""
    return _subset(
        gcmf_gains_ref(y, selmask, total, lam, metric, rbf_sigma, diag), idx
    )


def fl_gains_update_ref(
    sim: jax.Array, curmax: jax.Array, winner: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused greedy step: gains, then the updated curmax for column ``winner``."""
    g = fl_gains_ref(sim, curmax)
    new_curmax = jnp.maximum(
        curmax.astype(jnp.float32), sim[:, winner].astype(jnp.float32)
    )
    return g, new_curmax
