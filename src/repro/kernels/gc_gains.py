"""Pallas TPU kernel: fused graph-cut marginal-gain sweep.

For Graph Cut (paper §2.1.2) the marginal gain of candidate j given the
selection indicator m (m_k = 1 iff k in A) is

    gains_j = total_j - lam * (2 * selsum_j + S_jj),
    selsum_j = sum_k S_jk * m_k

This kernel recomputes the sweep FROM THE SELECTION MASK in one fused pass:
each (BJ x BK) tile of S streams through VMEM exactly once, contributing
sum_k S_jk * (2*m_k + [j == k])  (masked matvec + diagonal extraction) to a
(1, BJ) accumulator that is finalized to  total - lam * acc  on the last K
strip.  grid = (n/BJ, n/BK) with K innermost; ``lam`` rides along in SMEM.

Trade-off vs the memoized path: GraphCut's incremental ``selsum`` statistic
makes a gain sweep O(n) elementwise, which is cheaper inside a greedy loop
that updates state every step.  This kernel is O(n^2) streamed once, but
STATELESS — it answers a sweep from just (S, mask), which is the shape
one-shot scoring and serving paths want (no per-query memoized state to
keep resident).  See GraphCut.gain_backend for routing.

``gc_gains_at_pallas`` is the masked-subset entry point (the lazy engines'
``partial_sweep`` contract): an XLA gather of the K requested kernel ROWS
feeds the same masked-matvec tile stream, with the rows' GLOBAL indices
riding along so the in-stream ``[j == k]`` diagonal fold — and therefore the
per-row accumulation order, and the floats — match the full sweep exactly.
Slots with idx < 0 are padding and return NEG_INF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import NEG_INF

BJ = 256  # candidate columns of the output per tile
BK = 256  # summed-over ground elements per tile


def _gc_kernel(lam_ref, s_ref, m_ref, tot_ref, out_ref, *, nk, bj, bk):
    jblk = pl.program_id(0)
    kblk = pl.program_id(1)

    @pl.when(kblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = s_ref[...].astype(jnp.float32)  # (BJ, BK) rows j, cols k
    m = m_ref[...].astype(jnp.float32)  # (1, BK)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bj, bk), 0) + jblk * bj
    cols = jax.lax.broadcasted_iota(jnp.int32, (bj, bk), 1) + kblk * bk
    w = 2.0 * m + jnp.where(rows == cols, 1.0, 0.0)  # (BJ, BK)
    out_ref[...] += (s * w).sum(axis=1)[None, :]

    @pl.when(kblk == nk - 1)
    def _finalize():
        lam = lam_ref[0]
        tot = tot_ref[...].astype(jnp.float32)  # (1, BJ)
        out_ref[...] = tot - lam * out_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "bj", "bk"))
def gc_gains_pallas(
    sim: jax.Array,
    selmask: jax.Array,
    total: jax.Array,
    lam: jax.Array,
    interpret: bool = False,
    bj: int = BJ,
    bk: int = BK,
) -> jax.Array:
    """sim (n, n) ground kernel, selmask (n,) 0/1 selection indicator,
    total (n,) modular representation term, lam scalar -> gains (n,) fp32."""
    n = sim.shape[0]
    pad_j = (-n) % bj
    pad_k = (-n) % bk
    sp = jnp.pad(sim, ((0, pad_j), (0, pad_k)))
    mp = jnp.pad(selmask.astype(jnp.float32)[None, :], ((0, 0), (0, pad_k)))
    tp = jnp.pad(total.astype(jnp.float32)[None, :], ((0, 0), (0, pad_j)))
    npj, npk = sp.shape
    nk = npk // bk
    lam_s = jnp.asarray(lam, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_gc_kernel, nk=nk, bj=bj, bk=bk),
        grid=(npj // bj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bj, bk), lambda j, k: (j, k)),
            pl.BlockSpec((1, bk), lambda j, k: (0, k)),
            pl.BlockSpec((1, bj), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npj), jnp.float32),
        interpret=interpret,
    )(lam_s, sp, mp, tp)
    return out[0, :n]


def _gc_at_kernel(lam_ref, s_ref, m_ref, tot_ref, gid_ref, out_ref, *, nk, bj, bk):
    kblk = pl.program_id(1)

    @pl.when(kblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = s_ref[...].astype(jnp.float32)  # (BJ, BK) gathered candidate rows
    m = m_ref[...].astype(jnp.float32)  # (1, BK)
    gid = gid_ref[...]  # (BJ, 1) global row ids of the gathered candidates
    cols = jax.lax.broadcasted_iota(jnp.int32, (bj, bk), 1) + kblk * bk
    w = 2.0 * m + jnp.where(gid == cols, 1.0, 0.0)  # (BJ, BK)
    out_ref[...] += (s * w).sum(axis=1)[None, :]

    @pl.when(kblk == nk - 1)
    def _finalize():
        lam = lam_ref[0]
        tot = tot_ref[...].astype(jnp.float32)  # (1, BJ)
        out_ref[...] = tot - lam * out_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "bk"))
def gc_gains_at_pallas(
    sim: jax.Array,
    selmask: jax.Array,
    total: jax.Array,
    lam: jax.Array,
    idx: jax.Array,
    interpret: bool = False,
    bk: int = BK,
) -> jax.Array:
    """Masked-subset sweep: gains at the gathered candidates ``idx`` (k,)
    int32 -> (k,) fp32; slots with idx < 0 are padding and return NEG_INF."""
    n = sim.shape[0]
    (k,) = idx.shape
    from repro.kernels.fl_gains import _subset_tile

    bj = _subset_tile(k, BJ)
    safe = jnp.clip(idx, 0, n - 1)
    rows = jnp.take(sim, safe, axis=0)  # (k, n) gather feeding the fused sweep
    pad_j = (-k) % bj
    pad_k = (-n) % bk
    sp = jnp.pad(rows, ((0, pad_j), (0, pad_k)))
    mp = jnp.pad(selmask.astype(jnp.float32)[None, :], ((0, 0), (0, pad_k)))
    tp = jnp.pad(total[safe].astype(jnp.float32)[None, :], ((0, 0), (0, pad_j)))
    # padded slots get gid -1: never equal to a column id, so no diag term
    gp = jnp.pad(safe[:, None], ((0, pad_j), (0, 0)), constant_values=-1)
    npj, npk = sp.shape
    nk = npk // bk
    lam_s = jnp.asarray(lam, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_gc_at_kernel, nk=nk, bj=bj, bk=bk),
        grid=(npj // bj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bj, bk), lambda j, kb: (j, kb)),
            pl.BlockSpec((1, bk), lambda j, kb: (0, kb)),
            pl.BlockSpec((1, bj), lambda j, kb: (0, j)),
            pl.BlockSpec((bj, 1), lambda j, kb: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, kb: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npj), jnp.float32),
        interpret=interpret,
    )(lam_s, sp, mp, tp, gp)
    return jnp.where(idx >= 0, out[0, :k], NEG_INF)
