"""Pallas TPU kernel: matrix-free fused graph-cut gain sweep.

Stateless Graph-Cut sweep (``gc_gains.py`` semantics) with the similarity
computed in-stream from feature tiles instead of read from a materialized
(n, n) kernel:

    gains_j = total_j - lam * (2 * selsum_j + diag_j),
    selsum_j = sum_k sim(y_j, y_k) * m_k

Each (BJ, BKC) similarity block is built on the MXU from d-strips of the
candidate rows ``y_j`` and ground columns ``y_k`` (fp32 VMEM scratch,
metric epilogue in-register, exactly the ``similarity_kernel.py`` tiling)
and immediately collapsed into the masked matvec — HBM traffic stays at
the O(n * d) feature bytes.

``diag`` and ``total`` arrive precomputed (they are the memoized Graph-Cut
statistics :class:`~repro.core.functions.graph_cut.GraphCutMF` already
holds), so the stateless sweep agrees with the memoized gains on the same
diagonal instead of re-deriving sim(j, j) from a d2 = 0 roundtrip.

grid = (n/BJ, n/BKC, d/BKD), contraction strip innermost; the (1, BJ)
output accumulates selsum over the BKC steps and is finalized to
``total - lam * (2 * selsum + diag)`` on the last (k, d) step.  Ground
padding is exact: pad columns carry m = 0, so their (possibly nonzero
zero-feature) similarity contributes nothing.

``gcmf_gains_at_pallas`` gathers the K requested candidate rows (plus
their ``total``/``diag`` entries) and runs the same stream sized to the
subset; idx < 0 slots are padding and return NEG_INF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import NEG_INF

BJ = 256  # candidate rows of the output per tile
BKC = 256  # summed-over ground elements per tile
BKD = 512  # feature-contraction strip


def _gcmf_kernel(
    lam_ref, yj_ref, yk_ref, yyj_ref, yyk_ref, m_ref, tot_ref, diag_ref,
    out_ref, acc_ref, *, metric, inv_two_sigma_sq, nkc, nd,
):
    kc = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when((kc == 0) & (kd == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(kd == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    yj = yj_ref[...].astype(jnp.float32)  # (BJ, BKD)
    yk = yk_ref[...].astype(jnp.float32)  # (BKC, BKD)
    acc_ref[...] += jax.lax.dot_general(
        yj, yk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(kd == nd - 1)
    def _fold():
        acc = acc_ref[...]  # (BJ, BKC)
        if metric == "dot":
            s = acc
        elif metric == "cosine":
            s = 0.5 * (1.0 + acc)
        else:
            yyj = yyj_ref[...].astype(jnp.float32)  # (BJ, 1)
            yyk = yyk_ref[...].astype(jnp.float32)  # (1, BKC)
            d2 = jnp.maximum(yyj + yyk - 2.0 * acc, 0.0)
            if metric == "euclidean":
                s = 1.0 / (1.0 + jnp.sqrt(d2))
            else:  # rbf
                s = jnp.exp(-d2 * inv_two_sigma_sq)
        m = m_ref[...].astype(jnp.float32)  # (1, BKC)
        out_ref[...] += (s * m).sum(axis=1)[None, :]

    @pl.when((kc == nkc - 1) & (kd == nd - 1))
    def _finalize():
        lam = lam_ref[0]
        tot = tot_ref[...].astype(jnp.float32)  # (1, BJ)
        dg = diag_ref[...].astype(jnp.float32)  # (1, BJ)
        out_ref[...] = tot - lam * (2.0 * out_ref[...] + dg)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "rbf_sigma", "interpret", "bj", "bkc", "bkd"),
)
def gcmf_gains_pallas(
    yj: jax.Array,
    yk: jax.Array,
    yyj: jax.Array,
    yyk: jax.Array,
    selmask: jax.Array,
    total: jax.Array,
    diag: jax.Array,
    lam: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    interpret: bool = False,
    bj: int = BJ,
    bkc: int = BKC,
    bkd: int = BKD,
) -> jax.Array:
    """Candidate rows yj (j, d) vs ground yk (n, d) with squared norms
    yyj/yyk, selection mask (n,), total/diag (j,), lam scalar -> (j,) fp32."""
    j, d = yj.shape
    n = yk.shape[0]
    pad_j = (-j) % bj
    pad_k = (-n) % bkc
    pad_d = (-d) % bkd
    yjp = jnp.pad(yj.astype(jnp.float32), ((0, pad_j), (0, pad_d)))
    ykp = jnp.pad(yk.astype(jnp.float32), ((0, pad_k), (0, pad_d)))
    yyjp = jnp.pad(yyj.astype(jnp.float32)[:, None], ((0, pad_j), (0, 0)))
    yykp = jnp.pad(yyk.astype(jnp.float32)[None, :], ((0, 0), (0, pad_k)))
    mp = jnp.pad(selmask.astype(jnp.float32)[None, :], ((0, 0), (0, pad_k)))
    tp = jnp.pad(total.astype(jnp.float32)[None, :], ((0, 0), (0, pad_j)))
    dgp = jnp.pad(diag.astype(jnp.float32)[None, :], ((0, 0), (0, pad_j)))
    jp, dp = yjp.shape
    nkc = ykp.shape[0] // bkc
    nd = dp // bkd
    sigma = rbf_sigma if rbf_sigma is not None else float(d) ** 0.5
    lam_s = jnp.asarray(lam, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(
            _gcmf_kernel,
            metric=metric,
            inv_two_sigma_sq=1.0 / (2.0 * sigma * sigma),
            nkc=nkc,
            nd=nd,
        ),
        grid=(jp // bj, nkc, nd),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bj, bkd), lambda jb, kc, kd: (jb, kd)),
            pl.BlockSpec((bkc, bkd), lambda jb, kc, kd: (kc, kd)),
            pl.BlockSpec((bj, 1), lambda jb, kc, kd: (jb, 0)),
            pl.BlockSpec((1, bkc), lambda jb, kc, kd: (0, kc)),
            pl.BlockSpec((1, bkc), lambda jb, kc, kd: (0, kc)),
            pl.BlockSpec((1, bj), lambda jb, kc, kd: (0, jb)),
            pl.BlockSpec((1, bj), lambda jb, kc, kd: (0, jb)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda jb, kc, kd: (0, jb)),
        out_shape=jax.ShapeDtypeStruct((1, jp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bj, bkc), jnp.float32)],
        interpret=interpret,
    )(lam_s, yjp, ykp, yyjp, yykp, mp, tp, dgp)
    return out[0, :j]


@functools.partial(
    jax.jit, static_argnames=("metric", "rbf_sigma", "interpret", "bkc", "bkd")
)
def gcmf_gains_at_pallas(
    y: jax.Array,
    yy: jax.Array,
    selmask: jax.Array,
    total: jax.Array,
    diag: jax.Array,
    lam: jax.Array,
    idx: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    interpret: bool = False,
    bkc: int = BKC,
    bkd: int = BKD,
) -> jax.Array:
    """Masked-subset sweep: gains at the gathered candidates ``idx`` (k,)
    int32 -> (k,) fp32; slots with idx < 0 are padding and return NEG_INF.

    The candidate-row tile stays at the full-sweep width BJ (the
    similarity contraction is recomputed in-stream; see flmf_gains)."""
    safe = jnp.clip(idx, 0, y.shape[0] - 1)
    out = gcmf_gains_pallas(
        jnp.take(y, safe, axis=0),
        y,
        jnp.take(yy, safe),
        yy,
        selmask,
        jnp.take(total, safe),
        jnp.take(diag, safe),
        lam,
        metric=metric,
        rbf_sigma=rbf_sigma,
        interpret=interpret,
        bj=BJ,
        bkc=bkc,
        bkd=bkd,
    )
    return jnp.where(idx >= 0, out, NEG_INF)
