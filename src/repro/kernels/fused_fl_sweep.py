"""Pallas TPU kernel: FUSED similarity + facility-location gain sweep.

Beyond-paper (EXPERIMENTS §Perf-3/C3): the paper materializes the O(n^2)
kernel, then sweeps it every greedy round. This kernel computes, for every
candidate j,

    gains_j = sum_i max( sim(x_i, y_j) - curmax_i, 0 )

directly from the embeddings: each (BU x BN) similarity tile lives only in
a VMEM scratch accumulator across the K strips and is reduced in-register.
Per-sweep HBM traffic drops from O(u*n) kernel bytes to O((u+n)*d)
embedding bytes — for u=16384, n=1M, d=256 that is 64 GB -> 1.3 GB, and the
kernel matrix never exists at all (no 4 TB materialization for 1M x 1M).

grid = (n/BN, u/BU, d/BK), K innermost; dot metric (callers pre-normalize
for cosine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BU = 256
BN = 256
BK = 256

_PAD_CM = 3.0e38


def _fused_kernel(x_ref, y_ref, cm_ref, out_ref, s_acc, *, nk):
    k = pl.program_id(2)
    u = pl.program_id(1)

    @pl.when((u == 0) & (k == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(k == 0)
    def _init_tile():
        s_acc[...] = jnp.zeros_like(s_acc)

    x = x_ref[...].astype(jnp.float32)  # (BU, BK)
    y = y_ref[...].astype(jnp.float32)  # (BN, BK)
    s_acc[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _reduce():
        cm = cm_ref[...].astype(jnp.float32)  # (BU, 1)
        out_ref[...] += jnp.maximum(s_acc[...] - cm, 0.0).sum(axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "bu", "bn", "bk"))
def fused_fl_sweep_pallas(
    x: jax.Array,
    y: jax.Array,
    curmax: jax.Array,
    interpret: bool = False,
    bu: int = BU,
    bn: int = BN,
    bk: int = BK,
) -> jax.Array:
    """x (u, d) represented embeddings, y (n, d) candidates, curmax (u,)
    -> gains (n,) fp32, dot-product similarity."""
    u, d = x.shape
    n = y.shape[0]

    def pad(a, mult, axis, value=0.0):
        p = (-a.shape[axis]) % mult
        if p == 0:
            return a
        w = [(0, 0)] * a.ndim
        w[axis] = (0, p)
        return jnp.pad(a, w, constant_values=value)

    xp = pad(pad(x, bu, 0), bk, 1)
    yp = pad(pad(y, bn, 0), bk, 1)
    cmp_ = pad(curmax.astype(jnp.float32)[:, None], bu, 0, value=_PAD_CM)
    up, dp = xp.shape
    npad = yp.shape[0]
    nk = dp // bk
    out = pl.pallas_call(
        functools.partial(_fused_kernel, nk=nk),
        grid=(npad // bn, up // bu, nk),
        in_specs=[
            pl.BlockSpec((bu, bk), lambda j, i, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda j, i, k: (j, k)),
            pl.BlockSpec((bu, 1), lambda j, i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bu, bn), jnp.float32)],
        interpret=interpret,
    )(xp, yp, cmp_)
    return out[0, :n]


def fused_fl_sweep_ref(x, y, curmax):
    """Pure-jnp oracle."""
    s = x.astype(jnp.float32) @ y.astype(jnp.float32).T
    return jnp.maximum(s - curmax.astype(jnp.float32)[:, None], 0.0).sum(axis=0)
