"""Public jit'd wrappers around the Pallas kernels.

On TPU the compiled kernels run natively; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body in Python and is what
the allclose test-suite validates against the ``ref.py`` oracles.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.fb_gains import fb_gains_pallas
from repro.kernels.fl_gains import fl_gains_pallas
from repro.kernels.gc_gains import gc_gains_pallas
from repro.kernels.similarity_kernel import similarity_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def similarity(x, y, metric: str = "dot", rbf_sigma: float | None = None):
    return similarity_pallas(
        x, y, metric=metric, rbf_sigma=rbf_sigma, interpret=_interpret()
    )


def fl_gains(sim, curmax):
    return fl_gains_pallas(sim, curmax, interpret=_interpret())


def gc_gains(sim, selmask, total, lam):
    return gc_gains_pallas(sim, selmask, total, lam, interpret=_interpret())


def fb_gains(feats, acc, w, concave: str = "sqrt"):
    return fb_gains_pallas(feats, acc, w, concave=concave, interpret=_interpret())


# re-export oracles for convenience
similarity_ref = ref.similarity_ref
fl_gains_ref = ref.fl_gains_ref
gc_gains_ref = ref.gc_gains_ref
fb_gains_ref = ref.fb_gains_ref
