"""Public jit'd wrappers around the Pallas kernels.

On TPU the compiled kernels run natively; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body in Python and is what
the allclose test-suite validates against the ``ref.py`` oracles.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.disp_gains import dmin_gains_pallas, dsum_gains_pallas
from repro.kernels.fb_gains import fb_gains_at_pallas, fb_gains_pallas
from repro.kernels.fl_gains import fl_gains_at_pallas, fl_gains_pallas
from repro.kernels.flmf_gains import flmf_gains_at_pallas, flmf_gains_pallas
from repro.kernels.gc_gains import gc_gains_at_pallas, gc_gains_pallas
from repro.kernels.gcmf_gains import gcmf_gains_at_pallas, gcmf_gains_pallas
from repro.kernels.sc_gains import psc_gains_pallas, sc_gains_pallas
from repro.kernels.similarity_kernel import similarity_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def similarity(x, y, metric: str = "dot", rbf_sigma: float | None = None):
    return similarity_pallas(
        x, y, metric=metric, rbf_sigma=rbf_sigma, interpret=_interpret()
    )


def fl_gains(sim, curmax):
    return fl_gains_pallas(sim, curmax, interpret=_interpret())


def fl_gains_at(sim, curmax, idx):
    return fl_gains_at_pallas(sim, curmax, idx, interpret=_interpret())


def gc_gains(sim, selmask, total, lam):
    return gc_gains_pallas(sim, selmask, total, lam, interpret=_interpret())


def gc_gains_at(sim, selmask, total, lam, idx):
    return gc_gains_at_pallas(sim, selmask, total, lam, idx, interpret=_interpret())


def flmf_gains(x, y, xx, yy, curmax, metric: str = "dot", rbf_sigma: float | None = None):
    return flmf_gains_pallas(
        x, y, xx, yy, curmax, metric=metric, rbf_sigma=rbf_sigma,
        interpret=_interpret(),
    )


def flmf_gains_at(
    x, y, xx, yy, curmax, idx, metric: str = "dot", rbf_sigma: float | None = None
):
    return flmf_gains_at_pallas(
        x, y, xx, yy, curmax, idx, metric=metric, rbf_sigma=rbf_sigma,
        interpret=_interpret(),
    )


def gcmf_gains(
    y, yy, selmask, total, diag, lam,
    metric: str = "dot", rbf_sigma: float | None = None,
):
    return gcmf_gains_pallas(
        y, y, yy, yy, selmask, total, diag, lam,
        metric=metric, rbf_sigma=rbf_sigma, interpret=_interpret(),
    )


def gcmf_gains_at(
    y, yy, selmask, total, diag, lam, idx,
    metric: str = "dot", rbf_sigma: float | None = None,
):
    return gcmf_gains_at_pallas(
        y, yy, selmask, total, diag, lam, idx,
        metric=metric, rbf_sigma=rbf_sigma, interpret=_interpret(),
    )


def fb_gains(feats, acc, w, concave: str = "sqrt"):
    return fb_gains_pallas(feats, acc, w, concave=concave, interpret=_interpret())


def fb_gains_at(feats, acc, w, idx, concave: str = "sqrt"):
    return fb_gains_at_pallas(
        feats, acc, w, idx, concave=concave, interpret=_interpret()
    )


def sc_gains(cover, covered, w):
    return sc_gains_pallas(cover, covered, w, interpret=_interpret())


def psc_gains(probs, miss, w):
    return psc_gains_pallas(probs, miss, w, interpret=_interpret())


def dsum_gains(dist, selmask):
    return dsum_gains_pallas(dist, selmask, interpret=_interpret())


def dmin_gains(dist, selmask, count, curmin):
    return dmin_gains_pallas(dist, selmask, count, curmin, interpret=_interpret())


# re-export oracles for convenience
similarity_ref = ref.similarity_ref
fl_gains_ref = ref.fl_gains_ref
gc_gains_ref = ref.gc_gains_ref
flmf_gains_ref = ref.flmf_gains_ref
gcmf_gains_ref = ref.gcmf_gains_ref
flmf_gains_at_ref = ref.flmf_gains_at_ref
gcmf_gains_at_ref = ref.gcmf_gains_at_ref
fb_gains_ref = ref.fb_gains_ref
fl_gains_at_ref = ref.fl_gains_at_ref
gc_gains_at_ref = ref.gc_gains_at_ref
fb_gains_at_ref = ref.fb_gains_at_ref
sc_gains_ref = ref.sc_gains_ref
psc_gains_ref = ref.psc_gains_ref
dsum_gains_ref = ref.dsum_gains_ref
dmin_gains_ref = ref.dmin_gains_ref
