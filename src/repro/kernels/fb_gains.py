"""Pallas TPU kernel: fused feature-based (concave-over-modular) gain sweep.

For the Feature-Based function (paper §2.3.3) with memoized feature mass
``acc_f = m_f(A)``, the marginal gain of every candidate j is

    gains_j = sum_f w_f * ( g(acc_f + X_jf) - g(acc_f) )

with g a concave scalarizer (sqrt / log1p / inverse).  XLA materializes the
(n, F) concave intermediate in HBM; this kernel streams each (BN x BF) tile
of the feature matrix through VMEM once and fuses add -> concave -> weighted
row-reduce in-register on the VPU, accumulating the F strips into a (1, BN)
output block.

grid = (n/BN, F/BF), F innermost; the concave name is a static kernel param.

``fb_gains_at_pallas`` is the masked-subset entry point (the lazy engines'
``partial_sweep`` contract): an XLA gather of the K requested feature rows
feeds the same fused add -> concave -> weighted-reduce tile stream, sized to
the subset.  Per-row F-strip accumulation is independent of the other rows,
so subset values are bit-identical to the full sweep's at the same indices.
Slots with idx < 0 are padding and return NEG_INF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import NEG_INF, get_concave

BN = 256  # candidates per tile
BF = 256  # features per tile


def _fb_kernel(x_ref, acc_ref, w_ref, out_ref, *, concave):
    fblk = pl.program_id(1)

    @pl.when(fblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = get_concave(concave)
    x = x_ref[...].astype(jnp.float32)  # (BN, BF)
    a = acc_ref[...].astype(jnp.float32)  # (1, BF)
    w = w_ref[...].astype(jnp.float32)  # (1, BF)
    out_ref[...] += ((g(a + x) - g(a)) * w).sum(axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("concave", "interpret", "bn", "bf"))
def fb_gains_pallas(
    feats: jax.Array,
    acc: jax.Array,
    w: jax.Array,
    concave: str = "sqrt",
    interpret: bool = False,
    bn: int = BN,
    bf: int = BF,
) -> jax.Array:
    """feats (n, F) non-negative scores, acc (F,) memoized mass, w (F,)
    weights -> gains (n,) fp32.  Padded features get w = 0 so contribute 0."""
    n, F = feats.shape
    pad_n = (-n) % bn
    pad_f = (-F) % bf
    xp = jnp.pad(feats, ((0, pad_n), (0, pad_f)))
    ap = jnp.pad(acc.astype(jnp.float32)[None, :], ((0, 0), (0, pad_f)))
    wp = jnp.pad(w.astype(jnp.float32)[None, :], ((0, 0), (0, pad_f)))
    npn, npf = xp.shape
    out = pl.pallas_call(
        functools.partial(_fb_kernel, concave=concave),
        grid=(npn // bn, npf // bf),
        in_specs=[
            pl.BlockSpec((bn, bf), lambda j, f: (j, f)),
            pl.BlockSpec((1, bf), lambda j, f: (0, f)),
            pl.BlockSpec((1, bf), lambda j, f: (0, f)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, f: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npn), jnp.float32),
        interpret=interpret,
    )(xp, ap, wp)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("concave", "interpret"))
def fb_gains_at_pallas(
    feats: jax.Array,
    acc: jax.Array,
    w: jax.Array,
    idx: jax.Array,
    concave: str = "sqrt",
    interpret: bool = False,
) -> jax.Array:
    """Masked-subset sweep: feats (n, F), acc (F,), w (F,), idx (k,) int32 ->
    gains (k,) fp32; slots with idx < 0 are padding and return NEG_INF."""
    from repro.kernels.fl_gains import _subset_tile

    (k,) = idx.shape
    safe = jnp.clip(idx, 0, feats.shape[0] - 1)
    rows = jnp.take(feats, safe, axis=0)  # (k, F) gather feeding the fused sweep
    out = fb_gains_pallas(
        rows, acc, w, concave=concave, interpret=interpret, bn=_subset_tile(k, BN)
    )
    return jnp.where(idx >= 0, out, NEG_INF)
