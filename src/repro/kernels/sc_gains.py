"""Pallas TPU kernels: fused Set-Cover family gain sweeps.

Both covers maintain an O(m) memoized statistic over the concept axis
(paper Table 3), so a full candidate sweep is one pass over the (n, m)
concept-incidence matrix:

  SetCover             gains_j = sum_u w_u * max(G_ju - covered_u, 0)
  ProbabilisticSetCover gains_j = sum_u w_u * Pbar_u(A) * p_ju

XLA materializes the (n, m) relu / product intermediate in HBM; these
kernels stream each (BN x BM) tile of the incidence matrix through VMEM
once and fuse mask -> weight -> row-reduce in-register on the VPU,
accumulating the m strips into a (1, BN) output block — the same shape as
the feature-based sweep (``fb_gains.py``), with the memoized vector
(``covered`` resp. ``w * miss``) riding along as a (1, BM) row.

grid = (n/BN, m/BM), m innermost.  Zero padding is exact for both: a padded
concept has G = 0 / p = 0 and w = 0, so it contributes nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256  # candidates per tile
BM = 256  # concepts per tile


def _sc_kernel(g_ref, cov_ref, w_ref, out_ref):
    mblk = pl.program_id(1)

    @pl.when(mblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)  # (BN, BM) incidence tile
    cov = cov_ref[...].astype(jnp.float32)  # (1, BM) covered indicator
    w = w_ref[...].astype(jnp.float32)  # (1, BM) concept weights
    out_ref[...] += (jnp.maximum(g - cov, 0.0) * w).sum(axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "bn", "bm"))
def sc_gains_pallas(
    cover: jax.Array,
    covered: jax.Array,
    w: jax.Array,
    interpret: bool = False,
    bn: int = BN,
    bm: int = BM,
) -> jax.Array:
    """cover (n, m) binary incidence, covered (m,) memoized indicator,
    w (m,) concept weights -> gains (n,) fp32."""
    n, m = cover.shape
    pad_n = (-n) % bn
    pad_m = (-m) % bm
    gp = jnp.pad(cover, ((0, pad_n), (0, pad_m)))
    cp = jnp.pad(covered.astype(jnp.float32)[None, :], ((0, 0), (0, pad_m)))
    wp = jnp.pad(w.astype(jnp.float32)[None, :], ((0, 0), (0, pad_m)))
    npn, npm = gp.shape
    out = pl.pallas_call(
        _sc_kernel,
        grid=(npn // bn, npm // bm),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda j, u: (j, u)),
            pl.BlockSpec((1, bm), lambda j, u: (0, u)),
            pl.BlockSpec((1, bm), lambda j, u: (0, u)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, u: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npn), jnp.float32),
        interpret=interpret,
    )(gp, cp, wp)
    return out[0, :n]


def _psc_kernel(p_ref, wm_ref, out_ref):
    mblk = pl.program_id(1)

    @pl.when(mblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...].astype(jnp.float32)  # (BN, BM) membership probabilities
    wm = wm_ref[...].astype(jnp.float32)  # (1, BM) w_u * Pbar_u(A)
    out_ref[...] += (p * wm).sum(axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "bn", "bm"))
def psc_gains_pallas(
    probs: jax.Array,
    miss: jax.Array,
    w: jax.Array,
    interpret: bool = False,
    bn: int = BN,
    bm: int = BM,
) -> jax.Array:
    """probs (n, m) membership probabilities, miss (m,) memoized
    Pbar_u(A) = prod_{j in A}(1 - p_ju), w (m,) weights -> gains (n,) fp32.

    The weighted miss vector ``w * miss`` is formed once on the host side of
    the kernel (O(m)) so the tile loop is a single fused multiply-reduce."""
    n, m = probs.shape
    pad_n = (-n) % bn
    pad_m = (-m) % bm
    pp = jnp.pad(probs, ((0, pad_n), (0, pad_m)))
    wm = (w.astype(jnp.float32) * miss.astype(jnp.float32))[None, :]
    wmp = jnp.pad(wm, ((0, 0), (0, pad_m)))
    npn, npm = pp.shape
    out = pl.pallas_call(
        _psc_kernel,
        grid=(npn // bn, npm // bm),
        in_specs=[
            pl.BlockSpec((bn, bm), lambda j, u: (j, u)),
            pl.BlockSpec((1, bm), lambda j, u: (0, u)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, u: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npn), jnp.float32),
        interpret=interpret,
    )(pp, wmp)
    return out[0, :n]
