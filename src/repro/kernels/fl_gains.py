"""Pallas TPU kernel: fused facility-location marginal gains.

Per greedy step the hotspot is  gains_j = sum_i max(S_ij - curmax_i, 0)
over the whole candidate set (paper Table 3 memoization, vectorized — see
DESIGN §2).  A naive XLA lowering materializes the (U, N) relu intermediate
in HBM (3x traffic: read S, write relu, read relu for the reduce).  This
kernel streams each S tile through VMEM exactly once and fuses
subtract→relu→column-reduce in-register, so the op stays at the 1x-HBM-read
roofline of S itself.

grid = (N/BN, U/BU) with U innermost; the (1, BN) output block is revisited
across U steps and used as the fp32 accumulator.

``fl_gains_at_pallas`` is the masked-subset entry point (the lazy engines'
``partial_sweep`` contract): an XLA gather of the K requested columns feeds
the SAME fused subtract->relu->reduce tile stream, sized to the subset, so a
bucketed lazy step touches O(U * K) of S instead of O(U * N).  Slots with
idx < 0 are padding and return NEG_INF.  Because each output column's
accumulation order over U tiles is independent of the other columns, the
subset values are bit-identical to the full sweep's at the same indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import NEG_INF

BU = 256  # represented-set rows per tile
BN = 512  # candidates per tile

_PAD_CM = 3.0e38  # pad value for curmax: relu(s - huge) == 0 contributes nothing


def _fl_gains_kernel(s_ref, cm_ref, out_ref):
    u = pl.program_id(1)

    @pl.when(u == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = s_ref[...].astype(jnp.float32)  # (BU, BN)
    cm = cm_ref[...].astype(jnp.float32)  # (BU, 1)
    out_ref[...] += jnp.maximum(s - cm, 0.0).sum(axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "bu", "bn"))
def fl_gains_pallas(
    sim: jax.Array,
    curmax: jax.Array,
    interpret: bool = False,
    bu: int = BU,
    bn: int = BN,
) -> jax.Array:
    """sim (u, n), curmax (u,) -> gains (n,) in fp32."""
    u, n = sim.shape
    pad_u = (-u) % bu
    pad_n = (-n) % bn
    sp = jnp.pad(sim, ((0, pad_u), (0, pad_n)))
    cmp_ = jnp.pad(
        curmax.astype(jnp.float32)[:, None], ((0, pad_u), (0, 0)),
        constant_values=_PAD_CM,
    )
    up, npad = sp.shape
    grid = (npad // bn, up // bu)
    out = pl.pallas_call(
        _fl_gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bu, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(sp, cmp_)
    return out[0, :n]


def _subset_tile(k: int, cap: int) -> int:
    """Candidate-tile width for a K-subset sweep: one lane-width-aligned tile
    when the subset is small, the full-sweep tiling otherwise."""
    b = 128
    while b < min(k, cap):
        b *= 2
    return min(b, cap)


@functools.partial(jax.jit, static_argnames=("interpret", "bu"))
def fl_gains_at_pallas(
    sim: jax.Array,
    curmax: jax.Array,
    idx: jax.Array,
    interpret: bool = False,
    bu: int = BU,
) -> jax.Array:
    """Masked-subset sweep: sim (u, n), curmax (u,), idx (k,) int32 ->
    gains (k,) fp32; slots with idx < 0 are padding and return NEG_INF."""
    (k,) = idx.shape
    safe = jnp.clip(idx, 0, sim.shape[1] - 1)
    cols = jnp.take(sim, safe, axis=1)  # (u, k) gather feeding the fused sweep
    out = fl_gains_pallas(cols, curmax, interpret=interpret, bu=bu, bn=_subset_tile(k, BN))
    return jnp.where(idx >= 0, out, NEG_INF)
