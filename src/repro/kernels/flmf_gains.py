"""Pallas TPU kernel: matrix-free fused facility-location gain sweep.

The dense sweep (``fl_gains.py``) streams a materialized (U, N) similarity
matrix; at n >= 10^6 that matrix does not exist.  This kernel fuses the
similarity computation itself into the sweep: feature tiles of the
represented set ``x`` (U, d) and the candidate set ``y`` (N, d) stream
through the MXU exactly as in ``similarity_kernel.py`` — matmul strips into
an fp32 VMEM scratch accumulator, metric epilogue (cosine shift / euclidean
/ RBF) in-register — and the finished (BU, BN) similarity block feeds the
subtract->relu->column-reduce of the gain sweep without ever leaving VMEM.
HBM traffic is O(n * d) feature bytes; the n x n matrix is never written.

grid = (N/BN, U/BU, d/BK) with the contraction strip innermost; the
(1, BN) output block is revisited across the U and K steps.  The (BU, BN)
similarity scratch lives in VMEM (``scratch_shapes``), zeroed at each
candidate/row tile's first K strip and folded into the output on its last.

``flmf_gains_at_pallas`` is the masked-subset entry point (the lazy
engines' ``partial_sweep`` contract): an XLA gather of the K requested
candidate ROWS of ``y`` feeds the same fused stream, sized to the subset.
Slots with idx < 0 are padding and return NEG_INF.  Each output column's
accumulation order over U and d tiles is independent of the other columns,
so subset values match the full sweep's at the same indices.

Row padding: ``x`` pads with zero rows and ``curmax`` with ``_PAD_CM``
(relu(s - huge) == 0), so pad rows contribute nothing for ANY metric —
including cosine/RBF, whose zero-feature similarity is nonzero.  Candidate
padding is sliced off the output.  Cosine inputs arrive PRE-normalized
(the :class:`~repro.core.sources.FeatureSource` contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.common import NEG_INF
from repro.kernels.fl_gains import _PAD_CM

BU = 256  # represented-set rows per tile
BN = 512  # candidates per tile
BK = 512  # feature-contraction strip


def _flmf_kernel(
    x_ref, y_ref, xx_ref, yy_ref, cm_ref, out_ref, acc_ref,
    *, metric, inv_two_sigma_sq, nd,
):
    u = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when((u == 0) & (kd == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(kd == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (BU, BK)
    y = y_ref[...].astype(jnp.float32)  # (BN, BK)
    acc_ref[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(kd == nd - 1)
    def _fold():
        acc = acc_ref[...]  # (BU, BN) raw dot block
        if metric == "dot":
            s = acc
        elif metric == "cosine":
            s = 0.5 * (1.0 + acc)
        else:
            xx = xx_ref[...].astype(jnp.float32)  # (BU, 1)
            yy = yy_ref[...].astype(jnp.float32)  # (1, BN)
            d2 = jnp.maximum(xx + yy - 2.0 * acc, 0.0)
            if metric == "euclidean":
                s = 1.0 / (1.0 + jnp.sqrt(d2))
            else:  # rbf
                s = jnp.exp(-d2 * inv_two_sigma_sq)
        cm = cm_ref[...].astype(jnp.float32)  # (BU, 1)
        out_ref[...] += jnp.maximum(s - cm, 0.0).sum(axis=0)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("metric", "rbf_sigma", "interpret", "bu", "bn", "bk"),
)
def flmf_gains_pallas(
    x: jax.Array,
    y: jax.Array,
    xx: jax.Array,
    yy: jax.Array,
    curmax: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    interpret: bool = False,
    bu: int = BU,
    bn: int = BN,
    bk: int = BK,
) -> jax.Array:
    """x (u, d), y (n, d), squared norms xx (u,) / yy (n,), curmax (u,)
    -> gains (n,) fp32, without materializing the (u, n) similarity."""
    u, d = x.shape
    n = y.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, (-u) % bu), (0, (-d) % bk)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, (-n) % bn), (0, (-d) % bk)))
    xxp = jnp.pad(xx.astype(jnp.float32)[:, None], ((0, (-u) % bu), (0, 0)))
    yyp = jnp.pad(yy.astype(jnp.float32)[None, :], ((0, 0), (0, (-n) % bn)))
    cmp_ = jnp.pad(
        curmax.astype(jnp.float32)[:, None], ((0, (-u) % bu), (0, 0)),
        constant_values=_PAD_CM,
    )
    up, dp = xp.shape
    npad = yp.shape[0]
    nd = dp // bk
    sigma = rbf_sigma if rbf_sigma is not None else float(d) ** 0.5
    grid = (npad // bn, up // bu, nd)
    out = pl.pallas_call(
        functools.partial(
            _flmf_kernel,
            metric=metric,
            inv_two_sigma_sq=1.0 / (2.0 * sigma * sigma),
            nd=nd,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu, bk), lambda j, i, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda j, i, k: (j, k)),
            pl.BlockSpec((bu, 1), lambda j, i, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),
            pl.BlockSpec((bu, 1), lambda j, i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bu, bn), jnp.float32)],
        interpret=interpret,
    )(xp, yp, xxp, yyp, cmp_)
    return out[0, :n]


@functools.partial(
    jax.jit, static_argnames=("metric", "rbf_sigma", "interpret", "bu", "bk")
)
def flmf_gains_at_pallas(
    x: jax.Array,
    y: jax.Array,
    xx: jax.Array,
    yy: jax.Array,
    curmax: jax.Array,
    idx: jax.Array,
    metric: str = "dot",
    rbf_sigma: float | None = None,
    interpret: bool = False,
    bu: int = BU,
    bk: int = BK,
) -> jax.Array:
    """Masked-subset sweep: gains at the gathered candidates ``idx`` (k,)
    int32 -> (k,) fp32; slots with idx < 0 are padding and return NEG_INF.

    Unlike the dense subset sweeps, the candidate tile stays at the
    full-sweep width BN: the similarity dot is recomputed here, and a
    narrower contraction can drift from the full sweep by ulps — fixed
    tiling keeps subset and full-sweep gains bit-identical."""
    safe = jnp.clip(idx, 0, y.shape[0] - 1)
    out = flmf_gains_pallas(
        x,
        jnp.take(y, safe, axis=0),
        xx,
        jnp.take(yy, safe),
        curmax,
        metric=metric,
        rbf_sigma=rbf_sigma,
        interpret=interpret,
        bu=bu,
        bn=BN,
        bk=bk,
    )
    return jnp.where(idx >= 0, out, NEG_INF)
