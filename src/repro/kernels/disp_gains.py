"""Pallas TPU kernels: fused disparity (dispersion) gain sweeps.

Both kernels recompute the sweep FROM THE SELECTION MASK in one streamed
pass over the (n, n) distance matrix — the stateless serving shape (no
memoized per-query state resident), mirroring ``gc_gains.py``:

  DisparitySum   gains_j = sum_k d_jk * m_k          (masked matvec)
  DisparityMin   gains_j = min(surr_j, BIG) - f(A),
                 surr_j  = 0 if |A| = 0 else min_{k in A} d_jk
                 (masked min — the Dasgupta et al. farthest-point surrogate,
                  see core/functions/disparity.py)

Each (BJ x BK) tile of D streams through VMEM exactly once; the (1, BJ)
output block accumulates over the K strips (sum for DisparitySum, min for
DisparityMin) and DisparityMin finalizes with the |A|-conditional and the
current-dispersion subtraction on the last strip (|A| and f(A) ride in SMEM).

grid = (n/BJ, n/BK) with K innermost.  Zero row/column padding is exact:
padded candidates read only masked-out columns (sum adds 0 * m, min keeps
BIG), and real candidates never see a padded column selected.

Note DisparityMin's masked min is order-independent and float-exact, so this
stateless sweep reproduces the memoized ``mind`` statistic bit-for-bit; the
DisparitySum sum is a different reduction order than the incrementally
accumulated ``selsum`` and matches to ulps only (see the use_kernel notes in
``core/functions/disparity.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BJ = 256  # candidate columns of the output per tile
BK = 256  # summed/minimized-over ground elements per tile

_BIG = 1e30  # matches core/functions/disparity.py


def _dsum_kernel(d_ref, m_ref, out_ref):
    kblk = pl.program_id(1)

    @pl.when(kblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = d_ref[...].astype(jnp.float32)  # (BJ, BK) rows j = candidates
    m = m_ref[...].astype(jnp.float32)  # (1, BK) selection indicator
    out_ref[...] += (d * m).sum(axis=1)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "bj", "bk"))
def dsum_gains_pallas(
    dist: jax.Array,
    selmask: jax.Array,
    interpret: bool = False,
    bj: int = BJ,
    bk: int = BK,
) -> jax.Array:
    """dist (n, n) pairwise distances, selmask (n,) 0/1 selection indicator
    -> DisparitySum gains (n,) fp32."""
    n = dist.shape[0]
    pad_j = (-n) % bj
    pad_k = (-n) % bk
    dp = jnp.pad(dist, ((0, pad_j), (0, pad_k)))
    mp = jnp.pad(selmask.astype(jnp.float32)[None, :], ((0, 0), (0, pad_k)))
    npj, npk = dp.shape
    out = pl.pallas_call(
        _dsum_kernel,
        grid=(npj // bj, npk // bk),
        in_specs=[
            pl.BlockSpec((bj, bk), lambda j, k: (j, k)),
            pl.BlockSpec((1, bk), lambda j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npj), jnp.float32),
        interpret=interpret,
    )(dp, mp)
    return out[0, :n]


def _dmin_kernel(cnt_ref, cur_ref, d_ref, m_ref, out_ref, *, nk):
    kblk = pl.program_id(1)

    @pl.when(kblk == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _BIG)

    d = d_ref[...].astype(jnp.float32)  # (BJ, BK)
    m = m_ref[...].astype(jnp.float32)  # (1, BK)
    vals = jnp.where(m > 0.0, d, _BIG)  # unselected columns drop out of the min
    out_ref[...] = jnp.minimum(out_ref[...], vals.min(axis=1)[None, :])

    @pl.when(kblk == nk - 1)
    def _finalize():
        count = cnt_ref[0]
        curmin = cur_ref[0]
        surrogate = jnp.where(count == 0, 0.0, out_ref[...])
        out_ref[...] = jnp.minimum(surrogate, _BIG) - curmin


@functools.partial(jax.jit, static_argnames=("interpret", "bj", "bk"))
def dmin_gains_pallas(
    dist: jax.Array,
    selmask: jax.Array,
    count: jax.Array,
    curmin: jax.Array,
    interpret: bool = False,
    bj: int = BJ,
    bk: int = BK,
) -> jax.Array:
    """dist (n, n), selmask (n,) 0/1 indicator, count scalar |A|, curmin
    scalar f(A) -> DisparityMin surrogate gains (n,) fp32."""
    n = dist.shape[0]
    pad_j = (-n) % bj
    pad_k = (-n) % bk
    dp = jnp.pad(dist, ((0, pad_j), (0, pad_k)))
    mp = jnp.pad(selmask.astype(jnp.float32)[None, :], ((0, 0), (0, pad_k)))
    npj, npk = dp.shape
    nk = npk // bk
    cnt = jnp.asarray(count, jnp.int32).reshape((1,))
    cur = jnp.asarray(curmin, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_dmin_kernel, nk=nk),
        grid=(npj // bj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bj, bk), lambda j, k: (j, k)),
            pl.BlockSpec((1, bk), lambda j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, npj), jnp.float32),
        interpret=interpret,
    )(cnt, cur, dp, mp)
    return out[0, :n]
