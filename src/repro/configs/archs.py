"""The 10 assigned architectures (exact specs from the assignment block).

Sources are noted per-arch; where the assignment text and the public model
card disagree, the assignment wins (e.g. kimi-k2 is specified here as GQA
kv=8 rather than the real model's MLA).
"""
from repro.configs.base import ArchConfig, register

# [audio] whisper-small — enc-dec, conv frontend stubbed to precomputed
# frame embeddings [arXiv:2212.04356]
WHISPER_SMALL = register(
    ArchConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,  # decoder layers
        enc_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51865,
        rope="none",  # whisper uses learned/sinusoidal positions
        use_bias=True,
        enc_positions=1500,
    )
)

# [moe] Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]
KIMI_K2 = register(
    ArchConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,  # per assignment: expert FFN width
        vocab=163840,
        n_experts=384,
        n_shared_experts=1,
        top_k=8,
        d_expert=2048,
        first_dense_layers=1,
        rope_theta=5e6,
    )
)

# [moe] DeepSeek-V2 236B — MLA kv_lora=512, 2 shared + 160 routed top-6
# [arXiv:2405.04434]
DEEPSEEK_V2 = register(
    ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=1536,  # expert FFN width per assignment
        vocab=102400,
        mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        d_expert=1536,
        first_dense_layers=1,
    )
)

# [hybrid] Jamba-1.5-large — Mamba+attn 1:7 interleave, MoE 16e top-2
# [arXiv:2403.19887]
JAMBA_15_LARGE = register(
    ArchConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        rope="none",  # jamba attention layers are NoPE
        n_experts=16,
        top_k=2,
        moe_every=2,  # MoE every other layer
        moe_offset=1,
        attn_every=8,  # 1 attention : 7 mamba
        attn_offset=4,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
    )
)

# [dense] StarCoder2-3B — GQA kv=2, RoPE [arXiv:2402.19173]
STARCODER2_3B = register(
    ArchConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab=49152,
        use_bias=True,
        rope_theta=1e5,
    )
)

# [dense] Qwen3-0.6B — qk_norm, GQA [hf:Qwen/Qwen3-0.6B]
QWEN3_06B = register(
    ArchConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1e6,
    )
)

# [dense] InternLM2-20B — GQA kv=8 [arXiv:2403.17297]
INTERNLM2_20B = register(
    ArchConfig(
        name="internlm2-20b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=92544,
        rope_theta=1e6,
    )
)

# [dense] Command R+ 104B — GQA kv=8, no-bias [hf:CohereForAI]
COMMAND_R_PLUS = register(
    ArchConfig(
        name="command-r-plus-104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab=256000,
        tie_embeddings=True,
        rope_theta=75e6,
    )
)

# [vlm] Qwen2-VL-7B — M-RoPE, dynamic resolution (stub patch embeddings)
# [arXiv:2409.12191]
QWEN2_VL_7B = register(
    ArchConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152064,
        rope="mrope",
        mrope_sections=(16, 24, 24),
        use_bias=True,  # qkv bias in qwen2
        n_patches=256,
        rope_theta=1e6,
    )
)

# [ssm] Mamba2-370M — SSD (state-space duality) [arXiv:2405.21060]
MAMBA2_370M = register(
    ArchConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        rope="none",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
    )
)

ALL_ARCHS = [
    "whisper-small",
    "kimi-k2-1t-a32b",
    "deepseek-v2-236b",
    "jamba-1.5-large-398b",
    "starcoder2-3b",
    "qwen3-0.6b",
    "internlm2-20b",
    "command-r-plus-104b",
    "qwen2-vl-7b",
    "mamba2-370m",
]
