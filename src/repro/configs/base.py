"""Architecture config system.

One :class:`ArchConfig` per assigned architecture (see sibling modules), plus
``reduced()`` views used by the CPU smoke tests.  Everything the model code
needs is derived from this dataclass — family-specific fields are simply
unused by other families.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    use_bias: bool = False
    rope: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    tie_embeddings: bool = False

    # --- MLA (DeepSeek-style multi-head latent attention) ---
    mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 -> no query compression
    rope_head_dim: int = 64

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0  # expert FFN hidden size (0 -> d_ff)
    moe_every: int = 1  # MoE at layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    first_dense_layers: int = 0  # leading layers stay dense (DeepSeek style)
    capacity_factor: float = 1.0
    moe_group_size: int = 512  # GShard-style dispatch group (DESIGN §3)

    # --- SSM / Mamba2 (SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    attn_every: int = 0  # hybrid: attention at layers where l % attn_every == attn_offset
    attn_offset: int = 0

    # --- encoder-decoder (whisper-style) ---
    enc_layers: int = 0
    enc_positions: int = 1500  # stub audio frames

    # --- VLM stub frontend ---
    n_patches: int = 0

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_expert_(self) -> int:
        return self.d_expert or self.d_ff

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, layer: int) -> bool:
        if self.n_experts == 0 or layer < self.first_dense_layers:
            return False
        return layer % self.moe_every == self.moe_offset

    def is_attn_layer(self, layer: int) -> bool:
        if self.family not in ("hybrid",):
            return self.family != "ssm"
        return self.attn_every > 0 and layer % self.attn_every == self.attn_offset

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers), for roofline."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        for l in range(self.n_layers):
            if self.family in ("ssm", "hybrid") and not self.is_attn_layer(l):
                di, ns, h = self.d_inner, self.ssm_state, self.n_ssm_heads
                total += d * (2 * di + 2 * ns + h)  # in_proj -> z, x, B, C, dt
                total += self.ssm_conv_width * (di + 2 * ns)  # causal conv
                total += di * d + di  # out_proj + gated norm
                total += 3 * h  # A_log, D, dt_bias
            else:
                hd = self.head_dim_
                if self.mla:
                    total += d * (self.kv_lora_rank + self.rope_head_dim)
                    total += self.kv_lora_rank * self.n_heads * 2 * hd
                    q_in = self.q_lora_rank or d
                    if self.q_lora_rank:
                        total += d * self.q_lora_rank
                    total += q_in * self.n_heads * (hd + self.rope_head_dim)
                    total += self.n_heads * hd * d
                else:
                    total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                    total += self.n_heads * hd * d
            if self.is_moe_layer(l):
                fe = self.d_expert_
                total += self.n_experts * 3 * d * fe + d * self.n_experts
                total += self.n_shared_experts * 3 * d * fe
            elif self.family != "ssm" or self.is_attn_layer(l):
                total += 3 * d * self.d_ff
        for l in range(self.enc_layers):
            hd = self.head_dim_
            total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            total += 3 * d * self.d_ff
            # decoder cross-attention (paired with each decoder layer)
        if self.enc_layers:
            total += self.n_layers * (
                d * self.head_dim_ * (self.n_heads + 2 * self.n_kv_heads)
                + self.n_heads * self.head_dim_ * d
            )
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        for l in range(self.n_layers):
            if self.is_moe_layer(l):
                fe = self.d_expert_
                inactive = (self.n_experts - self.top_k) * 3 * self.d_model * fe
                total -= inactive
        return total

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else self.attn_every),
            d_model=128,
            mrope_sections=(4, 6, 6),  # scaled to head_dim=32 (half dim 16)
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab=512,
            kv_lora_rank=64,
            q_lora_rank=64 if self.q_lora_rank else 0,
            rope_head_dim=16,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            d_expert=64 if self.d_expert else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            ssm_chunk=32,
            enc_layers=min(self.enc_layers, 2),
            enc_positions=min(self.enc_positions, 64),
            n_patches=min(self.n_patches, 16),
            moe_group_size=64,
            # cap == group size -> no token dropping, so decode logits match
            # prefill exactly in the smoke tests
            capacity_factor=4.0,
            param_dtype="float32",
            compute_dtype="float32",
        )


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs.archs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs.archs  # noqa: F401

    return sorted(_REGISTRY)
