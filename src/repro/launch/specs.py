"""Input shape cells for the dry-run's architectures x shapes sweep, and
their ShapeDtypeStruct stand-ins — weak-type-correct, shardable, no
allocation (see launch/dryrun.py for the driver that lowers each cell)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic attention: only the SSM/hybrid archs run it
LONG_CONTEXT_ARCHS = {"jamba-1.5-large-398b", "mamba2-370m"}


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Model-input ShapeDtypeStructs for a shape cell."""
    B = cell.global_batch
    tok_dt = jnp.int32

    def _frontend(batch: dict, seq_like_b: int):
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (seq_like_b, cfg.enc_positions, cfg.d_model), jnp.float32
            )
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (seq_like_b, cfg.n_patches, cfg.d_model), jnp.float32
            )
        return batch

    if cell.kind == "train":
        return _frontend(
            {"tokens": jax.ShapeDtypeStruct((B, cell.seq_len), tok_dt)}, B
        )
    if cell.kind == "prefill":
        return _frontend(
            {"tokens": jax.ShapeDtypeStruct((B, cell.seq_len), tok_dt)}, B
        )
    # decode: one new token against a seq_len cache (built separately)
    return {"tokens": jax.ShapeDtypeStruct((B, 1), tok_dt)}
