"""End-to-end training launcher with submodular data selection.

Runs real steps on whatever devices exist (CPU here; the mesh shape adapts),
with checkpoint/restart, per-round submodular coreset selection, and logging.
This is the driver behind examples/coreset_training.py.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 50 --batch 8 --seq 256 --select-every 10 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import get_config
from repro.data.pipeline import SyntheticTokens, embed_examples
from repro.data.selection import SelectorConfig, SubmodularSelector
from repro.train.train_step import init_train_state, make_train_step


def run(
    arch: str,
    steps: int = 50,
    batch: int = 8,
    seq: int = 256,
    select_every: int = 0,
    pool_factor: int = 4,
    budget: int | None = None,
    ckpt_dir: str | None = None,
    ckpt_every: int = 25,
    reduced: bool = True,
    objective: str = "representative",
    seed: int = 0,
    log_every: int = 10,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    data = SyntheticTokens(cfg, seq, seed=seed)
    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg), donate_argnums=0)

    start_step = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state, meta = ckpt.restore(ckpt_dir, state)
        start_step = meta["step"]
        print(f"[ckpt] resumed from step {start_step}")

    selector = (
        SubmodularSelector(
            cfg,
            SelectorConfig(
                objective=objective, budget=budget or batch * select_every
            ),
        )
        if select_every
        else None
    )
    embed_fn = jax.jit(lambda p, b: embed_examples(cfg, p, b)) if selector else None

    cursor = start_step * batch
    queue: list[int] = []
    losses = []
    t0 = time.monotonic()
    for step in range(start_step, steps):
        if selector and not queue:
            # selection round: embed a pool, pick a representative coreset
            pool_n = batch * select_every * pool_factor
            pool_idx = list(range(cursor, cursor + pool_n))
            embs = []
            for i in range(0, pool_n, batch):
                embs.append(embed_fn(state.params, data.batch(pool_idx[i : i + batch])))
            emb = jnp.concatenate(embs, axis=0)
            chosen = selector.select(emb)
            queue = [pool_idx[i] for i in chosen]
            cursor += pool_n
            print(f"[select] step {step}: pool {pool_n} -> coreset {len(queue)}")
        if selector:
            idx, queue = queue[:batch], queue[batch:]
            while len(idx) < batch:  # pad from the stream if coreset exhausted
                idx.append(cursor)
                cursor += 1
        else:
            idx = list(range(cursor, cursor + batch))
            cursor += batch
        state, metrics = step_fn(state, data.batch(idx))
        losses.append(float(metrics["loss"]))
        if (step + 1) % log_every == 0:
            dt = time.monotonic() - t0
            print(
                f"step {step + 1:5d}  loss {losses[-1]:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"{dt / log_every:.2f}s/step"
            )
            t0 = time.monotonic()
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state, {"arch": arch})
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--select-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--objective", default="representative")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    a = ap.parse_args()
    run(
        a.arch,
        steps=a.steps,
        batch=a.batch,
        seq=a.seq,
        select_every=a.select_every,
        ckpt_dir=a.ckpt_dir,
        ckpt_every=a.ckpt_every,
        reduced=not a.full,
        objective=a.objective,
    )


if __name__ == "__main__":
    main()
