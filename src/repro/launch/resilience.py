"""Retry policies, typed request failures, and circuit breakers.

This module is deliberately dependency-free (stdlib only): the
:class:`RetryPolicy` rides on :class:`~repro.core.optimizers.spec.
SelectionSpec` as static aux data, so ``core`` may import it without
pulling the serving stack in.

Three pieces:

- :class:`RetryPolicy` — validated retry/backoff knobs carried per request.
  ``timeout_s`` is the request's WALL-CLOCK budget across attempts; it is
  distinct from the spec's ``deadline_s``, which only shapes *scheduling*
  (when a group flushes) and never fails a request.  Backoff jitter is
  deterministic, derived from the request id and attempt number — two runs
  of the same workload back off identically.
- :class:`RequestFailed` — the typed terminal error a request resolves to
  when it exhausts its attempts (``reason="quarantined"``) or its
  ``timeout_s`` (``reason="timeout"``); carries the full attempt history.
- :class:`CircuitBreaker` / :class:`BreakerBoard` — per-key
  closed -> open -> half-open breakers.  The serving stack keys them by
  ``(family, "kernel")`` and ``(family, "mesh")``: an open kernel breaker
  makes dispatch rewrite the wave to ``use_kernel=False`` (Pallas -> XLA),
  an open mesh breaker drops the wave to single-device — both degraded
  modes stay bit-identical to sequential ``solve()`` because backend and
  mesh parity are already pinned by the test suite.

See docs/serving.md ("Failures, retries, and degraded modes") for the knob
table and the sync/async/session failure-semantics matrix.
"""
from __future__ import annotations

import dataclasses
import math
import random
import threading
import time
from typing import Callable, Mapping

__all__ = [
    "RetryPolicy",
    "SINGLE_ATTEMPT",
    "RequestFailed",
    "CircuitBreaker",
    "BreakerBoard",
]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Validated retry/backoff knobs for one request (hashable, so it rides
    a spec's static aux data and jit cache keys).

    - ``max_attempts``: total dispatch attempts before the request is
      quarantined with a :class:`RequestFailed` (1 = no retry).
    - ``backoff_s`` / ``backoff_mult`` / ``max_backoff_s``: exponential
      backoff schedule — attempt k waits
      ``min(backoff_s * backoff_mult**(k-1), max_backoff_s)``.
    - ``jitter``: +/- fraction applied to each backoff, drawn
      deterministically from (request id, attempt) — never from wall-clock
      RNG, so reruns are bit-reproducible.
    - ``timeout_s``: wall-clock budget from submit; a request older than
      this is failed (``reason="timeout"``) instead of retried.  Distinct
      from ``deadline_s``: a lapsed deadline flushes early and flags the
      response, a lapsed timeout fails the request.
    """

    max_attempts: int = 3
    backoff_s: float = 0.01
    backoff_mult: float = 2.0
    max_backoff_s: float = 1.0
    jitter: float = 0.1
    timeout_s: float | None = None

    def __post_init__(self):
        if int(self.max_attempts) < 1:
            raise ValueError(
                f"max_attempts must be an int >= 1, got {self.max_attempts!r}"
            )
        object.__setattr__(self, "max_attempts", int(self.max_attempts))
        for name in ("backoff_s", "max_backoff_s"):
            v = float(getattr(self, name))
            if not math.isfinite(v) or v < 0:
                raise ValueError(f"{name} must be a finite float >= 0, got {v!r}")
            object.__setattr__(self, name, v)
        mult = float(self.backoff_mult)
        if not math.isfinite(mult) or mult < 1.0:
            raise ValueError(f"backoff_mult must be >= 1, got {mult!r}")
        object.__setattr__(self, "backoff_mult", mult)
        j = float(self.jitter)
        if not 0.0 <= j <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {j!r}")
        object.__setattr__(self, "jitter", j)
        if self.timeout_s is not None:
            t = float(self.timeout_s)
            if not math.isfinite(t) or t <= 0:
                raise ValueError(
                    "timeout_s must be a positive finite number of seconds "
                    f"(or None), got {t!r}"
                )
            object.__setattr__(self, "timeout_s", t)

    def backoff(self, attempt: int, seed: object = 0) -> float:
        """Seconds to wait after failed attempt ``attempt`` (1-based), with
        deterministic jitter derived from ``seed`` (the request id)."""
        base = min(
            self.backoff_s * self.backoff_mult ** (max(1, attempt) - 1),
            self.max_backoff_s,
        )
        if base <= 0.0 or self.jitter <= 0.0:
            return base
        u = random.Random(f"{seed!r}/{attempt}").random()  # reproducible
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))

    def to_dict(self) -> dict:
        return {
            "max_attempts": self.max_attempts,
            "backoff_s": self.backoff_s,
            "backoff_mult": self.backoff_mult,
            "max_backoff_s": self.max_backoff_s,
            "jitter": self.jitter,
            "timeout_s": self.timeout_s,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "RetryPolicy":
        return cls(**{k: d[k] for k in d})


# the implicit policy of a request with no retry configured: one attempt,
# no backoff — resilient flush paths fail it typed on first error instead
# of raising a bare FlushError past the caller
SINGLE_ATTEMPT = RetryPolicy(
    max_attempts=1, backoff_s=0.0, jitter=0.0, timeout_s=None
)


class RequestFailed(RuntimeError):
    """Terminal, typed failure of one request.

    ``reason`` is ``"quarantined"`` (attempts exhausted — the request was
    isolated so it cannot re-poison its group) or ``"timeout"`` (its
    ``RetryPolicy.timeout_s`` lapsed).  ``attempts`` is the full history:
    a tuple of ``{"attempt", "error", "elapsed_s"}`` dicts.  ``__cause__``
    is the last underlying error, when there was one.
    """

    def __init__(self, rid, reason: str, attempts=(), cause=None):
        attempts = tuple(attempts)
        last = attempts[-1]["error"] if attempts else None
        super().__init__(
            f"request {rid!r} {reason} after {len(attempts)} attempt(s)"
            + (f"; last error: {last}" if last else "")
        )
        self.rid = rid
        self.reason = reason
        self.attempts = attempts
        if cause is not None:
            self.__cause__ = cause


class CircuitBreaker:
    """closed -> open -> half-open breaker over consecutive failures.

    ``threshold`` consecutive failures open the breaker; after
    ``cooldown_s`` the next ``allow()`` transitions to half-open (probe
    traffic passes).  A half-open failure re-opens (fresh cooldown); a
    success closes.  ``allow()`` is what dispatch consults — False means
    "serve degraded instead".
    """

    _GUARDED_BY = {
        "_state": "_lock",
        "_failures": "_lock",
        "_opened_at": "_lock",
    }

    def __init__(
        self,
        threshold: int = 3,
        cooldown_s: float = 5.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if int(threshold) < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold!r}")
        if float(cooldown_s) < 0:
            raise ValueError(f"cooldown_s must be >= 0, got {cooldown_s!r}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open":
                if self._clock() - self._opened_at >= self.cooldown_s:
                    self._state = "half_open"  # probe traffic passes
                    return True
                return False
            return True  # half_open: keep probing until a record lands

    def record_failure(self) -> None:
        with self._lock:
            if self._state == "half_open":
                self._state = "open"  # failed probe: fresh cooldown
                self._opened_at = self._clock()
                return
            self._failures += 1
            if self._failures >= self.threshold and self._state == "closed":
                self._state = "open"
                self._opened_at = self._clock()

    def record_success(self) -> None:
        with self._lock:
            self._state = "closed"
            self._failures = 0


class BreakerBoard:
    """A lazily-populated map of breakers keyed by hashable keys (the
    serving stack uses ``(family, "kernel")`` / ``(family, "mesh")``).

    ``bind(listener)`` registers a ``listener(label, state)`` callback
    invoked on every state CHANGE — the server wires it to
    ``ServerMetrics.set_breaker`` so ``snapshot()["breakers"]`` mirrors the
    board.  Labels join tuple keys with ``/``.
    """

    # _listener is deliberately undeclared: bind() happens once at server
    # construction before any traffic, and firing it outside _lock is what
    # keeps listener callbacks (metrics) from running under the board lock
    _GUARDED_BY = {"_breakers": "_lock"}

    def __init__(
        self,
        threshold: int = 3,
        cooldown_s: float = 5.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._breakers: dict = {}
        self._lock = threading.Lock()
        self._listener: Callable[[str, str], None] | None = None

    @staticmethod
    def label(key) -> str:
        if isinstance(key, tuple):
            return "/".join(str(k) for k in key)
        return str(key)

    def bind(self, listener: Callable[[str, str], None]) -> None:
        self._listener = listener

    def get(self, key) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                br = self._breakers[key] = CircuitBreaker(
                    self.threshold, self.cooldown_s, clock=self._clock
                )
            return br

    def _notify(self, key, before: str, breaker: CircuitBreaker) -> None:
        after = breaker.state
        if after != before and self._listener is not None:
            self._listener(self.label(key), after)

    def allow(self, key) -> bool:
        br = self.get(key)
        before = br.state
        out = br.allow()
        self._notify(key, before, br)
        return out

    def record_failure(self, key) -> None:
        br = self.get(key)
        before = br.state
        br.record_failure()
        self._notify(key, before, br)

    def record_success(self, key) -> None:
        br = self.get(key)
        before = br.state
        br.record_success()
        self._notify(key, before, br)

    def states(self) -> dict:
        """{label: state} for every breaker the board has created."""
        with self._lock:
            items = list(self._breakers.items())
        return {self.label(k): b.state for k, b in items}
