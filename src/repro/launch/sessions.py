"""Long-lived selection sessions over the serving front doors.

A :class:`SelectionSession` is the serving shape for *growing* data: a
client opens a session around a :class:`~repro.core.optimizers.spec.
SelectionSpec`, then feeds **deltas** — new ground-set rows, or newly
unlocked indices of a fixed universe — and receives an updated selection
after every delta:

    session = server.open_session(SelectionSpec(fn0, budget=8))
    upd = session.extend(features=new_rows)   # sync: SessionUpdate
    fut = session.extend(features=more_rows)  # async server: Future
    session.close()

Replay semantics (the determinism contract): each ``extend`` rebuilds the
session's function over the FULL stream seen so far and submits one fresh
spec through the server's normal per-group queues — deltas coalesce with
everyone else's requests, ride padded waves, and obey backpressure and
deadlines exactly like one-shot requests.  Because the k-th update *is*
``solve()`` over the concatenated stream, a session fed N deltas returns a
final selection bit-identical (ids, gains, n_evals) to one direct
``solve()`` over the same data, on or off a mesh — there is no incremental
state to drift.

Two delta modes, fixed by the first ``extend``:

- **features mode** (``extend(features=rows)``): the spec's function seeds
  the stream and a registered *extender* appends rows.  Extenders MUST be
  concatenation-associative bit-for-bit — every built-in preprocesses rows
  independently (row-wise clamp / normalize / log1p), so one big extend
  equals many small ones exactly.
- **indices mode** (``extend(indices=ids)``): the spec's function is the
  fixed universe and a registered *restrictor* exposes the active subset.
  Restrictors preserve values — the restricted function agrees with the
  universe function on every subset of the active set — and updates report
  UNIVERSE ids, not positions in the active list.

Families opt in through :func:`register_feature_extender` /
:func:`register_restrictor` (MRO-resolved, like the coalescer's padders,
so the info-measure subclasses of SetCover/PSC inherit coverage for free).

Session metrics ride the server's :class:`~repro.launch.metrics.
ServerMetrics`: counters ``sessions_opened`` / ``sessions_closed`` /
``session_deltas`` / ``session_churn`` plus the ``delta_s`` histogram
(submit -> update latency per delta).  Each session also keeps its own
``deltas_absorbed`` / ``churn_total`` / ``last_update``.

Async edge discipline: ``extend`` on an :class:`~repro.launch.async_serve.
AsyncSelectionServer` returns a Future chained onto the server's — a
``close(flush=False)`` on the server cancels the in-flight delta's future,
engine errors propagate as exceptional futures, and a full queue raises
:class:`~repro.launch.serve.ServerOverloaded` synchronously at ``extend``
time (backpressure applies to deltas like any submit).

Crash safety: open a session with a :class:`SessionJournal` and every
COMMITTED delta's raw input (the float32 rows / the index array, exactly as
given) is appended to an atomic on-disk journal (one checkpoint step per
delta, riding ``repro/ckpt/checkpoint.py``'s tmp + os.replace discipline).
After a crash, :func:`restore_sessions` replays each journaled stream
through a fresh server's REAL ``extend`` path — re-preprocessing the raw
inputs identically — so the restored sessions' state (stream, active set,
selection, churn accounting) is bit-identical to the lost server's.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time
from concurrent.futures import Future

import jax.numpy as jnp
import numpy as np

from repro.core.functions.facility_location import (
    FacilityLocation,
    FacilityLocationMF,
)
from repro.core.functions.feature_based import FeatureBased
from repro.core.functions.graph_cut import GraphCut
from repro.core.functions.set_cover import ProbabilisticSetCover, SetCover
from repro.core.optimizers.spec import SelectionSpec
from repro.core.sources import DenseSource, FeatureSource
from repro.launch import faults
from repro.launch.async_serve import AsyncSelectionServer

__all__ = [
    "SelectionSession",
    "SessionClosed",
    "SessionJournal",
    "SessionUpdate",
    "register_feature_extender",
    "register_restrictor",
    "resolve_extender",
    "resolve_restrictor",
    "restore_sessions",
]


class SessionClosed(RuntimeError):
    """``extend`` was called on a closed :class:`SelectionSession`."""


@dataclasses.dataclass
class SessionUpdate:
    """One absorbed delta: the refreshed selection plus its accounting.

    ``selection`` ids are always in the session's UNIVERSE space — stream
    positions for features mode, the caller's own indices for indices mode
    — so consecutive updates are directly comparable (``churn`` is the
    symmetric difference of consecutive id sets).
    """

    seq: int  # 1-based delta sequence number within the session
    selection: list  # [(universe_id, gain), ...] in pick order
    result: object  # the GreedyResult (== sequential solve over the stream)
    response: object  # the underlying SelectionResponse (wave accounting)
    n_total: int  # ground-set size after this delta
    n_delta: int  # elements this delta added
    churn: int  # |previous ids  ^  current ids|
    latency_s: float  # extend() -> update built (queue + wave + chaining)


# ---------------------------------------------------------------------------
# Family registries (MRO-resolved, like launch/coalesce.py's padders)
# ---------------------------------------------------------------------------

_EXTENDERS: dict[type, object] = {}
_RESTRICTORS: dict[type, object] = {}


def register_feature_extender(family: type):
    """Register ``extender(fn, rows) -> fn'`` for a function family.

    The extender appends ``rows`` (the family's natural raw input — feature
    rows, cover rows, probability rows) to ``fn``'s ground set.  It must be
    concatenation-associative bit-for-bit: preprocessing may only look at
    one row at a time, so feeding rows one-by-one builds the exact array
    one big concatenate would.
    """

    def deco(fn):
        _EXTENDERS[family] = fn
        return fn

    return deco


def register_restrictor(family: type):
    """Register ``restrictor(fn, active) -> fn'`` for a function family.

    ``active`` is an int32 array of universe ids; the restricted function
    must agree with ``fn`` on every subset of ``active`` (restrict the
    CANDIDATE axis only — the represented side stays the full universe)."""

    def deco(fn):
        _RESTRICTORS[family] = fn
        return fn

    return deco


def _resolve(registry: dict, cls: type, register_name: str):
    for base in cls.__mro__:
        hook = registry.get(base)
        if hook is not None:
            return hook
    raise NotImplementedError(
        f"{cls.__name__} has no session support for this delta mode; "
        f"register a hook with repro.launch.sessions.{register_name} "
        f"(supported: {sorted(c.__name__ for c in registry)})"
    )


def resolve_extender(cls: type):
    return _resolve(_EXTENDERS, cls, "register_feature_extender")


def resolve_restrictor(cls: type):
    return _resolve(_RESTRICTORS, cls, "register_restrictor")


# -- built-in extenders ------------------------------------------------------


@register_feature_extender(FeatureBased)
def _extend_feature_based(fn: FeatureBased, rows) -> FeatureBased:
    # same row-wise clamp as from_features, so session-grown == direct-built
    rows = jnp.maximum(jnp.asarray(rows, jnp.float32), 0.0)
    feats = jnp.concatenate([fn.feats, rows], axis=0)
    return dataclasses.replace(fn, feats=feats, n=int(feats.shape[0]))


@register_feature_extender(SetCover)
def _extend_set_cover(fn: SetCover, rows) -> SetCover:
    cover = jnp.concatenate([fn.cover, jnp.asarray(rows, jnp.float32)], axis=0)
    return dataclasses.replace(fn, cover=cover, n=int(cover.shape[0]))


@register_feature_extender(ProbabilisticSetCover)
def _extend_psc(fn: ProbabilisticSetCover, rows) -> ProbabilisticSetCover:
    # rows are raw coverage PROBABILITIES — the same clip + log1p as
    # from_probs, applied per row
    probs = jnp.clip(jnp.asarray(rows, jnp.float32), 0.0, 1.0 - 1e-7)
    log_miss = jnp.concatenate([fn.log_miss, jnp.log1p(-probs)], axis=0)
    return dataclasses.replace(fn, log_miss=log_miss, n=int(log_miss.shape[0]))


def _is_symmetric(src: FeatureSource) -> bool:
    """Self-represented source (feature_source(x, y=None))?  Identity is the
    fast path; after transformations fall back to an exact compare."""
    if src.x is src.y:
        return True
    return (
        src.n_rows == src.n_cols
        and src.x.shape == src.y.shape
        and bool(jnp.all(src.x == src.y))
    )


@register_feature_extender(FacilityLocationMF)
def _extend_fl_mf(fn: FacilityLocationMF, rows) -> FacilityLocationMF:
    src = fn.src
    if not isinstance(src, FeatureSource):
        raise NotImplementedError(
            "session extension of FacilityLocationMF needs a FeatureSource "
            f"(raw rows can be appended); got {type(src).__name__}"
        )
    if src.row_labels is not None or src.col_labels is not None:
        raise NotImplementedError(
            "clustered (label-masked) sources cannot be extended in a session"
        )
    # exactly feature_source's row-wise preprocessing (normalize for cosine,
    # then squared norms) — concat-associative by construction
    d32 = jnp.asarray(rows, jnp.float32)
    if src.metric == "cosine":
        d32 = d32 / jnp.maximum(jnp.linalg.norm(d32, axis=1, keepdims=True), 1e-12)
    dd = (d32 * d32).sum(axis=1)
    if _is_symmetric(src):
        x = jnp.concatenate([src.x, d32], axis=0)
        xx = jnp.concatenate([src.xx, dd], axis=0)
        new_src = dataclasses.replace(
            src, x=x, y=x, xx=xx, yy=xx,
            n_rows=int(x.shape[0]), n_cols=int(x.shape[0]),
        )
    else:  # fixed represented rows, growing candidate columns
        y = jnp.concatenate([src.y, d32], axis=0)
        yy = jnp.concatenate([src.yy, dd], axis=0)
        new_src = dataclasses.replace(src, y=y, yy=yy, n_cols=int(y.shape[0]))
    return dataclasses.replace(fn, src=new_src, n=new_src.n_cols)


# -- built-in restrictors (candidate axis only: values are preserved) --------


@register_restrictor(FacilityLocation)
def _restrict_fl(fn: FacilityLocation, active) -> FacilityLocation:
    return dataclasses.replace(
        fn, sim=jnp.take(fn.sim, active, axis=1), n=int(active.shape[0])
    )


@register_restrictor(FacilityLocationMF)
def _restrict_fl_mf(fn: FacilityLocationMF, active) -> FacilityLocationMF:
    src = fn.src
    if isinstance(src, DenseSource):
        sub = dataclasses.replace(
            src, sim=jnp.take(src.sim, active, axis=1),
            n_cols=int(active.shape[0]),
        )
    elif isinstance(src, FeatureSource):
        sub = dataclasses.replace(
            src,
            y=jnp.take(src.y, active, axis=0),
            yy=jnp.take(src.yy, active),
            col_labels=(
                None
                if src.col_labels is None
                else jnp.take(src.col_labels, active)
            ),
            n_cols=int(active.shape[0]),
        )
    else:
        raise NotImplementedError(
            "session restriction of FacilityLocationMF needs a FeatureSource "
            f"or DenseSource; got {type(src).__name__}"
        )
    return dataclasses.replace(fn, src=sub, n=int(active.shape[0]))


@register_restrictor(GraphCut)
def _restrict_gc(fn: GraphCut, active) -> GraphCut:
    # representation term stays over the full universe (total gathered),
    # the S x S penalty only ever reads active x active
    sub = jnp.take(jnp.take(fn.sim_ground, active, axis=0), active, axis=1)
    return dataclasses.replace(
        fn,
        sim_ground=sub,
        total=jnp.take(fn.total, active),
        n=int(active.shape[0]),
    )


@register_restrictor(FeatureBased)
def _restrict_fb(fn: FeatureBased, active) -> FeatureBased:
    return dataclasses.replace(
        fn, feats=jnp.take(fn.feats, active, axis=0), n=int(active.shape[0])
    )


@register_restrictor(SetCover)
def _restrict_sc(fn: SetCover, active) -> SetCover:
    return dataclasses.replace(
        fn, cover=jnp.take(fn.cover, active, axis=0), n=int(active.shape[0])
    )


@register_restrictor(ProbabilisticSetCover)
def _restrict_psc(fn: ProbabilisticSetCover, active) -> ProbabilisticSetCover:
    return dataclasses.replace(
        fn, log_miss=jnp.take(fn.log_miss, active, axis=0), n=int(active.shape[0])
    )


# ---------------------------------------------------------------------------
# Crash-safe journaling
# ---------------------------------------------------------------------------


class SessionJournal:
    """Append-only on-disk journal of session deltas.

    Layout: ``root/<sid>/step_<seq>/`` — one checkpoint step per committed
    delta, written through :mod:`repro.ckpt.checkpoint`'s atomic
    tmp + ``os.replace`` discipline, so a crash mid-append never corrupts
    an already-journaled delta.  What is journaled is the delta's RAW input
    (the float32 feature rows, or the index array exactly as the client
    gave it), NOT the preprocessed function state: replay re-runs the real
    ``extend`` path, so restored state is bit-identical by construction
    rather than by trusting a serialized snapshot.
    """

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)

    def append(self, sid: str, seq: int, mode: str, payload) -> None:
        """Journal one committed delta (``seq`` is the session's 1-based
        delta ordinal)."""
        from repro.ckpt import checkpoint

        checkpoint.save(
            os.path.join(self.root, sid),
            seq,
            {"payload": np.asarray(payload)},
            meta={"sid": sid, "seq": int(seq), "mode": mode},
            keep_last=10**9,  # a journal never prunes
        )

    def sessions(self) -> list[str]:
        """Session ids with at least one journaled delta, sorted."""
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        out = []
        for name in sorted(names):
            sid_dir = os.path.join(self.root, name)
            if os.path.isdir(sid_dir) and any(
                d.startswith("step_") and not d.endswith(".tmp")
                for d in os.listdir(sid_dir)
            ):
                out.append(name)
        return out

    def deltas(self, sid: str) -> list[dict]:
        """The session's journaled deltas in commit order:
        ``[{"seq", "mode", "payload"}, ...]``."""
        from repro.ckpt import checkpoint

        sid_dir = os.path.join(self.root, sid)
        if not os.path.isdir(sid_dir):
            return []
        seqs = sorted(
            int(d.split("_")[1])
            for d in os.listdir(sid_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        out = []
        for seq in seqs:
            tree, meta = checkpoint.restore(sid_dir, {"payload": 0}, step=seq)
            out.append(
                {
                    "seq": int(meta["seq"]),
                    "mode": meta["mode"],
                    "payload": np.asarray(tree["payload"]),
                }
            )
        return out


def restore_sessions(server, journal: SessionJournal, specs: dict) -> dict:
    """Rebuild every journaled session on a fresh ``server`` by replaying
    each stream through the REAL ``extend`` path.

    ``specs`` maps sid -> the base :class:`SelectionSpec` the session was
    opened around (specs hold live function objects, so the journal cannot
    reconstruct them itself; persist them with ``spec.to_dict()`` /
    ``from_dict`` or rebuild from your own config).  Returns
    ``{sid: SelectionSession}`` — each replayed to the exact state the lost
    server held: same stream, same selection (ids / gains / n_evals), same
    ``seq``.  Replayed deltas are NOT re-journaled (the journal already has
    them) and do not consult fault plans — recovery itself is not a fault
    boundary.
    """
    restored: dict = {}
    for sid in journal.sessions():
        if sid not in specs:
            raise KeyError(
                f"journal has session {sid!r} but specs= does not; pass its "
                f"base SelectionSpec to replay it"
            )
        session = SelectionSession(server, specs[sid], sid=sid, journal=journal)
        session._replaying = True
        try:
            with faults.suspended():  # recovery is not a fault boundary
                for delta in journal.deltas(sid):
                    if delta["seq"] != session._seq + 1:
                        raise RuntimeError(
                            f"journal for session {sid!r} is not contiguous: "
                            f"expected seq {session._seq + 1}, got {delta['seq']}"
                        )
                    kw = (
                        {"features": delta["payload"]}
                        if delta["mode"] == "features"
                        else {"indices": delta["payload"]}
                    )
                    upd = session.extend(**kw)
                    if isinstance(upd, Future):  # async: force the wave now
                        server.flush_now()
                        upd.result()
        finally:
            session._replaying = False
        restored[sid] = session
    return restored


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------


class SelectionSession:
    """Per-client state across waves: the stream so far, the id mapping,
    and churn accounting.  Build one with ``server.open_session(spec)``.

    Thread-safety: stream order is submission order — ``extend`` mutates
    the accumulated stream and submits under one lock, so concurrent
    extends serialize into a well-defined stream.  Async completions
    (churn bookkeeping) take the same lock.

    ``sid`` names the session (auto-generated when omitted); with a
    ``journal``, every committed delta's raw input is appended under that
    sid so :func:`restore_sessions` can replay the session after a crash.
    A delta is journaled when it COMMITS (enqueued into the server), before
    its dispatch resolves — a delta whose dispatch later fails stays both
    committed and journaled, matching the stream semantics (the failed
    extend raised, but the stream already advanced; replay reproduces that
    state exactly).
    """

    # the public accounting attrs (deltas_absorbed / churn_total /
    # last_update) are documented benign-snapshot reads and stay undeclared
    _GUARDED_BY = {
        "_mode": "_lock",
        "_fn": "_lock",
        "_active": "_lock",
        "_seen": "_lock",
        "_prev_ids": "_lock",
        "_seq": "_lock",
        "_closed": "_lock",
    }

    _SID_COUNTER = itertools.count()

    def __init__(
        self,
        server,
        spec: SelectionSpec,
        *,
        sid: str | None = None,
        journal: "SessionJournal | None" = None,
    ):
        if not isinstance(spec, SelectionSpec):
            raise TypeError(
                f"open_session() takes a SelectionSpec, got {type(spec).__name__!r}"
            )
        self.sid = (
            sid if sid is not None else f"s{next(SelectionSession._SID_COUNTER)}"
        )
        self._journal = journal
        self._replaying = False  # restore_sessions: suppress re-journaling
        self._server = server
        self._async = isinstance(server, AsyncSelectionServer)
        self._metrics = server.metrics
        self._spec = spec
        self._lock = threading.Lock()
        self._mode: str | None = None  # "features" | "indices", set by 1st extend
        self._fn = spec.fn  # features mode: the concatenated-stream function
        self._active: list[int] = []  # indices mode: universe ids, arrival order
        self._seen: set[int] = set()
        self._prev_ids: set = set()
        self._seq = 0
        self._closed = False
        self.deltas_absorbed = 0
        self.churn_total = 0
        self.last_update: SessionUpdate | None = None
        self._metrics.inc("sessions_opened")

    # -- client API ----------------------------------------------------------

    @property
    def mode(self) -> str | None:
        return self._mode  # lint: ok(LOCKDISC): benign racy snapshot read for observability

    @property
    def closed(self) -> bool:
        return self._closed  # lint: ok(LOCKDISC): benign racy snapshot read for observability

    def extend(self, features=None, indices=None):
        """Absorb one delta and re-select over the full stream.

        Exactly one of ``features`` (new raw rows for the session family's
        extender) or ``indices`` (universe ids to unlock; repeats are
        ignored) must be given; the first call fixes the session's mode.
        Returns a :class:`SessionUpdate` on a sync server, or a Future
        resolving to one on an async server (cancelled if the server drops
        the delta via ``close(flush=False)``).  Raises
        :class:`~repro.launch.serve.ServerOverloaded` synchronously when
        the server applies backpressure.
        """
        if (features is None) == (indices is None):
            raise TypeError("extend() takes exactly one of features= or indices=")
        want = "features" if features is not None else "indices"
        t0 = time.monotonic()
        with self._lock:
            if self._closed:
                raise SessionClosed("extend() on a closed SelectionSession")
            if self._mode is not None and self._mode != want:
                raise ValueError(
                    f"session is in {self._mode!r} mode; extend() cannot "
                    f"switch to {want!r} deltas"
                )
            # the "session-extend" fault boundary: fires BEFORE the delta is
            # built, so an injected fault leaves the stream untouched (the
            # retryable position — the client re-extends)
            faults.check(
                "session-extend",
                session=self.sid,
                seq=self._seq + 1,
                mode=want,
                family=type(self._spec.fn).__name__,
            )
            # build the delta WITHOUT committing, submit, then commit — so a
            # failed extend (unsupported family, ServerOverloaded) leaves the
            # stream untouched and a retry cannot double-append the delta
            if want == "features":
                rows = np.asarray(features, np.float32)
                n_delta = int(rows.shape[0])
                fn = (
                    resolve_extender(type(self._fn))(self._fn, rows)
                    if n_delta
                    else self._fn
                )
                active = None
                n_total = int(fn.n)
            else:
                raw_idx = np.asarray(indices, np.int64).reshape(-1)
                fresh = []
                for i in raw_idx:
                    i = int(i)
                    if not 0 <= i < self._spec.fn.n:
                        raise ValueError(
                            f"index {i} outside the universe "
                            f"[0, {self._spec.fn.n})"
                        )
                    if i not in self._seen and i not in fresh:
                        fresh.append(i)
                if not self._active and not fresh:
                    raise ValueError(
                        "the first indices delta must unlock at least one "
                        "universe element"
                    )
                n_delta = len(fresh)
                active = np.asarray(self._active + fresh, np.int32)
                fn = resolve_restrictor(type(self._spec.fn))(self._spec.fn, active)
                n_total = int(active.shape[0])
            spec = SelectionSpec(
                fn,
                min(self._spec.budget, n_total),
                self._spec.optimizer,
                stopIfZeroGain=self._spec.stop_if_zero,
                stopIfNegativeGain=self._spec.stop_if_negative,
                use_kernel=self._spec.use_kernel,
                deadline_s=self._spec.deadline_s,
                retry=self._spec.retry,  # deltas inherit the session's policy
            )
            if self._async:
                inner = self._server.submit(spec)  # may raise ServerOverloaded
            else:
                rid = self._server.submit_spec(spec)  # ditto
                inner = None
            # the delta is enqueued: commit it to the session's stream
            self._mode = want
            if want == "features":
                self._fn = fn
            else:
                self._seen.update(fresh)
                self._active.extend(fresh)
            seq = self._seq = self._seq + 1
            if self._journal is not None and not self._replaying:
                # journal the committed delta's RAW input — replay will
                # re-preprocess it through this same extend path
                self._journal.append(
                    self.sid, seq, want, rows if want == "features" else raw_idx
                )
        if not self._async:
            out = self._server.flush()
            resp = out.pop(rid, None)
            self._server.hold_undelivered(out)  # co-travellers' answers
            if resp is None:
                # resilient flush: the delta exhausted its retries and
                # resolved to a typed failure instead of a response
                fails = self._server.take_failures()
                err = fails.pop(rid, None)
                if fails:
                    self._server.hold_failures(fails)  # not ours to consume
                if err is None:
                    raise KeyError(
                        f"flush returned no response for session delta {rid!r}"
                    )
                raise err
            return self._absorb(resp, seq, n_total, n_delta, active, t0)

        out: Future = Future()

        def _chain(done: Future):
            if done.cancelled():
                out.cancel()
                return
            exc = done.exception()
            if exc is not None:
                out.set_exception(exc)
                return
            try:
                upd = self._absorb(done.result(), seq, n_total, n_delta, active, t0)
            except BaseException as e:  # never strand the chained future
                out.set_exception(e)
                return
            out.set_result(upd)

        inner.add_done_callback(_chain)
        return out

    def close(self) -> None:
        """Mark the session closed (idempotent).  In-flight async deltas
        still resolve; further ``extend`` calls raise
        :class:`SessionClosed`."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._metrics.inc("sessions_closed")

    def __enter__(self) -> "SelectionSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals -----------------------------------------------------------

    def _absorb(self, resp, seq, n_total, n_delta, active, t0) -> SessionUpdate:
        if active is None:  # features mode: ids are already stream positions
            selection = [(int(j), float(g)) for j, g in resp.selection]
        else:  # indices mode: map active-list positions back to universe ids
            selection = [(int(active[j]), float(g)) for j, g in resp.selection]
        latency = time.monotonic() - t0
        ids = {j for j, _ in selection}
        with self._lock:
            churn = len(self._prev_ids ^ ids)
            self._prev_ids = ids
            self.deltas_absorbed += 1
            self.churn_total += churn
            upd = SessionUpdate(
                seq=seq,
                selection=selection,
                result=resp.result,
                response=resp,
                n_total=n_total,
                n_delta=n_delta,
                churn=churn,
                latency_s=latency,
            )
            self.last_update = upd
        self._metrics.observe_delta(latency, churn=churn)
        return upd
