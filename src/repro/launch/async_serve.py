"""Async selection serving: futures over the synchronous coalescer.

:class:`AsyncSelectionServer` wraps a :class:`~repro.launch.serve.SelectionServer`
with the two flush triggers a latency-bounded deployment needs:

- **queue depth**: the moment ``max_pending`` requests are waiting, a flush
  dispatches them as coalesced waves (throughput trigger);
- **timer**: a request never waits longer than ``flush_interval`` seconds
  for co-travellers — a lone request is dispatched when its deadline hits
  (latency trigger).

``submit(spec)`` returns a ``concurrent.futures.Future`` that resolves to
the request's :class:`~repro.launch.serve.SelectionResponse` (await it from
asyncio via ``asyncio.wrap_future``).  Because requests are already
:class:`~repro.core.optimizers.spec.SelectionSpec` objects, the wrapper
reuses ``coalesce()`` and the batched engines **unchanged** — same waves,
same padding, same bit-identical results as synchronous serving and
sequential ``solve()``.

    server = AsyncSelectionServer(max_pending=16, flush_interval=0.02)
    fut = server.submit(SelectionSpec(fn, budget))
    response = fut.result()          # [(index, gain), ...] in .selection
    server.close()                   # or use it as a context manager

Thread-safety: all SelectionServer state is touched under one lock, by the
submitting thread (validation) and the flush thread (dispatch).  Dispatch
holds the lock — submissions arriving mid-flush enqueue as soon as it
completes and ride the next wave, which is the coalescing behaviour a
synchronous flush loop would give them anyway.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

from repro.core.optimizers.spec import SelectionSpec
from repro.launch.serve import SelectionServer


class AsyncSelectionServer:
    """Timer / queue-depth triggered flush wrapper around ``SelectionServer``.

    Args:
      server: an existing :class:`SelectionServer` to drive, or None to
        build one from ``mesh`` / ``max_wave`` / axis names.
      max_pending: flush as soon as this many requests are waiting.
      flush_interval: flush whenever the OLDEST pending request has waited
        this many seconds (so a lone request is never stranded).
      mesh, batch_axis, data_axis, max_wave: forwarded to the internal
        ``SelectionServer`` when ``server`` is None.
    """

    def __init__(
        self,
        server: SelectionServer | None = None,
        *,
        max_pending: int = 16,
        flush_interval: float = 0.05,
        mesh=None,
        batch_axis: str = "batch",
        data_axis: str = "data",
        max_wave: int = 64,
    ):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if flush_interval <= 0:
            raise ValueError(
                f"flush_interval must be positive, got {flush_interval}"
            )
        self._server = (
            server
            if server is not None
            else SelectionServer(
                mesh=mesh,
                batch_axis=batch_axis,
                data_axis=data_axis,
                max_wave=max_wave,
            )
        )
        self.max_pending = int(max_pending)
        self.flush_interval = float(flush_interval)
        self._cv = threading.Condition()
        self._futures: dict = {}  # rid -> Future, for the NEXT flush
        self._oldest: float | None = None  # monotonic enqueue time
        self._closed = False
        self.flushes = 0  # completed flush count (observability / tests)
        self._thread = threading.Thread(
            target=self._loop, name="AsyncSelectionServer", daemon=True
        )
        self._thread.start()

    # -- client API ----------------------------------------------------------

    def submit(self, spec: SelectionSpec, rid=None) -> Future:
        """Enqueue one :class:`SelectionSpec`; returns a Future resolving to
        its :class:`~repro.launch.serve.SelectionResponse`.

        Validation is synchronous and immediate (unsupported family /
        non-batched optimizer raise HERE, exactly like
        ``SelectionServer.submit_spec``); only the dispatch is deferred to a
        flush trigger.  Awaitable from asyncio via ``asyncio.wrap_future``.
        """
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncSelectionServer is closed")
            rid = self._server.submit_spec(spec, rid=rid)
            fut: Future = Future()
            self._futures[rid] = fut
            if self._oldest is None:
                self._oldest = time.monotonic()
            self._cv.notify_all()  # depth trigger is evaluated in the loop
        return fut

    def flush_now(self) -> None:
        """Dispatch everything pending immediately (manual trigger)."""
        with self._cv:
            self._flush_locked()

    def close(self, flush: bool = True) -> None:
        """Stop the flush thread.  Pending futures are dispatched first when
        ``flush`` (default) — otherwise they are cancelled."""
        with self._cv:
            if self._closed:
                return
            if flush:
                self._flush_locked()
            else:
                for fut in self._futures.values():
                    fut.cancel()
                self._futures.clear()
                self._oldest = None
            self._closed = True
            self._cv.notify_all()
        self._thread.join()

    def __enter__(self) -> "AsyncSelectionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._futures)

    @property
    def stats(self):
        """The wrapped server's aggregate accounting."""
        return self._server.stats

    # -- flush machinery -----------------------------------------------------

    def _flush_locked(self) -> None:
        """Dispatch pending requests and complete their futures.  Caller
        holds the condition lock."""
        if not self._futures:
            return
        futures, self._futures = self._futures, {}
        self._oldest = None
        try:
            responses = self._server.flush()
        except BaseException as e:  # complete ALL futures, never strand one
            for fut in futures.values():
                if not fut.cancelled():
                    fut.set_exception(e)
            return
        self.flushes += 1
        for rid, fut in futures.items():
            if fut.cancelled():
                continue
            if rid in responses:
                fut.set_result(responses.pop(rid))
            else:  # cannot happen while flush() returns every rid; be loud
                fut.set_exception(
                    KeyError(f"flush returned no response for rid {rid!r}")
                )
        if responses:
            # requests enqueued directly on the wrapped sync server rode this
            # flush; re-hold their responses for the sync caller's flush()
            self._server.hold_undelivered(responses)

    def _loop(self) -> None:
        with self._cv:
            while not self._closed:
                now = time.monotonic()
                deadline = (
                    None
                    if self._oldest is None
                    else self._oldest + self.flush_interval
                )
                if len(self._futures) >= self.max_pending or (
                    deadline is not None and now >= deadline
                ):
                    self._flush_locked()
                    continue
                # wait for a trigger: a submit notification, the oldest
                # request's deadline, or close()
                self._cv.wait(
                    timeout=None if deadline is None else deadline - now
                )
