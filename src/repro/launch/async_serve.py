"""Async selection serving: futures over the per-group coalescing server.

:class:`AsyncSelectionServer` wraps a :class:`~repro.launch.serve.SelectionServer`
with the flush triggers a latency-bounded deployment needs, evaluated
**per (family, n-bucket) group** — continuous batching, not a global flush:

- **queue depth**: the moment a group holds ``max_pending`` requests, THAT
  group flushes (throughput trigger) — other groups keep waiting for their
  own co-travellers;
- **timer**: a group flushes once its oldest request has waited
  ``flush_interval`` seconds, so a lone request is never stranded
  (latency trigger);
- **deadline**: a request whose spec carries ``deadline_s`` stops its group
  from waiting past that deadline (the scheduler dispatches at the deadline
  at the latest; wave wall time may still push completion past it, which is
  counted under ``deadline_misses`` and flagged on the response).

``submit(spec)`` returns a ``concurrent.futures.Future`` that resolves to
the request's :class:`~repro.launch.serve.SelectionResponse` (await it from
asyncio via ``asyncio.wrap_future``).  With ``max_queue`` set on the server,
``submit`` applies **backpressure**: it raises
:class:`~repro.launch.serve.ServerOverloaded` when the server is full, or —
with ``block=True`` — waits until a flush frees space.  Because requests
are already :class:`~repro.core.optimizers.spec.SelectionSpec` objects, the
wrapper reuses the coalescer and the batched engines **unchanged** — same
waves, same padding, same bit-identical results as synchronous serving and
sequential ``solve()``.

    server = AsyncSelectionServer(max_pending=16, flush_interval=0.02)
    fut = server.submit(SelectionSpec(fn, budget))
    response = fut.result()          # [(index, gain), ...] in .selection
    server.close()                   # or use it as a context manager

Thread-safety and the lock discipline (the fix for head-of-line blocking):
the condition lock guards ONLY the queues and the futures map.  A flush
swaps the due groups' requests and futures out under the lock, then runs
the engine dispatch OUTSIDE it (serialized by a separate dispatch lock), so
``submit`` never blocks behind an executing wave — a submission arriving
mid-flush enqueues immediately and rides its group's next wave.

Failure discipline: an engine error mid-flush completes the poisoned wave's
futures exceptionally with the engine's original exception, re-enqueues
every never-dispatched request (futures intact — they ride the next flush),
and delivers the responses that did complete.  Corner case: a request
submitted directly on the wrapped *sync* server that lands in a poisoned
async wave has no future to complete and is not requeued — its loss is
reported only through ``flush_errors``; keep sync and async front ends on
separate servers if that matters.

Resilient mode: when the wrapped server carries a ``retry_policy`` (or any
spec its own ``retry``), dispatch runs the server's retry / poison-isolation
/ quarantine path instead — transient failures retry with backoff, and a
request that exhausts its budget resolves its future exceptionally with a
typed :class:`~repro.launch.resilience.RequestFailed` (never a bare engine
error, never a stranded future).  Wave-build (padder) failures are always
handled resiliently here, whatever the policy: they fail the affected
requests typed instead of killing the flush thread.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

from repro.core.optimizers.spec import SelectionSpec
from repro.launch.serve import FlushError, SelectionServer


class AsyncSelectionServer:
    """Per-group depth / timer / deadline triggered flush wrapper around
    ``SelectionServer``.

    Args:
      server: an existing :class:`SelectionServer` to drive, or None to
        build one from ``mesh`` / ``max_wave`` / axis names.
      max_pending: flush a group as soon as it holds this many requests.
      flush_interval: flush a group whenever its OLDEST pending request has
        waited this many seconds (so a lone request is never stranded).
      max_queue: backpressure cap on total pending requests (sets the
        wrapped server's ``max_queue``); None leaves the server's own
        setting untouched.
      block: default for ``submit(..., block=)`` — True makes a full-queue
        submit wait for space instead of raising ``ServerOverloaded``.
      mesh, batch_axis, data_axis, max_wave: forwarded to the internal
        ``SelectionServer`` when ``server`` is None.
    """

    # the two-lock protocol: _cv guards the queues + futures map ONLY;
    # engine dispatch runs under _dispatch_lock with _cv released so new
    # submits never block behind a running wave (enforced by lint LOCKDISC)
    _GUARDED_BY = {"_futures": "_cv", "_closed": "_cv"}

    def __init__(
        self,
        server: SelectionServer | None = None,
        *,
        max_pending: int = 16,
        flush_interval: float = 0.05,
        max_queue: int | None = None,
        block: bool = False,
        mesh=None,
        batch_axis: str = "batch",
        data_axis: str = "data",
        max_wave: int = 64,
    ):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if flush_interval <= 0:
            raise ValueError(
                f"flush_interval must be positive, got {flush_interval}"
            )
        self._server = (
            server
            if server is not None
            else SelectionServer(
                mesh=mesh,
                batch_axis=batch_axis,
                data_axis=data_axis,
                max_wave=max_wave,
            )
        )
        if max_queue is not None:
            if max_queue < 1:
                raise ValueError(f"max_queue must be >= 1 or None, got {max_queue}")
            self._server.max_queue = int(max_queue)
        self.max_pending = int(max_pending)
        self.flush_interval = float(flush_interval)
        self.block = bool(block)
        self._cv = threading.Condition()  # guards queues + futures map ONLY
        self._dispatch_lock = threading.Lock()  # serializes engine dispatch
        self._futures: dict = {}  # rid -> Future, for requests not yet drained
        self._closed = False
        self.flushes = 0  # completed (error-free) flush count
        self._thread = threading.Thread(
            target=self._loop, name="AsyncSelectionServer", daemon=True
        )
        self._thread.start()

    # -- client API ----------------------------------------------------------

    def submit(self, spec: SelectionSpec, rid=None, *, block: bool | None = None) -> Future:
        """Enqueue one :class:`SelectionSpec`; returns a Future resolving to
        its :class:`~repro.launch.serve.SelectionResponse`.

        Validation is synchronous and immediate (unsupported family /
        non-batched optimizer raise HERE, exactly like
        ``SelectionServer.submit_spec``); only the dispatch is deferred to a
        flush trigger.  When the server is at ``max_queue``: raises
        :class:`~repro.launch.serve.ServerOverloaded` (counted under
        ``rejections``), or with ``block=True`` waits until a flush frees
        space.  Awaitable from asyncio via ``asyncio.wrap_future``.
        """
        if block is None:
            block = self.block
        with self._cv:
            while True:
                if self._closed:
                    raise RuntimeError("AsyncSelectionServer is closed")
                cap = self._server.max_queue
                if not block or cap is None or self._server.pending_count < cap:
                    break
                self._cv.wait()  # a drain or cancel will notify
            rid = self._server.submit_spec(spec, rid=rid)
            fut: Future = Future()
            self._futures[rid] = fut
            self._cv.notify_all()  # triggers are evaluated in the loop
        return fut

    def open_session(self, spec: SelectionSpec, *, sid=None, journal=None):
        """Open a :class:`~repro.launch.sessions.SelectionSession` whose
        ``extend`` returns Futures: each delta submits through this front
        end's triggers and resolves to a ``SessionUpdate`` when its wave
        lands.  ``close(flush=False)`` cancels in-flight delta futures;
        a full queue raises ``ServerOverloaded`` at ``extend`` time.
        ``sid`` / ``journal`` enable crash recovery, see
        :func:`~repro.launch.sessions.restore_sessions`."""
        from repro.launch.sessions import SelectionSession

        return SelectionSession(self, spec, sid=sid, journal=journal)

    def flush_now(self) -> None:
        """Drain every group and dispatch immediately in the calling thread
        (manual trigger).  Safe to race the timer: draining is atomic under
        the condition lock, so each request is dispatched exactly once —
        whoever drains it first owns it."""
        with self._cv:
            batch = self._drain_locked(None)
        if batch is not None:
            self._execute(batch)

    def close(self, flush: bool = True) -> None:
        """Stop the flush thread.  Pending futures are dispatched first when
        ``flush`` (default) — otherwise they are cancelled AND their
        requests removed from the wrapped server's queues (no orphans for a
        later sync ``flush()`` to trip over).  A wave already executing
        completes either way; its futures resolve normally.

        Order matters: the worker is JOINED before the final drain.  An
        in-flight ``_execute`` may, on a flush error, requeue undispatched
        requests and reinstate their futures — draining before the join
        would miss those and strand their futures forever.  The final drain
        loops until the queues are empty for the same reason: the close-time
        dispatch itself may requeue."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()  # wake the loop and any blocked submitters
        self._thread.join()  # an in-flight _execute finishes (and may requeue)
        if flush:
            while True:
                with self._cv:
                    batch = self._drain_locked(None)
                if batch is None:
                    break
                self._execute(batch)
        else:
            with self._cv:
                for rid, fut in self._futures.items():
                    fut.cancel()
                    self._server.cancel(rid)
                self._futures.clear()
                self._cv.notify_all()

    def __enter__(self) -> "AsyncSelectionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._futures)

    @property
    def stats(self):
        """The wrapped server's aggregate accounting."""
        return self._server.stats

    @property
    def metrics(self):
        """The wrapped server's structured metric tree."""
        return self._server.metrics

    # -- flush machinery -----------------------------------------------------

    def _due_groups(self, now: float):
        """(due group keys, earliest future trigger time).  A group is due
        when its depth hits ``max_pending`` or ``now`` reached its trigger —
        the oldest member's ``enqueue_t + flush_interval``, pulled earlier
        by the group's earliest deadline."""
        due, wake_at = [], None
        for key, depth, oldest_t, deadline_t in self._server.group_states():
            trigger_t = oldest_t + self.flush_interval
            if deadline_t is not None:
                trigger_t = min(trigger_t, deadline_t)
            if depth >= self.max_pending or now >= trigger_t:
                due.append(key)
            elif wake_at is None or trigger_t < wake_at:
                wake_at = trigger_t
        return due, wake_at

    def _drain_locked(self, keys):
        """Swap the due groups' requests and futures out of shared state.
        Caller holds the condition lock.  Returns ``(waves, futures)`` or
        None when nothing was pending.

        Always drains via the server's resilient path: a wave-build (padder)
        error costs one group — its exhausted requests fail their futures
        typed HERE, its retryable ones stay queued for a later trigger —
        instead of raising out of the flush thread's loop and killing it.
        Without any retry policy the behavior is single-attempt (immediate
        typed failure), so the legacy dispatch contract is unchanged."""
        waves, _, failures, _ = self._server.drain_resilient(
            keys, take_undelivered=False
        )
        sync_owned = {}
        for rid, err in failures.items():
            fut = self._futures.pop(rid, None)
            if fut is None:
                sync_owned[rid] = err  # sync submitter: surfaces take_failures
            elif not fut.cancelled():
                fut.set_exception(err)
        if sync_owned:
            self._server.hold_failures(sync_owned)
        if not waves:
            if failures:
                self._cv.notify_all()  # queue space freed by the reap
            return None
        futures = {}
        for wave in waves:
            for req in wave.requests:
                fut = self._futures.pop(req.rid, None)
                if fut is not None:
                    futures[req.rid] = fut
        self._cv.notify_all()  # queue space freed: wake blocked submitters
        return waves, futures

    def _execute(self, batch) -> None:
        """Dispatch drained waves OUTSIDE the condition lock and complete
        their futures.  The dispatch lock serializes engine use across the
        flush thread, ``flush_now`` callers, and ``close``.

        With a retry policy in play (server-wide or on any rider's spec)
        this runs the server's resilient dispatch: transient failures retry
        with backoff inside the dispatch lock, exhausted requests resolve
        their futures with typed
        :class:`~repro.launch.resilience.RequestFailed`.  Otherwise the
        legacy single-attempt :class:`FlushError` discipline applies
        unchanged."""
        waves, futures = batch
        resilient = self._server.retry_policy is not None or any(
            req.spec.retry is not None for w in waves for req in w.requests
        )
        if resilient:
            try:
                with self._dispatch_lock:
                    responses, failures = self._server.dispatch_resilient(waves)
            except BaseException as e:  # never strand a future
                for fut in futures.values():
                    if not fut.cancelled():
                        fut.set_exception(e)
                return
            sync_owned = {}
            for rid, err in failures.items():
                fut = futures.pop(rid, None)
                if fut is None:
                    sync_owned[rid] = err
                elif not fut.cancelled():
                    fut.set_exception(err)
            if sync_owned:
                with self._cv:
                    self._server.hold_failures(sync_owned)
            self.flushes += 1
            self._complete(responses, futures)
            return
        try:
            with self._dispatch_lock:
                responses = self._server.dispatch_waves(waves)
        except FlushError as e:
            self._complete_partial(e, futures)
            return
        except BaseException as e:  # complete ALL futures, never strand one
            for fut in futures.values():
                if not fut.cancelled():
                    fut.set_exception(e)
            return
        self.flushes += 1
        self._complete(responses, futures)

    def _complete(self, responses: dict, futures: dict) -> None:
        for rid, fut in futures.items():
            resp = responses.pop(rid, None)
            if fut.cancelled():
                continue
            if resp is not None:
                fut.set_result(resp)
            else:  # cannot happen while dispatch returns every rid; be loud
                fut.set_exception(
                    KeyError(f"flush returned no response for rid {rid!r}")
                )
        if responses:
            # requests enqueued directly on the wrapped sync server rode this
            # flush; re-hold their responses for the sync caller's flush()
            with self._cv:
                self._server.hold_undelivered(responses)

    def _complete_partial(self, e: FlushError, futures: dict) -> None:
        """An engine error mid-dispatch: deliver what completed, requeue
        what never ran (futures intact), and fail the poisoned wave's
        futures with the engine's own exception."""
        responses = dict(e.completed)
        for rid in list(futures):
            if rid in responses:
                fut = futures.pop(rid)
                resp = responses.pop(rid)
                if not fut.cancelled():
                    fut.set_result(resp)
        with self._cv:
            if responses:  # sync-owned responses that completed
                self._server.hold_undelivered(responses)
            if e.undispatched_requests:
                self._server.requeue(e.undispatched_requests)
                for req in e.undispatched_requests:
                    fut = futures.pop(req.rid, None)
                    if fut is not None:
                        self._futures[req.rid] = fut  # rides the next flush
            self._cv.notify_all()
        # what remains is the poisoned wave: complete exceptionally with the
        # engine's cause (NOT requeued — retrying a poisoned wave forever
        # would livelock the timer; the client decides whether to resubmit)
        cause = e.__cause__ or e
        for fut in futures.values():
            if not fut.cancelled():
                fut.set_exception(cause)

    def _loop(self) -> None:
        while True:
            with self._cv:
                if self._closed:
                    return
                now = time.monotonic()
                due, wake_at = self._due_groups(now)
                if not due:
                    timeout = None if wake_at is None else max(0.0, wake_at - now)
                    self._cv.wait(timeout=timeout)
                    continue
                batch = self._drain_locked(due)
            if batch is not None:
                self._execute(batch)
