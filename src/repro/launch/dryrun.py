import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

_DOC = """Multi-pod compile-only dry-run: cost/memory analysis without devices.

For every applicable (arch × shape) cell, on the single-pod 16x16 mesh and
the 2x16x16 multi-pod mesh:

    lowered  = jit(step, in_shardings=..., donate...).lower(*abstract_args)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / collective-bytes from the SPMD HLO

Results land in a json per cell (benchmarks/roofline.py turns them into the
EXPERIMENTS.md tables).  Also dry-runs the paper's technique itself: the
distributed FL selection step on the production mesh (--arch selection).

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
        --mesh single --out results/dryrun
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.archs import ALL_ARCHS
from repro.configs.base import get_config
from repro.distributed.act_sharding import activation_sharding
from repro.distributed.sharding import batch_specs, cache_specs, param_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_applicable, input_specs

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_INSTR_RE = re.compile(
    r"^%?[\w.\-]+ = ((?:\([^)]*\))|(?:[\w\[\],{}\s]*?)) ("
    + "|".join(_COLLECTIVES)
    + r")(-start|-done)?\("
)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device RESULT bytes of every collective in the SPMD module.

    Operands print as %refs in this HLO dialect, so we count result shapes:
    all-reduce result == payload; all-gather result == received bytes;
    reduce-scatter result == kept shard (lower bound); -done ops skipped to
    avoid double-counting async pairs."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        m = _INSTR_RE.match(s)
        if not m or m.group(3) == "-done":
            continue
        c = m.group(2)
        bytes_ = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(m.group(1))
        )
        out[c] += bytes_
        count[c] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = count
    return out


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def build_step(cfg, cell, mesh, policy: str = "auto"):
    """Returns (fn, abstract_args, in_shardings, donate) for the cell."""
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import auto_policy
    from repro.models.model import decode_step, init_cache, prefill, train_forward
    from repro.train.train_step import init_train_state, make_train_step

    if policy == "auto":
        policy = auto_policy(cfg.param_count())
    batch_abs = input_specs(cfg, cell)
    ns = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree
    )

    if cell.kind == "train":
        state_abs = init_train_state(cfg, abstract=True)
        step = make_train_step(cfg)
        state_sh = ns(param_specs(state_abs, mesh, policy))
        batch_sh = ns(batch_specs(batch_abs, mesh, policy=policy))
        return step, (state_abs, batch_abs), (state_sh, batch_sh), (0,), policy

    from repro.models.model import init_params

    params_abs = init_params(cfg, abstract=True)
    params_sh = ns(param_specs(params_abs, mesh, policy))

    if cell.kind == "prefill":

        def step(params, batch):
            return prefill(cfg, params, batch, max_len=cell.seq_len)

        batch_sh = ns(batch_specs(batch_abs, mesh, policy=policy))
        return step, (params_abs, batch_abs), (params_sh, batch_sh), (), policy

    # decode
    cache_abs = init_cache(cfg, cell.global_batch, cell.seq_len, abstract=True)
    if cfg.family == "audio":
        # decode against a filled cross-attn encoder output too
        pass
    cache_sh = ns(cache_specs(cache_abs, mesh, cell.global_batch, cell.seq_len))
    tok_abs = batch_abs["tokens"]
    tok_sh = ns(batch_specs(tok_abs, mesh, shard_batch=cell.global_batch > 1))
    len_abs = jax.ShapeDtypeStruct((), jnp.int32)
    len_sh = NamedSharding(mesh, jax.sharding.PartitionSpec())

    def step(params, caches, tokens, cache_len):
        return decode_step(cfg, params, caches, tokens, cache_len)

    return (
        step,
        (params_abs, cache_abs, tok_abs, len_abs),
        (params_sh, cache_sh, tok_sh, len_sh),
        (1,),
        policy,
    )


def build_selection_step(
    mesh,
    pool: int = 1 << 20,
    dim: int = 1024,
    budget: int = 512,
    variant: str = "dense",
):
    """The paper's technique on the production mesh: distributed FL greedy
    over a (rows x pool) kernel built from sharded embeddings.

    variants (§Perf-3): dense fp32 baseline | stochastic sampling sweep |
    bf16 kernel storage | stochastic+bf16."""
    from repro.core.optimizers.distributed import (
        distributed_fl_greedy,
        distributed_stochastic_fl_greedy,
    )
    from repro.distributed.sharding import data_axes
    from jax.sharding import NamedSharding, PartitionSpec as P

    rows = 1 << 14  # represented-set subsample (rows), cols = full pool
    dtype = jnp.bfloat16 if "bf16" in variant else jnp.float32
    sim_abs = jax.ShapeDtypeStruct((rows, pool), dtype)
    dp = data_axes(mesh)

    if "stochastic" in variant:
        key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

        def step(sim, key):
            return distributed_stochastic_fl_greedy(
                sim, budget, mesh, key, sample_per_shard=1024,
                row_axes=("model",), col_axes=dp,
            )

        return (
            step,
            (sim_abs, key_abs),
            (NamedSharding(mesh, P("model", dp)), NamedSharding(mesh, P())),
            (),
        )

    def step(sim):
        return distributed_fl_greedy(
            sim, budget, mesh, row_axes=("model",), col_axes=dp
        )

    sim_sh = NamedSharding(mesh, P("model", dp))
    return step, (sim_abs,), (sim_sh,), ()


def _compile_once(fn, args, shardings, donate, mesh, policy="fsdp"):
    t0 = time.monotonic()
    with activation_sharding(mesh, policy=policy), jax.sharding.set_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
    return compiled, t_lower, t_compile


def _depth_variants(cfg):
    """Two reduced-DEPTH (full-width, full-shape) configs (L1, L2) such that
    per-layer costs extrapolate affinely:  cost(L) = cost(L1) +
    (L - L1)/(L2 - L1) * (cost(L2) - cost(L1)).   Layer structure repeats
    with period p (hybrid: attn_every; moe: 1 after first_dense_layers), so
    variants step by one period."""
    import dataclasses

    if cfg.family == "hybrid":
        p = cfg.attn_every
        return (
            dataclasses.replace(cfg, n_layers=p),
            dataclasses.replace(cfg, n_layers=2 * p),
        )
    if cfg.family == "audio":
        return (
            dataclasses.replace(cfg, n_layers=1, enc_layers=1),
            dataclasses.replace(cfg, n_layers=2, enc_layers=2),
        )
    pre = cfg.first_dense_layers if cfg.n_experts else 0
    return (
        dataclasses.replace(cfg, n_layers=pre + 1),
        dataclasses.replace(cfg, n_layers=pre + 2),
    )


def _extrapolate(v1: float, v2: float, l1: int, l2: int, l: int) -> float:
    return v1 + (v2 - v1) * (l - l1) / (l2 - l1)


def _measure_costs(cfg, cell, mesh) -> dict:
    """Unrolled two-depth measurement -> per-device flops / bytes /
    collective bytes extrapolated to the full depth."""
    from repro.models.model import set_unroll

    set_unroll(True)
    try:
        c1, c2 = _depth_variants(cfg)
        out = []
        for c in (c1, c2):
            fn, args, shardings, donate, policy = build_step(c, cell, mesh)
            compiled, _, _ = _compile_once(fn, args, shardings, donate, mesh, policy)
            cost = _cost_analysis(compiled)
            coll = collective_bytes_from_hlo(compiled.as_text())
            out.append(
                {
                    "flops": cost.get("flops", 0.0),
                    "bytes": cost.get("bytes accessed", 0.0),
                    "coll": coll,
                }
            )
            del compiled
        l1, l2, L = c1.n_layers, c2.n_layers, cfg.n_layers
        coll_full = {
            k: _extrapolate(out[0]["coll"][k], out[1]["coll"][k], l1, l2, L)
            for k in _COLLECTIVES
        }
        coll_full["total"] = sum(coll_full.values())
        return {
            "flops_per_device": _extrapolate(
                out[0]["flops"], out[1]["flops"], l1, l2, L
            ),
            "bytes_per_device": _extrapolate(
                out[0]["bytes"], out[1]["bytes"], l1, l2, L
            ),
            "collectives": coll_full,
            "depth_probe": {"l1": l1, "l2": l2, "raw": out},
        }
    finally:
        set_unroll(False)


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str | None,
             skip_costs: bool = False):
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    if arch == "selection":
        variant = {
            "select_1m": "dense",
            "select_1m_stoch": "stochastic",
            "select_1m_bf16": "bf16",
            "select_1m_stoch_bf16": "stochastic_bf16",
        }.get(shape, "dense")
        fn, args, shardings, donate = build_selection_step(mesh, variant=variant)
        cfg = None
        cell = None
        policy = "fsdp"
    else:
        cfg = get_config(arch)
        cell = SHAPES[shape]
        fn, args, shardings, donate, policy = build_step(cfg, cell, mesh)

    # phase 1 — the production (scanned) program: THE compile proof + memory
    compiled, t_lower, t_compile = _compile_once(
        fn, args, shardings, donate, mesh, policy
    )
    mem = _mem_analysis(compiled)
    coll_scanned = collective_bytes_from_hlo(compiled.as_text())
    del compiled

    record = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "mesh_shape": list(mesh.devices.shape),
        "n_devices": int(mesh.devices.size),
        "policy": policy,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "collectives_scanned_hlo": coll_scanned,
    }

    # phase 2 — unrolled depth probes for truthful cost extrapolation
    # (XLA cost_analysis ignores while bodies; see models/model.py)
    if cfg is not None and not skip_costs:
        record.update(_measure_costs(cfg, cell, mesh))
        record["params_total"] = cfg.param_count()
        record["params_active"] = cfg.active_param_count()

    print(json.dumps({k: v for k, v in record.items() if k != "depth_probe"},
                     indent=2))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'selection'")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-costs", action="store_true",
                    help="scanned compile proof only (multi-pod pass)")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                if cell_applicable(arch, shape):
                    cells.append((arch, shape))
        cells.append(("selection", "select_1m"))
    else:
        assert args.arch, "--arch or --all required"
        archs = args.arch.split(",")
        for arch in archs:
            if arch == "selection":
                cells.append(("selection", "select_1m"))
            elif args.shape:
                cells.append((arch, args.shape))
            else:
                cells.extend(
                    (arch, s) for s in SHAPES if cell_applicable(arch, s)
                )

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"skip existing {path}")
                continue
            try:
                run_cell(arch, shape, mk, args.out, skip_costs=args.skip_costs)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mk, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells) * len(meshes)} cells")


if __name__ == "__main__":
    main()
