"""Device-mesh construction for the dry-run / training / serving launchers.

``make_production_mesh`` builds the 256-chip (single-pod 16x16) or 512-chip
(2x16x16 multi-pod) target meshes that ``launch/dryrun.py`` lowers against;
``make_test_mesh`` builds small host-device meshes for tests and CPU runs.
Selection serving builds its own 2-D ("batch", "data") meshes directly via
``jax.make_mesh`` — see launch/serve.py.

A FUNCTION, not a module constant — importing this module never touches jax
device state."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}; have {len(devices)}. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)."
        )
    dev_array = np.asarray(devices[:ndev]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for unit tests."""
    import jax

    dev_array = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)
