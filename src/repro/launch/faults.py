"""Deterministic fault injection for the serving stack.

Chaos tooling is only worth anything if it exercises the REAL code paths:
a test that monkeypatches a private method proves the monkeypatch, not the
server.  This module instead threads explicit *fault boundaries* through
the serving stack — the same lines production requests cross — and lets a
test (or ``benchmarks/chaos_bench.py``) arm them with a seeded, addressable
:class:`FaultPlan`:

    plan = FaultPlan([FaultSpec(site="dispatch", family="FacilityLocation",
                                times=1)])
    with inject(plan):
        server.flush()        # the first FL wave dispatch raises

Boundaries (each is a host-side ``check(site, **attrs)`` call in live code):

- ``"dispatch"``       — :meth:`SelectionServer._dispatch`, before the
  engine runs (attrs: family, backend, wave_index, mesh, rids, label);
- ``"kernel"``         — :func:`repro.core.optimizers.backends.
  resolve_backend`, when it resolves to a fused (non-XLA) backend
  (attrs: family, backend);
- ``"padder"``         — :func:`repro.launch.coalesce.pad_function`
  (attrs: family, n, n_to);
- ``"session-extend"`` — :meth:`SelectionSession.extend`, before the delta
  is built (attrs: session, seq, mode, family).

Determinism rules:

- A spec's ``times`` / ``after`` counters tick per *matching* check call,
  and every check site is host-side (``check`` is a no-op inside a jax
  trace), so firing order never depends on jit-cache state.
- ``rate`` draws come from the plan's own seeded RNG — same plan + same
  workload = same faults.
- ``delay_s`` sleeps before raising (latency injection); ``error=False``
  makes the spec a pure-delay fault.

Faults raise :class:`InjectedFault` (a ``RuntimeError`` tagged with its
``site``); the resilience layer (``launch/resilience.py``) treats it like
any transient engine error, which is the point — recovery is proved against
the same retry / fallback / quarantine machinery real failures hit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time

__all__ = [
    "SITES",
    "InjectedFault",
    "FaultSpec",
    "FaultPlan",
    "inject",
    "suspended",
    "check",
    "active_plan",
]

SITES = ("dispatch", "kernel", "padder", "session-extend")


class InjectedFault(RuntimeError):
    """A fault raised by an armed :class:`FaultPlan` at a serving boundary.

    ``site`` names the boundary, ``attrs`` is the boundary's address dict,
    ``spec`` the :class:`FaultSpec` that fired.  The resilience layer reads
    ``site`` to attribute breaker failures (a ``"kernel"`` fault trips the
    kernel breaker, a ``"dispatch"`` fault on a mesh trips the mesh one).
    """

    def __init__(self, site: str, attrs: dict, spec: "FaultSpec | None" = None):
        super().__init__(f"injected fault at {site}: {attrs}")
        self.site = site
        self.attrs = dict(attrs)
        self.spec = spec


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One addressable fault.  ``None`` matchers are wildcards.

    - ``site``: which boundary (required; one of :data:`SITES`).
    - ``family``: SetFunction class name (``"FacilityLocation"``).
    - ``backend``: gain-backend name; a trailing ``*`` prefix-matches
      (``"pallas-*"``).
    - ``wave_index``: the server's 0-based dispatch ordinal.
    - ``session``: a session id (``session-extend`` site).
    - ``rid``: fires when this request id rides the checked boundary.
    - ``mesh``: True/False — only when the dispatch is on / off a mesh.
    - ``times``: fire at most this many times (None = unlimited).
    - ``after``: skip the first ``after`` matching calls.
    - ``rate``: probability a match fires (drawn from the plan's seeded RNG).
    - ``delay_s``: sleep before acting (latency injection).
    - ``error``: False turns the spec into a pure-delay fault (no raise).
    """

    site: str
    family: str | None = None
    backend: str | None = None
    wave_index: int | None = None
    session: str | None = None
    rid: object = None
    mesh: bool | None = None
    times: int | None = 1
    after: int = 0
    rate: float = 1.0
    delay_s: float = 0.0
    error: bool = True

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; one of {SITES}")
        if self.times is not None and int(self.times) < 1:
            raise ValueError(f"times must be >= 1 or None, got {self.times!r}")
        if int(self.after) < 0:
            raise ValueError(f"after must be >= 0, got {self.after!r}")
        if not 0.0 <= float(self.rate) <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate!r}")
        if float(self.delay_s) < 0.0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s!r}")

    def matches(self, site: str, attrs: dict) -> bool:
        if site != self.site:
            return False
        if self.family is not None and attrs.get("family") != self.family:
            return False
        if self.backend is not None:
            got = attrs.get("backend")
            if got is None:
                return False
            if self.backend.endswith("*"):
                if not str(got).startswith(self.backend[:-1]):
                    return False
            elif got != self.backend:
                return False
        if self.wave_index is not None and attrs.get("wave_index") != self.wave_index:
            return False
        if self.session is not None and attrs.get("session") != self.session:
            return False
        if self.mesh is not None and bool(attrs.get("mesh")) != self.mesh:
            return False
        if self.rid is not None and self.rid not in attrs.get("rids", ()):
            return False
        return True


class FaultPlan:
    """A seeded set of :class:`FaultSpec` — arm it with :func:`inject`.

    Thread-safe: per-spec match/fire counters and the ``rate`` RNG live
    behind one lock, so the async flush thread and client threads hit the
    same deterministic sequence a single-threaded run would (per spec).
    """

    _GUARDED_BY = {"_matched": "_lock", "_fired": "_lock", "_rng": "_lock"}

    def __init__(self, specs, seed: int = 0):
        self.specs = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in specs
        ]
        self.seed = int(seed)
        self._rng = random.Random(self.seed)
        self._matched = [0] * len(self.specs)
        self._fired = [0] * len(self.specs)
        self._lock = threading.Lock()

    def fires(self, site: str, attrs: dict) -> FaultSpec | None:
        """The first spec firing for this check call, ticking counters."""
        with self._lock:
            for i, fs in enumerate(self.specs):
                if not fs.matches(site, attrs):
                    continue
                seen = self._matched[i]
                self._matched[i] += 1
                if seen < fs.after:
                    continue
                if fs.times is not None and self._fired[i] >= fs.times:
                    continue
                if fs.rate < 1.0 and self._rng.random() >= fs.rate:
                    continue
                self._fired[i] += 1
                return fs
        return None

    def counts(self) -> list[dict]:
        """Observability: per-spec ``{site, matched, fired}`` in plan order."""
        with self._lock:
            return [
                {"site": fs.site, "matched": m, "fired": f}
                for fs, m, f in zip(self.specs, self._matched, self._fired)
            ]


_STACK: list[FaultPlan] = []
_STACK_LOCK = threading.Lock()
_SUSPENDED = threading.local()


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Arm ``plan`` for the duration of the block (plans nest; the most
    recently armed plan is consulted first)."""
    with _STACK_LOCK:
        _STACK.append(plan)
    try:
        yield plan
    finally:
        with _STACK_LOCK:
            _STACK.remove(plan)


@contextlib.contextmanager
def suspended():
    """Disable fault checks on THIS thread inside the block.  The serving
    stack uses it for bookkeeping probes (e.g. resolving a wave's primary
    backend name for breaker routing) that must not consume fault budget."""
    _SUSPENDED.depth = getattr(_SUSPENDED, "depth", 0) + 1
    try:
        yield
    finally:
        _SUSPENDED.depth -= 1


def active_plan() -> FaultPlan | None:
    """The innermost armed plan, or None."""
    with _STACK_LOCK:
        return _STACK[-1] if _STACK else None


def _tracing() -> bool:
    # fault boundaries are host-side only: a check reached through a jit
    # trace must not fire, or firing order would depend on jit-cache state
    try:
        import jax.core as _jc

        return not _jc.trace_state_clean()
    except Exception:
        return False


def check(site: str, **attrs) -> None:
    """The boundary hook: no-op unless a plan is armed (and the thread is
    not suspended, and we are not inside a jax trace); otherwise consults
    plans innermost-first and raises :class:`InjectedFault` when one fires.
    """
    if not _STACK or getattr(_SUSPENDED, "depth", 0) > 0:
        return
    if _tracing():
        return
    with _STACK_LOCK:
        plans = list(_STACK)
    for plan in reversed(plans):
        fs = plan.fires(site, attrs)
        if fs is None:
            continue
        if fs.delay_s:
            time.sleep(fs.delay_s)
        if fs.error:
            raise InjectedFault(site, attrs, spec=fs)
        return
