"""Structured serving metrics: bounded counters and histograms.

The serving front door (`launch/serve.py` / `launch/async_serve.py`) used to
keep an unbounded ``wave_seconds`` list — linear memory in flush count on a
long-lived server — and reported nothing a scheduler could train on.  This
module is the replacement: every aggregate is **bounded** (count / sum / min
/ max plus a fixed-size uniform reservoir for percentiles), and the whole
tree snapshots to one JSON-able dict consumed by ``benchmarks/serve_bench.py
--json``, the serve CLI, and — per ROADMAP item 4 — the future backend/mesh
autotuner.

Schema (``ServerMetrics.snapshot()``)::

    {
      "counters": {
        "requests": int,        # real requests served
        "waves": int,           # engine dispatches
        "slots": int,           # engine slots incl. batch pads
        "padded_slots": int,    # batch-pad slots (pad waste)
        "rejections": int,      # submits refused by backpressure
        "flush_errors": int,    # dispatch errors (FlushError raised)
        "requeued": int,        # requests re-enqueued after a flush error
        "deadline_misses": int, # responses delivered past their deadline_s
        "sessions_opened": int, # SelectionSessions opened on this server
        "sessions_closed": int, # SelectionSessions closed
        "session_deltas": int,  # extend() deltas absorbed across sessions
        "session_churn": int,   # total selection churn across all deltas
        "retries_total": int,   # wave re-dispatch attempts scheduled
        "fallbacks_total": int, # waves served degraded (breaker open)
        "quarantined_total": int, # requests failed typed after N attempts
      },
      "queue_s":  {count, sum, max, p50, p99},   # submit -> dispatch start
      "wave_s":   {count, sum, max, p50, p99},   # one engine dispatch
      "queue_depth": {count, sum, max, p50, p99},# depth sampled at enqueue
      "delta_s":  {count, sum, max, p50, p99},   # session extend -> update
      "breakers": {"<label>": "closed|open|half_open", ...},
      "groups": {                                 # per-(family, n-bucket,
        "<label>": {                              #  optimizer) queue
          "requests": int, "waves": int,
          "queue_s": {...}, "wave_s": {...},
        }, ...
      },
    }

Group labels are ``Family/n<bucket>/<Optimizer>`` — the same (family,
n-bucket) keys the coalescer groups waves by, promoted to queue identity.

Thread-safety: increments and histogram records are guarded by one internal
lock, so the flush thread, submitters, and a metrics scraper can interleave
freely; ``snapshot()`` returns a detached copy.
"""
from __future__ import annotations

import math
import random
import threading
import zlib


def _seed_for(name: str) -> int:
    """Per-histogram reservoir seed.  Seeding every reservoir identically
    would correlate their eviction patterns (all reservoirs replace the same
    slots on the same ticks for equal-length streams); hashing the metric
    name decorrelates them while staying reproducible across runs."""
    return zlib.crc32(name.encode("utf-8"))

__all__ = ["Reservoir", "Histogram", "ServerMetrics"]


class Reservoir:
    """Fixed-size uniform sample of a stream (Vitter's algorithm R).

    Memory is O(capacity) no matter how many values are recorded; the
    percentile estimates converge on the stream's true quantiles.  The RNG
    is seeded per instance, so a server's metrics are reproducible for a
    deterministic workload.
    """

    def __init__(self, capacity: int = 512, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._rng = random.Random(seed)
        self._sample: list[float] = []
        self._seen = 0

    def add(self, value: float) -> None:
        self._seen += 1
        if len(self._sample) < self.capacity:
            self._sample.append(float(value))
            return
        j = self._rng.randrange(self._seen)
        if j < self.capacity:
            self._sample[j] = float(value)

    @property
    def seen(self) -> int:
        return self._seen

    def percentile(self, q: float) -> float:
        """Empirical q-quantile (q in [0, 1]) of the retained sample; NaN
        when nothing was recorded."""
        if not self._sample:
            return float("nan")
        s = sorted(self._sample)
        idx = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
        return s[idx]


class Histogram:
    """Bounded aggregation of a stream: count / sum / min / max exactly,
    percentiles from a fixed-size :class:`Reservoir`."""

    def __init__(self, reservoir_size: int = 512, seed: int = 0):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = 0.0
        self._reservoir = Reservoir(reservoir_size, seed=seed)

    def record(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        self._reservoir.add(value)

    def percentile(self, q: float) -> float:
        return self._reservoir.percentile(q)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def snapshot(self, ndigits: int = 6) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0, "max": 0.0, "p50": 0.0, "p99": 0.0}
        return {
            "count": self.count,
            "sum": round(self.total, ndigits),
            "max": round(self.max, ndigits),
            "p50": round(self.percentile(0.50), ndigits),
            "p99": round(self.percentile(0.99), ndigits),
        }


_COUNTERS = (
    "requests",
    "waves",
    "slots",
    "padded_slots",
    "rejections",
    "flush_errors",
    "requeued",
    "deadline_misses",
    "sessions_opened",
    "sessions_closed",
    "session_deltas",
    "session_churn",
    "retries_total",
    "fallbacks_total",
    "quarantined_total",
)


class _GroupMetrics:
    """Per-(family, n-bucket, optimizer) queue accounting."""

    __slots__ = ("requests", "waves", "queue_s", "wave_s")

    def __init__(self, reservoir_size: int, label: str = ""):
        self.requests = 0
        self.waves = 0
        self.queue_s = Histogram(reservoir_size, seed=_seed_for(f"{label}/queue_s"))
        self.wave_s = Histogram(reservoir_size, seed=_seed_for(f"{label}/wave_s"))


class ServerMetrics:
    """The serving stack's metric tree (see module docstring for schema)."""

    _GUARDED_BY = {
        "counters": "_lock",
        "queue_s": "_lock",
        "wave_s": "_lock",
        "queue_depth": "_lock",
        "delta_s": "_lock",
        "groups": "_lock",
        "breaker_states": "_lock",
    }

    def __init__(self, reservoir_size: int = 512):
        self._reservoir_size = int(reservoir_size)
        self._lock = threading.Lock()
        self.counters: dict[str, int] = {name: 0 for name in _COUNTERS}
        self.queue_s = Histogram(reservoir_size, seed=_seed_for("queue_s"))
        self.wave_s = Histogram(reservoir_size, seed=_seed_for("wave_s"))
        self.queue_depth = Histogram(reservoir_size, seed=_seed_for("queue_depth"))
        self.delta_s = Histogram(reservoir_size, seed=_seed_for("delta_s"))
        self.groups: dict[str, _GroupMetrics] = {}
        self.breaker_states: dict[str, str] = {}

    # -- recording -----------------------------------------------------------

    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + by

    def _group_locked(self, label: str) -> _GroupMetrics:
        g = self.groups.get(label)
        if g is None:
            g = self.groups[label] = _GroupMetrics(self._reservoir_size, label)
        return g

    def set_breaker(self, label: str, state: str) -> None:
        """Record a circuit breaker's current state (the server binds this
        to its :class:`~repro.launch.resilience.BreakerBoard`)."""
        with self._lock:
            self.breaker_states[label] = str(state)

    def observe_enqueue(self, label: str, depth: int) -> None:
        """One request admitted to ``label``'s queue, which now holds
        ``depth`` requests (the depth histogram feeds the autotuner's
        batching-pressure signal)."""
        with self._lock:
            self.queue_depth.record(depth)
            self._group_locked(label)  # the group exists from first admission

    def observe_wave(
        self,
        label: str,
        wave_s: float,
        *,
        requests: int,
        slots: int,
        padded_slots: int,
    ) -> None:
        """One engine dispatch for ``label``'s group."""
        with self._lock:
            self.counters["waves"] += 1
            self.counters["requests"] += requests
            self.counters["slots"] += slots
            self.counters["padded_slots"] += padded_slots
            self.wave_s.record(wave_s)
            g = self._group_locked(label)
            g.waves += 1
            g.requests += requests
            g.wave_s.record(wave_s)

    def observe_served(
        self, label: str, queue_s: float, *, deadline_missed: bool = False
    ) -> None:
        """One request answered: it waited ``queue_s`` before its wave's
        dispatch began."""
        with self._lock:
            self.queue_s.record(queue_s)
            self._group_locked(label).queue_s.record(queue_s)
            if deadline_missed:
                self.counters["deadline_misses"] += 1

    def observe_delta(self, delta_s: float, *, churn: int = 0) -> None:
        """One session ``extend()`` absorbed: it took ``delta_s`` seconds
        submit-to-update and replaced ``churn`` members of the previous
        selection (symmetric difference of the id sets)."""
        with self._lock:
            self.counters["session_deltas"] += 1
            self.counters["session_churn"] += int(churn)
            self.delta_s.record(delta_s)

    # -- reading -------------------------------------------------------------

    def snapshot(self) -> dict:
        """Detached JSON-able copy of every counter and histogram."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "queue_s": self.queue_s.snapshot(),
                "wave_s": self.wave_s.snapshot(),
                "queue_depth": self.queue_depth.snapshot(ndigits=1),
                "delta_s": self.delta_s.snapshot(),
                "breakers": dict(sorted(self.breaker_states.items())),
                "groups": {
                    label: {
                        "requests": g.requests,
                        "waves": g.waves,
                        "queue_s": g.queue_s.snapshot(),
                        "wave_s": g.wave_s.snapshot(),
                    }
                    for label, g in sorted(self.groups.items())
                },
            }
